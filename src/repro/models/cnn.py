"""Image classifiers for the paper-faithful repro (CIFAR-style, CPU scale).

The paper trains VGG-16 / ResNet-18 / ResNet-50 on CIFAR/ImageNet.  This
container has no datasets and one CPU core, so the repro benchmarks use
*reduced-width* members of the same families (ResNet-lite with residual
stages, VGG-lite conv stacks, plus an MLP) on a deterministic synthetic
image task — the claims being validated are the *relative patterns*
(Baseline averaged ≈ chance, WASH averaged ≈ ensemble, WASH ≥ PAPA), which
are scale-transferable, not the absolute CIFAR numbers.

Normalization is GroupNorm: the paper explicitly does not shuffle/recompute
BatchNorm running statistics, and GN removes that state entirely while
keeping the architecture family intact (noted in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any, List

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    kind: str = "resnet"  # resnet | vgg | mlp
    width: int = 32
    depth: int = 3  # stages (resnet/vgg) or hidden layers (mlp)
    num_classes: int = 10
    image_hw: int = 16
    in_channels: int = 3
    groups: int = 4

    @property
    def num_blocks(self) -> int:
        return self.depth


def _conv_init(key, k, cin, cout):
    fan = k * k * cin
    return jax.random.normal(key, (k, k, cin, cout), jnp.float32) * (2.0 / fan) ** 0.5


def _dense(key, cin, cout):
    return {
        "w": jax.random.normal(key, (cin, cout), jnp.float32) * cin ** -0.5,
        "b": jnp.zeros((cout,), jnp.float32),
    }


def conv(p, x, stride=1):
    return jax.lax.conv_general_dilated(
        x, p, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def groupnorm(p, x, groups):
    B, H, W, C = x.shape
    g = min(groups, C)
    xg = x.reshape(B, H, W, g, C // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(B, H, W, C) * p["scale"] + p["bias"]


def _gn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def init_classifier(key, cfg: ClassifierConfig) -> PyTree:
    ks = jax.random.split(key, cfg.depth + 3)
    if cfg.kind == "mlp":
        d_in = cfg.image_hw * cfg.image_hw * cfg.in_channels
        blocks: List[Any] = []
        for i in range(cfg.depth):
            blocks.append(_dense(ks[i + 1], cfg.width, cfg.width))
        return {
            "embed": _dense(ks[0], d_in, cfg.width),
            "blocks": blocks,
            "head": _dense(ks[-1], cfg.width, cfg.num_classes),
        }

    w = cfg.width
    stem = {"conv": _conv_init(ks[0], 3, cfg.in_channels, w), "gn": _gn_init(w)}
    blocks = []
    cin = w
    for i in range(cfg.depth):
        cout = w * (2 ** i)
        if cfg.kind == "resnet":
            blk = {
                "conv1": _conv_init(jax.random.fold_in(ks[i + 1], 0), 3, cin, cout),
                "gn1": _gn_init(cout),
                "conv2": _conv_init(jax.random.fold_in(ks[i + 1], 1), 3, cout, cout),
                "gn2": _gn_init(cout),
            }
            if cin != cout:
                blk["proj"] = _conv_init(jax.random.fold_in(ks[i + 1], 2), 1, cin, cout)
        else:  # vgg
            blk = {
                "conv1": _conv_init(jax.random.fold_in(ks[i + 1], 0), 3, cin, cout),
                "gn1": _gn_init(cout),
            }
        blocks.append(blk)
        cin = cout
    return {
        "embed": stem,
        "blocks": blocks,
        "head": _dense(ks[-1], cin, cfg.num_classes),
    }


def apply_classifier(params, cfg: ClassifierConfig, images) -> jax.Array:
    """images: (B, H, W, C) float32 -> logits (B, num_classes)."""
    if cfg.kind == "mlp":
        x = images.reshape(images.shape[0], -1)
        x = jax.nn.relu(x @ params["embed"]["w"] + params["embed"]["b"])
        for blk in params["blocks"]:
            x = jax.nn.relu(x @ blk["w"] + blk["b"])
        return x @ params["head"]["w"] + params["head"]["b"]

    x = jax.nn.relu(groupnorm(params["embed"]["gn"], conv(params["embed"]["conv"], images), cfg.groups))
    for i, blk in enumerate(params["blocks"]):
        stride = 2 if i > 0 else 1
        if cfg.kind == "resnet":
            h = jax.nn.relu(groupnorm(blk["gn1"], conv(blk["conv1"], x, stride), cfg.groups))
            h = groupnorm(blk["gn2"], conv(blk["conv2"], h), cfg.groups)
            skip = conv(blk["proj"], x, stride) if "proj" in blk else x
            x = jax.nn.relu(h + skip)
        else:
            x = jax.nn.relu(groupnorm(blk["gn1"], conv(blk["conv1"], x, stride), cfg.groups))
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]
