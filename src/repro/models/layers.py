"""Shared neural-net layers: norms, rope, MLPs, GQA + MLA attention.

Everything is a pure function over explicit parameter dicts (no module
framework — flax is not available here and plain pytrees keep the WASH
shuffle logic trivial).  Compute-sensitive reductions run in float32.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG_INF = -1e30


def param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def l2norm(x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.sum(xf * xf, axis=-1, keepdims=True) + eps)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: (T,) or (..., T) absolute positions."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    pos = positions.astype(jnp.float32)
    ang = pos[..., :, None] * inv[None, :]  # (..., T, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., T, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (d_model, d_ff), dtype),
        "w3": dense_init(k2, (d_model, d_ff), dtype),
        "w2": dense_init(k3, (d_ff, d_model), dtype),
    }


def swiglu(p, x):
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]


def gelu_mlp_init(key, d_model, d_ff, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w1": dense_init(k1, (d_model, d_ff), dtype),
        "b1": jnp.zeros((d_ff,), dtype),
        "w2": dense_init(k2, (d_ff, d_model), dtype),
        "b2": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig):
    dtype = param_dtype(cfg)
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.num_heads * hd), dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, cfg.d_model), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, positions):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.num_heads, hd)
    k = k.reshape(B, T, cfg.num_kv_heads, hd)
    v = v.reshape(B, T, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.pos_kind == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def sdpa(q, k, v, mask, num_kv_heads: int):
    """q: (B,Tq,H,hd) k/v: (B,Tk,KV,hd); mask: (Tq,Tk) or (B,Tq,Tk) bool."""
    B, Tq, H, hd = q.shape
    kv = num_kv_heads
    g = H // kv
    qf = q.reshape(B, Tq, kv, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("btkgh,bskh->bkgts", qf, kf) / (hd ** 0.5)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w, vf)
    return out.reshape(B, Tq, H, hd).astype(q.dtype)


def sdpa_chunked(q, k, v, num_kv_heads: int, *, chunk: int, window=None,
                 bidirectional: bool = False):
    """Online-softmax attention over kv chunks — never materializes SxS.

    Pure-jnp flash-style formulation (lax.scan over kv chunks with running
    max/sum), so it lowers through XLA on any backend and is differentiable;
    the Pallas kernel (repro.kernels.flash_attention) is the TPU-tiled
    version of the same schedule.  Used when cfg.attn_impl == "chunked".
    """
    B, Tq, H, hd = q.shape
    S = k.shape[1]
    kv = num_kv_heads
    g = H // kv
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad S to a chunk multiple"
    qf = q.reshape(B, Tq, kv, g, hd).astype(jnp.float32) * (hd ** -0.5)
    qpos = jnp.arange(Tq)

    def body(carry, inp):
        acc, m_prev, l_prev = carry
        j, k_c, v_c = inp  # chunk idx, (B,chunk,kv,hd) x2
        scores = jnp.einsum("btkgh,bskh->bkgts", qf, k_c.astype(jnp.float32))
        kpos = j * chunk + jnp.arange(chunk)
        mask = jnp.ones((Tq, chunk), bool)
        if not bidirectional:
            mask = kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgts,bskh->bkgth", p, v_c.astype(jnp.float32)
        )
        return (acc_new, m_new, l_new), None

    n_chunks = S // chunk
    k_c = k.reshape(B, n_chunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(B, n_chunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    acc0 = jnp.zeros((B, kv, g, Tq, hd), jnp.float32)
    m0 = jnp.full((B, kv, g, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, kv, g, Tq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(n_chunks), k_c, v_c)
    )
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, hd).astype(q.dtype)


def sdpa_banded(q, k, v, num_kv_heads: int, *, window: int):
    """Sliding-window attention in O(S·2W): each W-sized query block attends
    only to its own and the previous key block (relative mask inside).

    The naive/chunked paths still *compute* S×S (masked) scores; for SWA
    archs (hymba, the long_500k dense variants) this banded form is the
    memory-roofline fix — score traffic drops by S/(2W).
    """
    B, S, H, hd = q.shape
    kv = num_kv_heads
    g = H // kv
    W = window
    assert S % W == 0, "pad S to a window multiple"
    nb = S // W
    qf = (q.reshape(B, nb, W, kv, g, hd).astype(jnp.float32)) * (hd ** -0.5)
    kb = k.reshape(B, nb, W, kv, hd).astype(jnp.float32)
    vb = v.reshape(B, nb, W, kv, hd).astype(jnp.float32)
    # previous block (zeros before block 0)
    zeros = jnp.zeros_like(kb[:, :1])
    k2 = jnp.concatenate([jnp.concatenate([zeros, kb[:, :-1]], 1), kb], axis=2)  # (B,nb,2W,kv,hd)
    v2 = jnp.concatenate([jnp.concatenate([zeros, vb[:, :-1]], 1), vb], axis=2)
    scores = jnp.einsum("bntkgh,bnskh->bnkgts", qf, k2)  # (B,nb,kv,g,W,2W)
    qpos = jnp.arange(W)[:, None]
    kpos = jnp.arange(2 * W)[None, :] - W
    rel = qpos - kpos  # how far behind the key is
    mask = (rel >= 0) & (rel < W)  # causal + window
    first = jnp.arange(2 * W)[None, :] >= W  # block 0 has no previous block
    m_all = jnp.broadcast_to(mask[None], (nb, W, 2 * W))
    m_all = m_all.at[0].set(mask & first)
    scores = jnp.where(m_all[None, :, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnkgts,bnskh->bntkgh", w, v2)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def causal_mask(T: int, window: Optional[int] = None):
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    m = j <= i
    if window is not None:
        m = m & (j > i - window)
    return m


def gqa_train(p, cfg: ModelConfig, x, bidirectional: bool = False):
    B, T, _ = x.shape
    positions = jnp.arange(T)
    q, k, v = _qkv(p, cfg, x, positions)
    if cfg.attn_impl == "chunked" and not bidirectional and cfg.window and T % cfg.window == 0 and T > cfg.window:
        out = sdpa_banded(q, k, v, cfg.num_kv_heads, window=cfg.window)
    elif cfg.attn_impl == "chunked":
        out = sdpa_chunked(q, k, v, cfg.num_kv_heads, chunk=min(cfg.attn_chunk, T),
                           window=cfg.window, bidirectional=bidirectional)
    else:
        if bidirectional:
            mask = jnp.ones((T, T), bool)
        else:
            mask = causal_mask(T, cfg.window)
        out = sdpa(q, k, v, mask, cfg.num_kv_heads)
    return out.reshape(B, T, -1) @ p["wo"]


# -- KV cache -------------------------------------------------------------


def gqa_cache_init(cfg: ModelConfig, batch: int, capacity: int, num_layers: int):
    hd = cfg.resolved_head_dim
    dtype = param_dtype(cfg)
    return {
        "k": jnp.zeros((num_layers, batch, capacity, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((num_layers, batch, capacity, cfg.num_kv_heads, hd), dtype),
        "pos_ids": jnp.full((num_layers, capacity), -1, jnp.int32),
    }


def gqa_prefill(p, cfg: ModelConfig, x, cache_l):
    """Full-sequence attention that also fills this layer's cache slice."""
    B, T, _ = x.shape
    positions = jnp.arange(T)
    q, k, v = _qkv(p, cfg, x, positions)
    cap = cache_l["k"].shape[1]
    # prefill writes the last `cap` tokens; ring layout slot = pos % cap so
    # a later decode step can keep appending at (pos % cap).
    start = max(T - cap, 0)
    if cap <= T:
        shift = start % cap
        cache_l = {
            "k": jnp.roll(k[:, start:], shift, axis=1).astype(cache_l["k"].dtype),
            "v": jnp.roll(v[:, start:], shift, axis=1).astype(cache_l["v"].dtype),
            "pos_ids": jnp.roll(jnp.arange(start, T, dtype=jnp.int32), shift),
        }
    else:
        cache_l = {
            "k": jax.lax.dynamic_update_slice(
                cache_l["k"], k.astype(cache_l["k"].dtype), (0, 0, 0, 0)
            ),
            "v": jax.lax.dynamic_update_slice(
                cache_l["v"], v.astype(cache_l["v"].dtype), (0, 0, 0, 0)
            ),
            "pos_ids": jax.lax.dynamic_update_slice(
                cache_l["pos_ids"], jnp.arange(T, dtype=jnp.int32), (0,)
            ),
        }
    if cfg.attn_impl == "chunked" and cfg.window and T % cfg.window == 0 and T > cfg.window:
        out = sdpa_banded(q, k, v, cfg.num_kv_heads, window=cfg.window)
    elif cfg.attn_impl == "chunked":
        out = sdpa_chunked(q, k, v, cfg.num_kv_heads,
                           chunk=min(cfg.attn_chunk, T), window=cfg.window)
    else:
        mask = causal_mask(T, cfg.window)
        out = sdpa(q, k, v, mask, cfg.num_kv_heads)
    return out.reshape(B, T, -1) @ p["wo"], cache_l


def gqa_decode(p, cfg: ModelConfig, x, cache_l, pos):
    """One-token decode against this layer's cache slice.

    ``pos`` is the absolute position of the new token.  The cache is a ring
    of size ``capacity``: full-attention archs use capacity == seq_len;
    sliding-window archs use capacity == window, giving O(window) decode
    regardless of logical context length (long_500k path).
    """
    B, T, _ = x.shape
    assert T == 1
    q, k, v = _qkv(p, cfg, x, jnp.asarray(pos)[None])
    cap = cache_l["k"].shape[1]
    slot = jnp.asarray(pos, jnp.int32) % cap
    ck = jax.lax.dynamic_update_index_in_dim(cache_l["k"], k[:, 0].astype(cache_l["k"].dtype), slot, 1)
    cv = jax.lax.dynamic_update_index_in_dim(cache_l["v"], v[:, 0].astype(cache_l["v"].dtype), slot, 1)
    cpos = jax.lax.dynamic_update_index_in_dim(
        cache_l["pos_ids"], jnp.asarray(pos, jnp.int32), slot, 0
    )
    cache_l = {"k": ck, "v": cv, "pos_ids": cpos}
    valid = cpos >= 0
    if cfg.window is not None:
        valid = valid & (cpos > pos - cfg.window)
    out = sdpa(q, ck, cv, valid[None, :], cfg.num_kv_heads)  # (Tq=1, cap) mask
    return out.reshape(B, 1, -1) @ p["wo"], cache_l


# -- paged KV cache (continuous-batching serving) ---------------------------

#: supported storage dtypes for the paged pools: None = the model's param
#: dtype (the bitwise-exact path); "int8" = per-page symmetric quantization
#: with a float32 scale per (layer, page), halving pool HBM
KV_DTYPES = (None, "int8")

#: adaptive page scales start here and only ever grow (monotone), so a
#: page's already-written rows are rescaled at most once per scale bump
KV_SCALE_FLOOR = 1e-8

#: page 0 (the runtime's scratch page) keeps this scale FOREVER: masked
#: garbage writes from inactive slots must never adapt quantization state
KV_SCRATCH_SCALE = 1.0


def paged_pools_init(cfg: ModelConfig, num_pages: int, page_size: int,
                     num_layers: int, kv_dtype: str = None):
    """Block-pool KV cache: ``num_pages`` shared fixed-size pages per layer.

    Layout ``(num_layers, num_pages, page_size, KV, hd)`` — the per-slot
    view is a **page table** of pool indices, not a contiguous slice, so
    slots with different context lengths share one allocation and common
    prompt prefixes can share pages (``repro.serving.batching`` owns the
    table/refcount bookkeeping).  Page 0 is reserved by the runtime as a
    scratch page for inactive slots.

    ``kv_dtype=None`` stores pages in the model's param dtype (bitwise
    path).  ``kv_dtype="int8"`` stores each pool as
    ``{"q": int8 (L, P, page_size, KV, hd), "scale": f32 (L, P)}`` — one
    symmetric scale per (layer, page), written by
    :func:`paged_store_rows` / :func:`paged_store_chunk` and applied at
    read time inside both paged attends.  Page 0's scale is pinned to
    :data:`KV_SCRATCH_SCALE` and never adapts."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype={kv_dtype!r}; expected one of {KV_DTYPES}")
    hd = cfg.resolved_head_dim
    shape = (num_layers, num_pages, page_size, cfg.num_kv_heads, hd)
    if kv_dtype is None:
        dtype = param_dtype(cfg)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    scale = jnp.full((num_layers, num_pages), KV_SCALE_FLOOR, jnp.float32)
    scale = scale.at[:, 0].set(KV_SCRATCH_SCALE)
    pool = {"q": jnp.zeros(shape, jnp.int8), "scale": scale}
    return {"k": pool, "v": jax.tree_util.tree_map(lambda x: x, pool)}


def kv_quantize(x, scale):
    """Symmetric int8 quantization of ``x`` under per-page ``scale``
    (broadcast against x's leading axes)."""
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def kv_dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def kv_page_scale(x, floor: float = None):
    """The smallest symmetric-int8 scale covering ``x`` (amax / 127)."""
    floor = KV_SCALE_FLOOR if floor is None else floor
    return jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, floor)


def paged_store_rows(pool, page_idx, offset, rows):
    """Write one (KV, hd) row per batch entry into ``pool`` at
    ``(page_idx[b], offset[b])`` — the decode-step scatter.

    For plain pools this is the raw ``.at[].set``.  For int8 pools the
    written pages' scales grow monotonically to cover the new rows
    (``max(old, amax(row)/127)``): untouched pages keep their bits, and a
    page whose scale does not change keeps its already-written rows
    bit-identical (the rescale ratio is exactly 1.0).  Page 0 (scratch)
    never adapts — its scale stays :data:`KV_SCRATCH_SCALE`.

    ``page_idx`` MAY contain duplicates (the speculative verify step
    scatters several rows of one slot — often one page — in a single
    call): scales merge through a scatter-max, every duplicate gathers
    the same pre-step page bits and rescales them identically, and the
    new rows land via a per-``(page, offset)`` scatter whose index pairs
    are distinct for live rows."""
    if not isinstance(pool, dict):
        return pool.at[page_idx, offset].set(rows.astype(pool.dtype))
    q, scale = pool["q"], pool["scale"]
    rows = rows.astype(jnp.float32)                       # (B, KV, hd)
    row_amax = jnp.max(jnp.abs(rows), axis=(1, 2))        # (B,)
    s_new = scale.at[page_idx].max(row_amax / 127.0)      # (P,) dup-safe
    s_new = s_new.at[0].set(KV_SCRATCH_SCALE)
    # rescale the touched pages' existing bits; duplicates gather the same
    # old page and the same (s_old/s_new) ratio, so their scatter-back
    # writes are identical and any winner is correct
    ratio = (scale / s_new)[page_idx]                     # (B,)
    pages = jnp.round(q[page_idx].astype(jnp.float32)
                      * ratio[:, None, None, None])
    pages = jnp.clip(pages, -127, 127).astype(jnp.int8)
    q = q.at[page_idx].set(pages)
    qrows = jnp.clip(jnp.round(rows / s_new[page_idx][:, None, None]),
                     -127, 127).astype(jnp.int8)
    return {"q": q.at[page_idx, offset].set(qrows), "scale": s_new}


def paged_store_chunk(pool, page_table, positions, rows):
    """Write a contiguous chunk of rows for ONE slot — the prefill scatter.

    ``positions`` are the rows' absolute positions; their pages are
    ``page_table[pos // page_size]``.  Same quantization discipline as
    :func:`paged_store_rows`; the static page-window covers the chunk's
    worst-case page span, and window entries past the chunk's last page
    are redirected to the scratch page (page 0) so no live page is ever
    gather/scattered without rows."""
    pos = positions.astype(jnp.int32)
    if not isinstance(pool, dict):
        page_size = pool.shape[1]
        return pool.at[page_table[pos // page_size], pos % page_size].set(
            rows.astype(pool.dtype))
    q, scale = pool["q"], pool["scale"]
    page_size = q.shape[1]
    max_pages = page_table.shape[0]
    rows = rows.astype(jnp.float32)                       # (T, KV, hd)
    T = rows.shape[0]
    n_w = T // page_size + 2                              # page-window bound
    first = pos[0] // page_size
    window = first + jnp.arange(n_w)                      # logical pages
    touched = window <= pos[T - 1] // page_size
    pids = jnp.where(touched,
                     page_table[jnp.minimum(window, max_pages - 1)], 0)
    local = pos // page_size - first                      # (T,) in-window
    offs = pos % page_size
    row_amax = jnp.max(jnp.abs(rows), axis=(1, 2))        # (T,)
    page_amax = jnp.zeros((n_w,), jnp.float32).at[local].max(row_amax)
    s_old = scale[pids]
    s_new = jnp.maximum(s_old, page_amax / 127.0)
    s_new = jnp.where(pids == 0, KV_SCRATCH_SCALE, s_new)
    pages = q[pids].astype(jnp.float32)                   # (n_w, ps, KV, hd)
    pages = jnp.round(pages * (s_old / s_new)[:, None, None, None])
    pages = pages.at[local, offs].set(
        jnp.round(rows / s_new[local][:, None, None]))
    pages = jnp.clip(pages, -127, 127).astype(jnp.int8)
    return {"q": q.at[pids].set(pages),
            "scale": scale.at[pids].set(s_new)}


def gqa_decode_paged(p, cfg: ModelConfig, x, k_pool_l, v_pool_l, page_table,
                     positions, use_pallas: bool):
    """One-token decode for a batch of slots against the paged pool.

      x          : (B, 1, D) — one new token per slot
      k/v_pool_l : (P, page_size, KV, hd) — this layer's page pool
      page_table : (B, max_pages) int32
      positions  : (B,) int32 — absolute write position of each new token
                   (its page is ``page_table[b, pos // page_size]``)
      use_pallas : route the attend through the fused Pallas kernel
                   (``kernels.paged_attention``) instead of the jnp
                   gather+attend oracle (``kernels.ref``)

    Every slot's new K/V lands in a page that slot owns exclusively (the
    runtime never hands a shared prefix page out as a write target), so
    the scatter below cannot collide across slots.  int8 pools
    (``{"q","scale"}`` dicts — see :func:`paged_pools_init`) quantize the
    write and dequantize inside the attend.  Returns
    ``(out (B,1,D), k_pool_l, v_pool_l)``.
    """
    from repro.kernels.paged_attention import paged_attention_pallas
    from repro.kernels.ref import paged_attention_ref

    B, T, _ = x.shape
    assert T == 1
    q, k, v = _qkv(p, cfg, x, positions[:, None])
    quantized = isinstance(k_pool_l, dict)
    page_size = (k_pool_l["q"] if quantized else k_pool_l).shape[1]
    pos = positions.astype(jnp.int32)
    page_idx = page_table[jnp.arange(B), pos // page_size]  # (B,)
    offset = pos % page_size
    k_pool_l = paged_store_rows(k_pool_l, page_idx, offset, k[:, 0])
    v_pool_l = paged_store_rows(v_pool_l, page_idx, offset, v[:, 0])
    lengths = pos + 1  # context = everything written so far incl. this token
    attend = paged_attention_pallas if use_pallas else paged_attention_ref
    if quantized:
        out = attend(q[:, 0], k_pool_l["q"], v_pool_l["q"], page_table,
                     lengths, k_scale=k_pool_l["scale"],
                     v_scale=v_pool_l["scale"])
    else:
        out = attend(q[:, 0], k_pool_l, v_pool_l, page_table, lengths)
    return out.reshape(B, 1, -1) @ p["wo"], k_pool_l, v_pool_l


def gqa_prefill_paged(p, cfg: ModelConfig, x, k_pool_l, v_pool_l, page_table,
                      positions):
    """Chunk/suffix prefill for ONE slot against the paged pool.

      x          : (1, T, D) — hidden states of a contiguous prompt chunk
      k/v_pool_l : (P, page_size, KV, hd) — this layer's page pool
      page_table : (max_pages,) int32 — the slot's pages, prompt order
      positions  : (T,) int32 — absolute positions pos0 .. pos0+T-1
                   (traced, so one compile serves every chunk offset)

    Writes the chunk's K/V into the slot's pages (all write targets are
    slot-owned — cached prefix pages sit strictly below ``positions[0]``
    and are never written), then attends over the table-gathered context
    under the causal mask ``j <= position``.  Rows are bitwise-identical
    to the same rows of the whole-prompt :func:`gqa_prefill`: q/k/v are
    per-position ops, the gathered context lists positions in order, and
    the masked tail contributes exact zeros to the softmax and the value
    sum — same argument (and same test evidence) as
    :func:`gqa_decode_paged` vs :func:`gqa_decode`.
    """
    B, T, _ = x.shape
    assert B == 1
    q, k, v = _qkv(p, cfg, x, positions)
    pos = positions.astype(jnp.int32)
    # in-chunk positions are distinct, so the store never scatter-dups
    k_pool_l = paged_store_chunk(k_pool_l, page_table, pos, k[0])
    v_pool_l = paged_store_chunk(v_pool_l, page_table, pos, v[0])
    if isinstance(k_pool_l, dict):
        kc = kv_dequantize(k_pool_l["q"][page_table],
                           k_pool_l["scale"][page_table][:, None, None, None])
        vc = kv_dequantize(v_pool_l["q"][page_table],
                           v_pool_l["scale"][page_table][:, None, None, None])
        kc = kc.reshape(1, -1, cfg.num_kv_heads, k.shape[-1])
        vc = vc.reshape(1, -1, cfg.num_kv_heads, v.shape[-1])
    else:
        kc = k_pool_l[page_table].reshape(1, -1, cfg.num_kv_heads, k.shape[-1])
        vc = v_pool_l[page_table].reshape(1, -1, cfg.num_kv_heads, v.shape[-1])
    ctx = kc.shape[1]
    mask = jnp.arange(ctx)[None, :] <= pos[:, None]  # (T, ctx)
    out = sdpa(q, kc, vc, mask, cfg.num_kv_heads)
    return out.reshape(B, T, -1) @ p["wo"], k_pool_l, v_pool_l


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def xattn_init(key, cfg: ModelConfig):
    dtype = param_dtype(cfg)
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.num_heads * hd), dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, cfg.d_model), dtype),
    }


def xattn(p, cfg: ModelConfig, x, kv_feats):
    """kv_feats: encoder output (B, S_enc, D) — no rope, full visibility."""
    B, T, _ = x.shape
    S = kv_feats.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, T, cfg.num_heads, hd)
    k = (kv_feats @ p["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (kv_feats @ p["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    mask = jnp.ones((T, S), bool)
    out = sdpa(q, k, v, mask, cfg.num_kv_heads)
    return out.reshape(B, T, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2, arXiv:2405.04434)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig):
    dtype = param_dtype(cfg)
    H = cfg.num_heads
    ks = jax.random.split(key, 6)
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": dense_init(ks[0], (cfg.d_model, H * qd), dtype),
        "w_dkv": dense_init(ks[1], (cfg.d_model, cfg.kv_lora_rank), dtype),
        "w_krope": dense_init(ks[2], (cfg.d_model, cfg.qk_rope_dim), dtype),
        "w_uk": dense_init(ks[3], (cfg.kv_lora_rank, H * cfg.qk_nope_dim), dtype),
        "w_uv": dense_init(ks[4], (cfg.kv_lora_rank, H * cfg.v_head_dim), dtype),
        "wo": dense_init(ks[5], (H * cfg.v_head_dim, cfg.d_model), dtype),
    }


def _mla_q(p, cfg: ModelConfig, x, positions):
    B, T, _ = x.shape
    H = cfg.num_heads
    q = (x @ p["wq"]).reshape(B, T, H, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_train(p, cfg: ModelConfig, x):
    """Training/prefill form: latents expanded to per-head K/V."""
    B, T, _ = x.shape
    H = cfg.num_heads
    positions = jnp.arange(T)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    ckv = x @ p["w_dkv"]  # (B,T,r)
    krope = apply_rope((x @ p["w_krope"])[:, :, None, :], positions, cfg.rope_theta)
    k_nope = (ckv @ p["w_uk"]).reshape(B, T, H, cfg.qk_nope_dim)
    v = (ckv @ p["w_uv"]).reshape(B, T, H, cfg.v_head_dim)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    scores = (
        jnp.einsum("bthd,bshd->bhts", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
        + jnp.einsum("bthd,bsxd->bhts", q_rope.astype(jnp.float32), krope.astype(jnp.float32))
    ) * scale
    mask = causal_mask(T)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", w, v.astype(jnp.float32)).astype(x.dtype)
    return out.reshape(B, T, -1) @ p["wo"]


def mla_cache_init(cfg: ModelConfig, batch: int, capacity: int, num_layers: int):
    dtype = param_dtype(cfg)
    return {
        "ckv": jnp.zeros((num_layers, batch, capacity, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((num_layers, batch, capacity, cfg.qk_rope_dim), dtype),
        "pos_ids": jnp.full((num_layers, capacity), -1, jnp.int32),
    }


def mla_prefill(p, cfg: ModelConfig, x, cache_l):
    B, T, _ = x.shape
    out = mla_train(p, cfg, x)
    positions = jnp.arange(T)
    ckv = x @ p["w_dkv"]
    krope = apply_rope((x @ p["w_krope"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    cache_l = {
        "ckv": cache_l["ckv"].at[:, :T].set(ckv.astype(cache_l["ckv"].dtype)),
        "krope": cache_l["krope"].at[:, :T].set(krope.astype(cache_l["krope"].dtype)),
        "pos_ids": cache_l["pos_ids"].at[:T].set(jnp.arange(T, dtype=jnp.int32)),
    }
    return out, cache_l


def mla_decode(p, cfg: ModelConfig, x, cache_l, pos):
    """Absorbed decode: scores/values computed against the *latent* cache.

    q_nope is absorbed through w_uk (q' = q_nope @ w_uk per head) and the
    attention output is read in latent space then expanded through w_uv —
    the memory-bandwidth-optimal MLA decode form.
    """
    B, T, _ = x.shape
    assert T == 1
    H = cfg.num_heads
    r = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(p, cfg, x, jnp.asarray(pos)[None])
    ckv_t = x @ p["w_dkv"]  # (B,1,r)
    krope_t = apply_rope(
        (x @ p["w_krope"])[:, :, None, :], jnp.asarray(pos)[None], cfg.rope_theta
    )[:, :, 0]
    cap = cache_l["ckv"].shape[1]
    slot = jnp.asarray(pos, jnp.int32) % cap
    ckv = jax.lax.dynamic_update_index_in_dim(
        cache_l["ckv"], ckv_t[:, 0].astype(cache_l["ckv"].dtype), slot, 1
    )
    krope = jax.lax.dynamic_update_index_in_dim(
        cache_l["krope"], krope_t[:, 0].astype(cache_l["krope"].dtype), slot, 1
    )
    cpos = jax.lax.dynamic_update_index_in_dim(
        cache_l["pos_ids"], jnp.asarray(pos, jnp.int32), slot, 0
    )
    cache_l = {"ckv": ckv, "krope": krope, "pos_ids": cpos}

    wk = p["w_uk"].reshape(r, H, cfg.qk_nope_dim)
    q_abs = jnp.einsum("bthd,rhd->bthr", q_nope.astype(jnp.float32), wk.astype(jnp.float32))
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    scores = (
        jnp.einsum("bthr,bsr->bhts", q_abs, ckv.astype(jnp.float32))
        + jnp.einsum("bthd,bsd->bhts", q_rope.astype(jnp.float32), krope.astype(jnp.float32))
    ) * scale
    valid = cpos >= 0
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bhts,bsr->bthr", w, ckv.astype(jnp.float32))  # (B,1,H,r)
    wv = p["w_uv"].reshape(r, H, cfg.v_head_dim)
    out = jnp.einsum("bthr,rhd->bthd", lat, wv.astype(jnp.float32)).astype(x.dtype)
    return out.reshape(B, 1, -1) @ p["wo"], cache_l
