"""Model assembly: init / train-loss / prefill / decode for every family.

Blocks are *scanned*: all layer parameters are stacked along a leading
``num_layers`` axis under ``params["blocks"]`` (and ``params["enc_blocks"]``
for encoder–decoder models).  This keeps HLO size O(1) in depth — required
for the 61/80-layer dry-runs — and the WASH layer-wise schedule stays exact
via the layered plans in ``repro.core.shuffle``.

Batch dicts:
  dense/moe/ssm/hybrid : {"tokens": (B,S) int32}
  vlm                  : + {"patches": (B,P,D)}        (stubbed ViT output)
  audio (whisper)      : + {"frames": (B,F,D)}          (stubbed conv/mel output)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

PyTree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _mlp_init(key, cfg: ModelConfig):
    if cfg.moe:
        return MOE.moe_init(key, cfg)
    return L.swiglu_init(key, cfg.d_model, cfg.d_ff, L.param_dtype(cfg))


def _block_init(key, cfg: ModelConfig):
    dtype = L.param_dtype(cfg)
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    if cfg.block_kind == "rwkv6":
        return {
            "ln1": L.rmsnorm_init(D, dtype),
            "ln2": L.rmsnorm_init(D, dtype),
            "rwkv": SSM.rwkv6_init(ks[0], cfg),
        }
    p = {
        "ln1": L.rmsnorm_init(D, dtype),
        "ln2": L.rmsnorm_init(D, dtype),
        "mlp": _mlp_init(ks[1], cfg),
    }
    if cfg.mla:
        p["attn"] = L.mla_init(ks[0], cfg)
    else:
        p["attn"] = L.gqa_init(ks[0], cfg)
    if cfg.block_kind == "hybrid":
        p["mamba"] = SSM.mamba_init(ks[2], cfg)
        p["beta"] = jnp.ones((2,), jnp.float32)  # learned attn/ssm fusion
    return p


def _enc_block_init(key, cfg: ModelConfig):
    dtype = L.param_dtype(cfg)
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.gqa_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_init(key, cfg: ModelConfig):
    p = _block_init(key, cfg)
    p["xattn"] = L.xattn_init(jax.random.fold_in(key, 99), cfg)
    p["ln_x"] = L.rmsnorm_init(cfg.d_model, L.param_dtype(cfg))
    return p


def init_params(key, cfg: ModelConfig) -> PyTree:
    dtype = L.param_dtype(cfg)
    ks = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab_size
    params: Dict[str, Any] = {
        "embed": {"tok": L.dense_init(ks[0], (V, D), dtype, scale=0.02)},
        "final_norm": L.rmsnorm_init(D, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": L.dense_init(ks[1], (D, V), dtype)}
    if cfg.pos_kind == "learned":
        params["embed"]["pos"] = L.dense_init(
            ks[2], (cfg.max_position, D), dtype, scale=0.02
        )
    if cfg.frontend == "vision":
        params["embed"]["patch_proj"] = L.dense_init(ks[3], (D, D), dtype)
    if cfg.frontend == "audio":
        params["embed"]["frame_proj"] = L.dense_init(ks[3], (D, D), dtype)
        params["embed"]["enc_pos"] = L.dense_init(
            ks[4], (cfg.num_frames, D), dtype, scale=0.02
        )

    block_init = _dec_block_init if cfg.is_encdec else _block_init
    bkeys = jax.random.split(ks[5], cfg.num_layers)
    params["blocks"] = jax.vmap(lambda k: block_init(k, cfg))(bkeys)
    if cfg.is_encdec:
        ekeys = jax.random.split(ks[6], cfg.encoder_layers)
        params["enc_blocks"] = jax.vmap(lambda k: _enc_block_init(k, cfg))(ekeys)
        params["enc_norm"] = L.rmsnorm_init(D, dtype)
    return params


# ---------------------------------------------------------------------------
# block application (full sequence)
# ---------------------------------------------------------------------------


def _mlp_apply(p, cfg: ModelConfig, x):
    if cfg.moe:
        return MOE.moe_apply(p, cfg, x)
    return L.swiglu(p, x), jnp.zeros((), jnp.float32)


def _block_train(p, cfg: ModelConfig, x, state_l=None):
    """Returns (x, new_state_l, aux)."""
    if cfg.block_kind == "rwkv6":
        x, new_state = SSM.rwkv6_block(
            p["rwkv"], cfg, x, state_l, {"ln1": p["ln1"], "ln2": p["ln2"]}
        )
        return x, new_state, jnp.zeros((), jnp.float32)

    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        a = L.mla_train(p["attn"], cfg, h)
    else:
        a = L.gqa_train(p["attn"], cfg, h)
    if cfg.block_kind == "hybrid":
        m, new_ssm = SSM.mamba_prefill(p["mamba"], cfg, h, state_l)
        beta = jax.nn.softmax(p["beta"]).astype(a.dtype)
        a = beta[0] * a + beta[1] * m
    else:
        new_ssm = state_l
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    y, aux = _mlp_apply(p["mlp"], cfg, h)
    return x + y, new_ssm, aux


def _run_blocks_train(params, cfg: ModelConfig, x):
    """Scan all decoder-only blocks over the stacked layer axis."""
    B = x.shape[0]
    if cfg.block_kind == "rwkv6":
        init_state = SSM.rwkv_state_init(cfg, B, cfg.num_layers)
    elif cfg.block_kind == "hybrid":
        init_state = SSM.mamba_state_init(cfg, B, cfg.num_layers)
    else:
        init_state = None

    def body(carry, xs):
        h = carry
        if init_state is None:
            block_l = xs
            h, _, aux = _block_train(block_l, cfg, h, None)
        else:
            block_l, state_l = xs
            h, _, aux = _block_train(block_l, cfg, h, state_l)
        return h, aux

    if cfg.remat_blocks:
        body = jax.checkpoint(body)
    xs = params["blocks"] if init_state is None else (params["blocks"], init_state)
    x, auxs = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
    return x, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------


def _embed_tokens(params, cfg: ModelConfig, tokens, pos0: int = 0):
    x = params["embed"]["tok"][tokens]
    if cfg.pos_kind == "learned":
        T = tokens.shape[1]
        pos = jax.lax.dynamic_slice_in_dim(params["embed"]["pos"], pos0, T, 0)
        x = x + pos[None]
    return x


def _logits(params, cfg: ModelConfig, x):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return x @ params["embed"]["tok"].T
    return x @ params["lm_head"]["w"]


def _encode(params, cfg: ModelConfig, frames):
    """Whisper-style encoder over stubbed frame embeddings (B,F,D)."""
    x = frames @ params["embed"]["frame_proj"] + params["embed"]["enc_pos"][None]

    def body(h, block_l):
        a = L.gqa_train(
            block_l["attn"], cfg, L.rmsnorm(block_l["ln1"], h, cfg.norm_eps),
            bidirectional=True,
        )
        h = h + a
        y = L.gelu_mlp(block_l["mlp"], L.rmsnorm(block_l["ln2"], h, cfg.norm_eps))
        return h + y, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=cfg.scan_unroll)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _run_dec_blocks_train(params, cfg: ModelConfig, x, enc_out):
    def body(h, block_l):
        a = L.gqa_train(block_l["attn"], cfg, L.rmsnorm(block_l["ln1"], h, cfg.norm_eps))
        h = h + a
        c = L.xattn(block_l["xattn"], cfg, L.rmsnorm(block_l["ln_x"], h, cfg.norm_eps), enc_out)
        h = h + c
        y, _ = _mlp_apply(block_l["mlp"], cfg, L.rmsnorm(block_l["ln2"], h, cfg.norm_eps))
        return h + y, None

    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    return x


# ---------------------------------------------------------------------------
# public API: train / eval
# ---------------------------------------------------------------------------


def forward_logits(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence logits (B,S,V) over the *text* positions + aux loss."""
    tokens = batch["tokens"]
    aux = jnp.zeros((), jnp.float32)

    if cfg.is_encdec:
        enc_out = _encode(params, cfg, batch["frames"])
        x = _embed_tokens(params, cfg, tokens)
        x = _run_dec_blocks_train(params, cfg, x, enc_out)
        return _logits(params, cfg, x), aux

    x = _embed_tokens(params, cfg, tokens)
    n_prefix = 0
    if cfg.frontend == "vision":
        patches = batch["patches"] @ params["embed"]["patch_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        n_prefix = patches.shape[1]
    x, aux = _run_blocks_train(params, cfg, x)
    if n_prefix:
        x = x[:, n_prefix:]
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ router aux loss for MoE)."""
    logits, aux = forward_logits(params, cfg, batch)
    targets = batch["tokens"][:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    total = loss + cfg.router_aux_coef * aux
    return total, {"nll": loss, "aux": aux}


def pipeline_supported(cfg: ModelConfig) -> Optional[str]:
    """None if the pipelined training engine can stage-split this config,
    else the reason.  The stage boundary carries ONE activation tensor, so
    anything with extra cross-block state (SSM/hybrid recurrences, the
    encoder output of enc-dec, modality prefixes) or a cross-stage loss
    term (the MoE router aux, summed over *all* layers) is rejected loudly
    rather than trained wrong."""
    if cfg.block_kind != "attn":
        return f"block_kind={cfg.block_kind!r} carries state across blocks"
    if cfg.is_encdec:
        return "encoder-decoder needs the encoder output on every stage"
    if cfg.frontend is not None:
        return f"frontend={cfg.frontend!r} prefixes are not stage-split"
    if cfg.moe:
        return "MoE router aux loss is not accumulated across stages"
    return None


def pipeline_stage_fns(cfg: ModelConfig):
    """(embed, blocks, head) callables for
    :func:`repro.train.engine.train_population_pipelined` (its
    ``StageFns``).  ``blocks`` scans whatever slice of ``params["blocks"]``
    the engine hands it, so the same function serves every stage.  The
    composition ``head(blocks(embed(..)))`` equals :func:`loss_fn`'s nll
    for the supported (attn, non-MoE) families."""
    reason = pipeline_supported(cfg)
    if reason is not None:
        raise NotImplementedError(f"pipelined training: {reason}")

    def embed(params, batch):
        return _embed_tokens(params, cfg, batch["tokens"])

    def blocks(params, x):
        def body(h, block_l):
            h, _, _ = _block_train(block_l, cfg, h, None)
            return h, None

        if cfg.remat_blocks:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
        return x

    def head(params, x, batch):
        logits = _logits(params, cfg, x)
        targets = batch["tokens"][:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        return jnp.mean(-jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0])

    return embed, blocks, head


# ---------------------------------------------------------------------------
# public API: serving (prefill + one-token decode)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> PyTree:
    """Decode-state pytree.  ``capacity`` = logical context; sliding-window
    archs allocate only ``min(window, capacity)`` KV slots."""
    cache: Dict[str, Any] = {}
    Lc = cfg.num_layers
    if cfg.block_kind == "rwkv6":
        cache["state"] = SSM.rwkv_state_init(cfg, batch, Lc)
        return cache
    cap = capacity if cfg.window is None else min(cfg.window, capacity)
    if cfg.mla:
        cache["kv"] = L.mla_cache_init(cfg, batch, cap, Lc)
    else:
        cache["kv"] = L.gqa_cache_init(cfg, batch, cap, Lc)
    if cfg.block_kind == "hybrid":
        cache["ssm"] = SSM.mamba_state_init(cfg, batch, Lc)
    if cfg.is_encdec:
        hd = cfg.resolved_head_dim
        dt = L.param_dtype(cfg)
        cache["xk"] = jnp.zeros((Lc, batch, cfg.num_frames, cfg.num_kv_heads, hd), dt)
        cache["xv"] = jnp.zeros((Lc, batch, cfg.num_frames, cfg.num_kv_heads, hd), dt)
    return cache


def _block_decode(block_l, cfg: ModelConfig, x, cache_l, pos):
    """One-token decode for one (scanned) layer. Returns (x, new_cache_l)."""
    new_cache = dict(cache_l)
    if cfg.block_kind == "rwkv6":
        x, new_state = SSM.rwkv6_block(
            block_l["rwkv"], cfg, x, cache_l["state"],
            {"ln1": block_l["ln1"], "ln2": block_l["ln2"]},
        )
        new_cache["state"] = new_state
        return x, new_cache

    h = L.rmsnorm(block_l["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        a, new_cache["kv"] = L.mla_decode(block_l["attn"], cfg, h, cache_l["kv"], pos)
    else:
        a, new_cache["kv"] = L.gqa_decode(block_l["attn"], cfg, h, cache_l["kv"], pos)
    if cfg.block_kind == "hybrid":
        m, new_cache["ssm"] = SSM.mamba_decode(block_l["mamba"], cfg, h, cache_l["ssm"])
        beta = jax.nn.softmax(block_l["beta"]).astype(a.dtype)
        a = beta[0] * a + beta[1] * m
    x = x + a
    if cfg.is_encdec:
        hx = L.rmsnorm(block_l["ln_x"], x, cfg.norm_eps)
        B = x.shape[0]
        hd = cfg.resolved_head_dim
        q = (hx @ block_l["xattn"]["wq"]).reshape(B, 1, cfg.num_heads, hd)
        mask = jnp.ones((1, cache_l["xk"].shape[1]), bool)
        c = L.sdpa(q, cache_l["xk"], cache_l["xv"], mask, cfg.num_kv_heads)
        x = x + c.reshape(B, 1, -1) @ block_l["xattn"]["wo"]
    h = L.rmsnorm(block_l["ln2"], x, cfg.norm_eps)
    y, _ = _mlp_apply(block_l["mlp"], cfg, h)
    return x + y, new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache, pos):
    """serve_step: ONE new token (B,1) against the cache at position ``pos``.

    ``pos`` may be a Python int or a traced scalar: every cache update is a
    ``dynamic_update``/ring-slot op, so the serving engine can drive this
    from a ``lax.scan`` over token positions without shape specialization.
    """
    pos = jnp.asarray(pos, jnp.int32)
    x = _embed_tokens(params, cfg, tokens, pos0=pos) if cfg.pos_kind == "learned" else (
        params["embed"]["tok"][tokens]
    )

    def body(h, xs):
        block_l, cache_l = xs
        h, new_cache_l = _block_decode(block_l, cfg, h, cache_l, pos)
        return h, new_cache_l

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache), unroll=cfg.scan_unroll)
    return _logits(params, cfg, x), new_cache


def staged_decode_supported(cfg: ModelConfig) -> Optional[str]:
    """None if the stage-split (pipeline) serving path can serve this
    config, else the reason.

    Stage-split decode slices ``params["blocks"]`` (and the layer-leading
    KV cache) over a ``pipe`` mesh axis and moves the activation between
    stages with ``ppermute``.  That only composes cleanly for the plain
    attention families whose entire decode state is the layer-stacked KV
    ring: SSM/hybrid recurrent state and the encoder-decoder cross cache
    carry extra per-layer leaves the staged cache plumbing does not split,
    and modality prefixes (vision patches) make the prefill embedding
    stage-dependent.  All rejected loudly rather than served wrong."""
    if cfg.block_kind != "attn":
        return f"block_kind={cfg.block_kind!r} state is not stage-split"
    if cfg.is_encdec:
        return "encoder-decoder cross-attention cache is not stage-split"
    if cfg.frontend is not None:
        return f"frontend={cfg.frontend!r} prefixes are not stage-split"
    return None


def decode_embed(params, cfg: ModelConfig, tokens, pos):
    """The embedding half of :func:`decode_step` (staged serving runs it on
    every stage — embed params are pipe-replicated, so all stages agree)."""
    pos = jnp.asarray(pos, jnp.int32)
    if cfg.pos_kind == "learned":
        return _embed_tokens(params, cfg, tokens, pos0=pos)
    return params["embed"]["tok"][tokens]


def decode_blocks(blocks, cfg: ModelConfig, x, cache, pos):
    """One-token decode through a contiguous slice of blocks.

    ``blocks``/``cache`` hold ``cfg.num_layers`` layers — the staged
    serving engine passes its per-stage slice with a ``num_layers``-patched
    config.  Scanning a slice composes bitwise with scanning the full
    stack, which is what the staged-vs-unstaged parity contract rests on.
    Returns ``(x, new_cache)``."""
    pos = jnp.asarray(pos, jnp.int32)

    def body(h, xs):
        block_l, cache_l = xs
        h, new_cache_l = _block_decode(block_l, cfg, h, cache_l, pos)
        return h, new_cache_l

    return jax.lax.scan(body, x, (blocks, cache), unroll=cfg.scan_unroll)


def prefill_embed(params, cfg: ModelConfig, batch):
    """Prompt embedding for the staged prefill (attn-only families — the
    vision/audio prefixes are rejected by :func:`staged_decode_supported`)."""
    return _embed_tokens(params, cfg, batch["tokens"])


def prefill_blocks(blocks, cfg: ModelConfig, x, cache):
    """Full-prompt prefill through a contiguous slice of blocks.

    Per-layer ops are the exact sequence of :func:`prefill`'s scan body
    restricted to the attn families, so stage-slicing preserves bitwise
    parity with the single-scan prefill.  Returns ``(x, new_cache)``."""

    def body(h, xs):
        block_l, cache_l = xs
        new_cache_l = dict(cache_l)
        a_in = L.rmsnorm(block_l["ln1"], h, cfg.norm_eps)
        if cfg.mla:
            a, new_cache_l["kv"] = L.mla_prefill(
                block_l["attn"], cfg, a_in, cache_l["kv"])
        else:
            a, new_cache_l["kv"] = L.gqa_prefill(
                block_l["attn"], cfg, a_in, cache_l["kv"])
        h = h + a
        y, _ = _mlp_apply(block_l["mlp"], cfg,
                          L.rmsnorm(block_l["ln2"], h, cfg.norm_eps))
        return h + y, new_cache_l

    return jax.lax.scan(body, x, (blocks, cache), unroll=cfg.scan_unroll)


def lm_logits(params, cfg: ModelConfig, x):
    """Final-norm + LM head (public alias of the private ``_logits`` for
    the staged serving engine, which runs the head on the last stage)."""
    return _logits(params, cfg, x)


def paged_decode_supported(cfg: ModelConfig) -> Optional[str]:
    """None if ``decode_step_paged`` can serve this config, else the reason.

    The paged path covers the GQA decoder-only families (dense + MoE).
    MLA needs a latent-space pool, SSM/hybrid state is not paged, sliding
    windows interact with page retirement, and encoder-decoder / modality
    prefixes need prefix-page plumbing — all future work, all rejected
    loudly rather than served wrong."""
    if cfg.block_kind != "attn":
        return f"block_kind={cfg.block_kind!r} state is not paged"
    if cfg.mla:
        return "MLA latent cache has no paged layout yet"
    if cfg.is_encdec:
        return "encoder-decoder cross-attention cache is not paged"
    if cfg.frontend is not None:
        return f"frontend={cfg.frontend!r} prefixes are not paged"
    if cfg.window is not None:
        return "sliding-window ring eviction is not paged"
    return None


def decode_step_paged(params, cfg: ModelConfig, tokens, positions, pools,
                      page_tables, use_pallas: bool = False):
    """One decode token for a batch of serving *slots* over the paged pool.

      tokens      : (B,) int32 — one new token id per slot
      positions   : (B,) int32 — each token's absolute write position
                    (per-slot, unlike :func:`decode_step`'s shared scalar —
                    slots in a continuous batch sit at different depths)
      pools       : {"k","v"}: (L, P, page_size, KV, hd)
                    (:func:`repro.models.layers.paged_pools_init`)
      page_tables : (B, max_pages) int32 pool-page ids per slot

    Everything is traced — admissions, retirements, and page-table edits
    change VALUES only, so the continuous-batching runtime compiles this
    exactly once per pool geometry.  Returns ``(logits (B,1,V), pools)``.
    """
    reason = paged_decode_supported(cfg)
    if reason is not None:
        raise NotImplementedError(f"paged decode: {reason}")
    pos = jnp.asarray(positions, jnp.int32)
    x = params["embed"]["tok"][tokens[:, None]]
    if cfg.pos_kind == "learned":
        x = x + params["embed"]["pos"][pos][:, None]

    def body(h, xs):
        block_l, kp_l, vp_l = xs
        a_in = L.rmsnorm(block_l["ln1"], h, cfg.norm_eps)
        a, kp_l, vp_l = L.gqa_decode_paged(
            block_l["attn"], cfg, a_in, kp_l, vp_l, page_tables, pos,
            use_pallas,
        )
        h = h + a
        y, _ = _mlp_apply(block_l["mlp"], cfg,
                          L.rmsnorm(block_l["ln2"], h, cfg.norm_eps))
        return h + y, (kp_l, vp_l)

    x, (k_pool, v_pool) = jax.lax.scan(
        body, x, (params["blocks"], pools["k"], pools["v"]),
        unroll=cfg.scan_unroll,
    )
    return _logits(params, cfg, x), {"k": k_pool, "v": v_pool}


def paged_prefill_supported(cfg: ModelConfig) -> Optional[str]:
    """None if ``prefill_paged`` can serve this config, else the reason.

    Everything :func:`paged_decode_supported` rejects, plus non-naive
    attention: ``attn_impl="chunked"`` prefills through the online-softmax
    formulation whose numerics differ from the paged gather+sdpa attend,
    so suffix/chunk prefill could not keep the bitwise parity contract."""
    reason = paged_decode_supported(cfg)
    if reason is not None:
        return reason
    if cfg.attn_impl != "naive":
        return (f"attn_impl={cfg.attn_impl!r} prefill numerics are not "
                "bitwise-compatible with the paged gather+sdpa attend")
    return None


def prefill_paged(params, cfg: ModelConfig, tokens, pos0, pools, page_table):
    """Chunk/suffix prefill for ONE serving slot over the paged pool.

      tokens     : (T,) int32 — a contiguous slice of the prompt
      pos0       : int or traced scalar — absolute position of ``tokens[0]``
                   (traced by the serving runtime, so ONE compile per chunk
                   length serves every offset and every slot)
      pools      : {"k","v"}: (L, P, page_size, KV, hd)
      page_table : (max_pages,) int32 — the slot's pages in prompt order;
                   entries below ``pos0 // page_size`` may be chain-hash
                   shared prefix pages (read, never written)

    Earlier context — a deduped prefix and/or previously prefilled chunks —
    is read straight from the pool, so a suffix admission skips the cached
    prefix's FLOPs entirely.  Returns ``(logits (1,1,V) for the chunk's
    last position, pools)``; rows written/read are bitwise-identical to
    the whole-prompt :func:`prefill` (see ``gqa_prefill_paged``)."""
    reason = paged_prefill_supported(cfg)
    if reason is not None:
        raise NotImplementedError(f"paged prefill: {reason}")
    pos0 = jnp.asarray(pos0, jnp.int32)
    T = tokens.shape[0]
    positions = pos0 + jnp.arange(T, dtype=jnp.int32)
    x = _embed_tokens(params, cfg, tokens[None], pos0=pos0)

    def body(h, xs):
        block_l, kp_l, vp_l = xs
        a_in = L.rmsnorm(block_l["ln1"], h, cfg.norm_eps)
        a, kp_l, vp_l = L.gqa_prefill_paged(
            block_l["attn"], cfg, a_in, kp_l, vp_l, page_table, positions,
        )
        h = h + a
        y, _ = _mlp_apply(block_l["mlp"], cfg,
                          L.rmsnorm(block_l["ln2"], h, cfg.norm_eps))
        return h + y, (kp_l, vp_l)

    x, (k_pool, v_pool) = jax.lax.scan(
        body, x, (params["blocks"], pools["k"], pools["v"]),
        unroll=cfg.scan_unroll,
    )
    return _logits(params, cfg, x[:, -1:]), {"k": k_pool, "v": v_pool}


def decode_scan(params, cfg: ModelConfig, first, cache, start_pos, num_steps,
                next_fn, step_fn=None):
    """Fused multi-token decode: ONE ``lax.scan`` over token positions.

    Runs ``num_steps`` decode steps starting at absolute position
    ``start_pos`` (a Python int or traced scalar).  The cache threads
    through the scan carry, so the whole generation lowers to a single
    executable — the serving engine jits this once per shape instead of
    dispatching (and historically re-tracing) per token.

      first    : (B,) int32 — token ids fed to the first decode step
      next_fn  : (logits (B,1,V), step i) -> (B,) int32 next token ids
                 (sampling lives in the serving layer: greedy / temperature
                 with per-request keys / ensemble voting all plug in here)
      step_fn  : optional override of :func:`decode_step` with signature
                 (params, cache, tokens (B,1), pos) -> (logits, cache);
                 the serving engine's ensemble mode passes a vmapped
                 population step that averages member logits.

    Returns ``(tokens (B, num_steps) int32, final cache)``; ``tokens[:, i]``
    is the id sampled *after* the step at position ``start_pos + i``.
    """
    if step_fn is None:
        def step_fn(p, c, t, pos):  # noqa: E306
            return decode_step(p, cfg, t, c, pos)
    start_pos = jnp.asarray(start_pos, jnp.int32)

    def body(carry, i):
        nxt, c = carry
        logits, c = step_fn(params, c, nxt[:, None], start_pos + i)
        new = next_fn(logits, i)
        return (new, c), new

    (_, cache), toks = jax.lax.scan(
        body, (first, cache), jnp.arange(num_steps, dtype=jnp.int32)
    )
    return jnp.moveaxis(toks, 0, 1), cache


def prefill(params, cfg: ModelConfig, batch, capacity: Optional[int] = None):
    """Process the full prompt, returning (last-token logits, filled cache)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    cap = capacity or T
    cache = init_cache(cfg, B, cap)

    if cfg.block_kind == "rwkv6":
        x = _embed_tokens(params, cfg, tokens)

        def body(h, xs):
            block_l, state_l = xs
            h, new_state = SSM.rwkv6_block(
                block_l["rwkv"], cfg, h, state_l,
                {"ln1": block_l["ln1"], "ln2": block_l["ln2"]},
            )
            return h, new_state

        x, new_state = jax.lax.scan(body, x, (params["blocks"], cache["state"]), unroll=cfg.scan_unroll)
        return _logits(params, cfg, x[:, -1:]), {"state": new_state}

    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, batch["frames"])
        hd = cfg.resolved_head_dim
        S = enc_out.shape[1]

    x = _embed_tokens(params, cfg, tokens)
    n_prefix = 0
    if cfg.frontend == "vision":
        patches = batch["patches"] @ params["embed"]["patch_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        n_prefix = patches.shape[1]

    def body(h, xs):
        block_l, cache_l = xs
        new_cache_l = dict(cache_l)
        a_in = L.rmsnorm(block_l["ln1"], h, cfg.norm_eps)
        if cfg.mla:
            a, new_cache_l["kv"] = L.mla_prefill(block_l["attn"], cfg, a_in, cache_l["kv"])
        else:
            a, new_cache_l["kv"] = L.gqa_prefill(block_l["attn"], cfg, a_in, cache_l["kv"])
        if cfg.block_kind == "hybrid":
            m, new_cache_l["ssm"] = SSM.mamba_prefill(block_l["mamba"], cfg, a_in, cache_l["ssm"])
            beta = jax.nn.softmax(block_l["beta"]).astype(a.dtype)
            a = beta[0] * a + beta[1] * m
        h = h + a
        if cfg.is_encdec:
            hx = L.rmsnorm(block_l["ln_x"], h, cfg.norm_eps)
            c = L.xattn(block_l["xattn"], cfg, hx, enc_out)
            h = h + c
            B_, = (h.shape[0],)
            new_cache_l["xk"] = (enc_out @ block_l["xattn"]["wk"]).reshape(
                B_, S, cfg.num_kv_heads, hd
            ).astype(cache_l["xk"].dtype)
            new_cache_l["xv"] = (enc_out @ block_l["xattn"]["wv"]).reshape(
                B_, S, cfg.num_kv_heads, hd
            ).astype(cache_l["xv"].dtype)
        y, _ = _mlp_apply(block_l["mlp"], cfg, L.rmsnorm(block_l["ln2"], h, cfg.norm_eps))
        return h + y, new_cache_l

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache), unroll=cfg.scan_unroll)
    return _logits(params, cfg, x[:, -1:]), new_cache
