"""Model zoo: scanned transformer families + CNN/MLP classifiers."""

from repro.models.transformer import (
    decode_step,
    forward_logits,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.cnn import ClassifierConfig, apply_classifier, init_classifier

__all__ = [
    "init_params",
    "loss_fn",
    "forward_logits",
    "init_cache",
    "prefill",
    "decode_step",
    "ClassifierConfig",
    "init_classifier",
    "apply_classifier",
]
