"""State-space sequence mixers: selective-SSM (Mamba-style, for the Hymba
hybrid) and RWKV-6 'Finch' (data-dependent decay linear attention).

Both expose a full-sequence form (lax.scan over time) for training /
prefill and an O(1)-state single-token form for decode — this is what makes
the ``long_500k`` shape tractable for the ssm/hybrid architectures.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, param_dtype, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (arXiv:2312.00752, simplified; used by Hymba)
# ---------------------------------------------------------------------------


def dt_rank(cfg: ModelConfig) -> int:
    return max(cfg.d_model // 16, 1)


def mamba_init(key, cfg: ModelConfig):
    dtype = param_dtype(cfg)
    D, DI, S = cfg.d_model, cfg.d_inner, cfg.ssm_state
    R = dt_rank(cfg)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * DI), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, DI), dtype, scale=0.5),
        "conv_b": jnp.zeros((DI,), dtype),
        "x_proj": dense_init(ks[2], (DI, R + 2 * S), dtype),
        "dt_proj": dense_init(ks[3], (R, DI), dtype),
        "dt_bias": jnp.full((DI,), -2.0, dtype),  # softplus ~= 0.12
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, S + 1, dtype=jnp.float32), (DI, S))
        ).astype(jnp.float32),
        "D": jnp.ones((DI,), jnp.float32),
        "out_proj": dense_init(ks[4], (DI, D), dtype),
    }


def _mamba_core(p, cfg, u, h0):
    """u: (B, T, DI) post-conv activations; h0: (B, DI, S) initial state."""
    S = cfg.ssm_state
    R = dt_rank(cfg)
    proj = u @ p["x_proj"]  # (B,T,R+2S)
    dt_in, Bmat, Cmat = jnp.split(proj, [R, R + S], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])  # (DI, S)

    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp  # (B,DI) (B,DI) (B,S) (B,S)
        dA = jnp.exp(dt_t[..., None] * A[None])  # (B,DI,S)
        dBu = dt_t[..., None] * B_t[:, None, :].astype(jnp.float32) * u_t[..., None].astype(jnp.float32)
        h = dA * h + dBu
        y = jnp.einsum("bds,bs->bd", h, C_t.astype(jnp.float32))
        return h, y

    xs = (
        jnp.moveaxis(u, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bmat, 1, 0),
        jnp.moveaxis(Cmat, 1, 0),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,T,DI)
    return (y + p["D"][None, None] * u.astype(jnp.float32)).astype(u.dtype), h


def mamba_train(p, cfg: ModelConfig, x):
    out, _, _ = mamba_prefill(p, cfg, x, mamba_state_init(cfg, x.shape[0], 1))
    return out


def mamba_state_init(cfg: ModelConfig, batch: int, num_layers: int):
    DI, S = cfg.d_inner, cfg.ssm_state
    return {
        "h": jnp.zeros((num_layers, batch, DI, S), jnp.float32),
        "conv": jnp.zeros((num_layers, batch, cfg.ssm_conv - 1, DI), param_dtype(cfg)),
    }


def _causal_depthwise_conv(p, cfg, xz, prev):
    """xz: (B,T,DI); prev: (B, k-1, DI) left context. Returns (out, new_prev)."""
    k = cfg.ssm_conv
    padded = jnp.concatenate([prev.astype(xz.dtype), xz], axis=1)  # (B,T+k-1,DI)
    T = xz.shape[1]
    out = jnp.zeros_like(xz)
    for i in range(k):
        out = out + padded[:, i : i + T] * p["conv_w"][i][None, None]
    new_prev = padded[:, -(k - 1) :] if k > 1 else prev
    return out + p["conv_b"][None, None], new_prev


def mamba_prefill(p, cfg: ModelConfig, x, state_l):
    """x: (B,T,D) -> (out, new_state). state_l: per-layer slice."""
    DI = cfg.d_inner
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, [DI], axis=-1)
    u, conv_prev = _causal_depthwise_conv(p, cfg, xs, state_l["conv"])
    u = jax.nn.silu(u)
    y, h = _mamba_core(p, cfg, u, state_l["h"])
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"h": h, "conv": conv_prev}


def mamba_decode(p, cfg: ModelConfig, x, state_l):
    """x: (B,1,D) single-token decode with O(1) state."""
    DI = cfg.d_inner
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, [DI], axis=-1)
    hist = jnp.concatenate([state_l["conv"].astype(xs.dtype), xs], axis=1)  # (B,k,DI)
    u = jnp.einsum("bkd,kd->bd", hist, p["conv_w"]) + p["conv_b"]
    u = jax.nn.silu(u)[:, None]  # (B,1,DI)
    y, h = _mamba_core(p, cfg, u, state_l["h"])
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"h": h, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# RWKV-6 "Finch" (arXiv:2404.05892) — data-dependent decay linear attention
# ---------------------------------------------------------------------------


def rwkv_heads(cfg: ModelConfig) -> int:
    assert cfg.d_model % cfg.rwkv_head_dim == 0
    return cfg.d_model // cfg.rwkv_head_dim


def rwkv6_init(key, cfg: ModelConfig):
    dtype = param_dtype(cfg)
    D = cfg.d_model
    F = cfg.d_ff
    H, hd = rwkv_heads(cfg), cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    lora = 32
    return {
        "tm": {  # time mix
            "mu": 0.5 * jnp.ones((5, D), dtype),  # static token-shift mix r,k,v,w,g
            "w0": jnp.zeros((D,), jnp.float32),  # decay base
            "w_lora_a": dense_init(ks[0], (D, lora), dtype),
            "w_lora_b": dense_init(ks[1], (lora, D), dtype, scale=0.01),
            "wr": dense_init(ks[2], (D, D), dtype),
            "wk": dense_init(ks[3], (D, D), dtype),
            "wv": dense_init(ks[4], (D, D), dtype),
            "wg": dense_init(ks[5], (D, D), dtype),
            "wo": dense_init(ks[6], (D, D), dtype),
            "u": jnp.zeros((H, hd), jnp.float32),  # per-head bonus
            "ln": rmsnorm_init(D, dtype),
        },
        "cm": {  # channel mix
            "mu": 0.5 * jnp.ones((2, D), dtype),  # k, r shifts
            "wk": dense_init(ks[7], (D, F), dtype),
            "wv": dense_init(ks[8], (F, D), dtype),
            "wr": dense_init(ks[9], (D, D), dtype),
        },
    }


def rwkv_state_init(cfg: ModelConfig, batch: int, num_layers: int):
    H, hd = rwkv_heads(cfg), cfg.rwkv_head_dim
    D = cfg.d_model
    dtype = param_dtype(cfg)
    return {
        "S": jnp.zeros((num_layers, batch, H, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((num_layers, batch, D), dtype),
        "x_cm": jnp.zeros((num_layers, batch, D), dtype),
    }


def _token_shift(x, prev):
    """x: (B,T,D), prev: (B,D) -> x shifted right by one with prev injected."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _rwkv_time_mix(p, cfg, x, S0, x_prev):
    B, T, D = x.shape
    H, hd = rwkv_heads(cfg), cfg.rwkv_head_dim
    xs = _token_shift(x, x_prev)
    mu = p["mu"]
    xr = x + (xs - x) * mu[0][None, None]
    xk = x + (xs - x) * mu[1][None, None]
    xv = x + (xs - x) * mu[2][None, None]
    xw = x + (xs - x) * mu[3][None, None]
    xg = x + (xs - x) * mu[4][None, None]

    r = (xr @ p["wr"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, T, H, hd).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (the RWKV6 signature): w in (0,1) per channel/step
    w_dd = p["w0"][None, None] + (jax.nn.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_dd)).reshape(B, T, H, hd)
    u = p["u"]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # each (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    xs_scan = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    S, ys = jax.lax.scan(step, S0, xs_scan)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, D).astype(x.dtype)
    y = rmsnorm(p["ln"], y, cfg.norm_eps) * g
    return y @ p["wo"], S, x[:, -1]


def _rwkv_channel_mix(p, x, x_prev):
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * p["mu"][0][None, None]
    xr = x + (xs - x) * p["mu"][1][None, None]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1]


def rwkv6_block(p, cfg: ModelConfig, x, state_l, norms):
    """Full RWKV block: time-mix + channel-mix with pre-norms.

    ``norms``: dict with ln1/ln2 rmsnorm params (owned by the block).
    Returns (x_out, new_state_l).
    """
    h = rmsnorm(norms["ln1"], x, cfg.norm_eps)
    y, S, x_tm = _rwkv_time_mix(p["tm"], cfg, h, state_l["S"], state_l["x_tm"])
    x = x + y
    h = rmsnorm(norms["ln2"], x, cfg.norm_eps)
    y, x_cm = _rwkv_channel_mix(p["cm"], h, state_l["x_cm"])
    x = x + y
    return x, {"S": S, "x_tm": x_tm, "x_cm": x_cm}
