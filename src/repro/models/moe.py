"""Mixture-of-Experts layer: top-k router + capacity-based sort dispatch.

The dispatch is the GShard/MaxText-style static-capacity formulation:
tokens are sorted by expert id, each expert processes a fixed-capacity
buffer, and overflow tokens fall back to the residual path.  Compute
scales with *active* experts (top_k), so the roofline FLOPs match
6·N_active·D accounting.

Dispatch operates on G token *groups* (a (G, E, C, D) buffer):

  ``cfg.moe_impl == "global"``  — one group over all B·T tokens (baseline;
      under pjit the scatter into the expert-sharded buffer crosses the
      data axis and lowers to giant all-reduces);
  ``cfg.moe_impl == "grouped"`` — one group per batch row: buffers stay
      data-local and the expert exchange lowers to all-to-all (§Perf).

``repro.sharding.hints.constrain`` pins the buffer to P(data, model, ·, ·)
when the launcher activates hints, making the expert einsum fully
expert-parallel instead of model-axis-replicated.

Shared experts (DeepSeek-V2 / Kimi-K2 style) run densely on every token.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, param_dtype, swiglu, swiglu_init
from repro.sharding.hints import constrain


def moe_init(key, cfg: ModelConfig):
    dtype = param_dtype(cfg)
    E, D, F = cfg.n_routed_experts, cfg.d_model, cfg.resolved_moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "experts": {
            "w1": dense_init(ks[1], (E, D, F), dtype),
            "w3": dense_init(ks[2], (E, D, F), dtype),
            "w2": dense_init(ks[3], (E, F, D), dtype),
        },
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = swiglu_init(ks[4], D, F * cfg.n_shared_experts, dtype)
    return p


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_routed_experts)
    return max(8, -(-c // 8) * 8)  # >=8, rounded up to a multiple of 8


def moe_apply(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (out, aux_loss). Residual is added by the caller."""
    B, T, D = x.shape
    if cfg.moe_impl == "grouped" and B > 1:
        xg = x
    else:
        xg = x.reshape(1, B * T, D)
    out, aux = _dispatch_grouped(p, cfg, xg)
    out = out.reshape(B, T, D)
    if cfg.n_shared_experts > 0:
        out = out + swiglu(p["shared"], x.reshape(B * T, D)).reshape(B, T, D)
    return out, aux


def _dispatch_grouped(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x: (G, Tg, D) token groups -> (out (G, Tg, D), aux)."""
    G, Tg, D = x.shape
    E, K = cfg.n_routed_experts, cfg.top_k
    C = capacity(cfg, Tg)
    TK = Tg * K
    g_idx = jnp.arange(G)[:, None]

    logits = x.astype(jnp.float32) @ p["router"]  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)  # (G, Tg, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # Switch-style load-balance auxiliary loss (over all tokens).
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[top_i[..., 0].reshape(-1)].add(
        1.0 / (G * Tg)
    )
    aux = E * jnp.sum(me * ce)

    # flatten (token, slot) assignments and sort by expert id, per group
    flat_e = top_i.reshape(G, TK)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), K)[None], (G, TK)
    )
    flat_w = top_w.reshape(G, TK)
    order = jnp.argsort(flat_e, axis=1)  # stable
    se = jnp.take_along_axis(flat_e, order, 1)
    st = jnp.take_along_axis(flat_t, order, 1)
    sw = jnp.take_along_axis(flat_w, order, 1)

    counts = jnp.zeros((G, E), jnp.int32).at[g_idx, se].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((G, 1), jnp.int32), jnp.cumsum(counts, axis=1)[:, :-1]], axis=1
    )
    pos_in_e = jnp.arange(TK)[None] - jnp.take_along_axis(starts, se, 1)
    keep = pos_in_e < C
    slot = se * C + jnp.where(keep, pos_in_e, 0)

    xt = jnp.take_along_axis(x, st[..., None], 1)  # (G, TK, D)
    buf = jnp.zeros((G, E * C, D), x.dtype).at[g_idx, slot].add(
        jnp.where(keep[..., None], xt, 0).astype(x.dtype)
    )
    # two-stage reshard: build data-local (the dispatch scatter never
    # crosses devices; model-axis replicas build redundant copies, which is
    # cheap), then slice experts onto the model axis so the expert einsum
    # is fully expert-parallel.  (A G==chips all-to-all variant was tried
    # and regressed — see EXPERIMENTS.md §Perf iteration 6.)
    buf = constrain(buf.reshape(G, E, C, D), "moe_buffer_local")
    buf = constrain(buf, "moe_buffer")

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["experts"]["w1"])) * jnp.einsum(
        "gecd,edf->gecf", buf, p["experts"]["w3"]
    )
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["experts"]["w2"])
    out_buf = constrain(constrain(out_buf, "moe_buffer"), "moe_buffer_local")
    out_buf = out_buf.reshape(G, E * C, D)

    contrib = jnp.take_along_axis(out_buf, slot[..., None], 1)
    contrib = contrib * (sw * keep)[..., None].astype(x.dtype)
    out = jnp.zeros((G, Tg, D), x.dtype).at[g_idx, st].add(contrib)
    return out, aux.astype(jnp.float32)
