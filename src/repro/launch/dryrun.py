import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) and emit
roofline terms.  The two lines above MUST stay first: jax locks the device
count on first init, and only the dry-run wants 512 placeholder devices.

Cost accounting: XLA's cost_analysis counts a rolled scan body ONCE, so a
full-depth rolled compile under-reports FLOPs/bytes by ~num_layers.  Each
pair therefore compiles three artifacts:

  1. full depth, rolled  — the PROOF that the production graph lowers,
     partitions and fits (memory_analysis comes from this one);
  2. depth-1 and depth-2, fully unrolled — their difference is exactly one
     layer's per-device FLOPs/bytes/collectives, so
         total(L) = cost(d1) + (L-1) · (cost(d2) - cost(d1))
     is exact for homogeneous stacks (validated against a full unroll in
     EXPERIMENTS.md §Dry-run).

Pass --full-unroll to skip extrapolation and unroll all layers (slow; used
for the validation run and the WASH population step, whose shuffle traffic
is depth-dependent through the Eq. 6 schedule).

Usage:
  python -m repro.launch.dryrun --arch minitron-8b --shape train_4k
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --wash 2 --multi-pod --full-unroll
  python -m repro.launch.dryrun --all [--multi-pod] --out-dir benchmarks/dryrun
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, INPUT_SHAPES_BY_NAME, get_arch
from repro.configs.base import InputShape, ModelConfig
from repro.core.layer_index import infer_layer_ids, total_layers
from repro.core.mixing import MixingConfig, mix_once
from repro.launch import hlo_stats
from repro.launch.mesh import make_ensemble_mesh, make_production_mesh
from repro.launch.specs import input_specs
from repro.models import transformer as M
from repro.optim import make_optimizer
from repro.sharding import rules
from jax.sharding import NamedSharding, PartitionSpec as P

# dense archs get an explicit sliding-window variant for long_500k
SWA_WINDOW = 8192
LONG_OK_FAMILIES = ("ssm", "hybrid")
LONG_SKIP = {
    "whisper-medium": "enc-dec full-attention decoder; 500k decode out of family",
    "deepseek-v2-lite-16b": "MLA latent cache is full attention over 500k (no SWA claim)",
    "kimi-k2-1t-a32b": "full-attention MoE; no sub-quadratic variant claimed",
    "internvl2-76b": "full-attention VLM backbone; long-context not in scope",
}

_EXTRAP_KEYS = (
    "hlo_flops", "hlo_bytes", "collective_bytes", "global_flops",
    "bytes_all-gather", "bytes_all-reduce", "bytes_reduce-scatter",
    "bytes_all-to-all", "bytes_collective-permute", "bytes_crosspod",
    "compute_s", "memory_s", "collective_s",
)


def variant_for(cfg: ModelConfig, shape: InputShape):
    """Returns (cfg, note) or (None, skip_reason)."""
    if shape.name != "long_500k":
        return cfg, ""
    if cfg.name in LONG_SKIP:
        return None, LONG_SKIP[cfg.name]
    if cfg.family in LONG_OK_FAMILIES:
        return cfg, "sub-quadratic native (SSM state / SWA)"
    return dataclasses.replace(cfg, window=SWA_WINDOW), f"SWA variant (window={SWA_WINDOW})"


def depth_variant(cfg: ModelConfig, d: int) -> ModelConfig:
    return dataclasses.replace(
        cfg,
        num_layers=d,
        encoder_layers=d if cfg.is_encdec else 0,
        scan_unroll=d,
    )


def params_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))


def opt_shapes(params_sds, optimizer: str):
    init, _ = make_optimizer(optimizer)
    return jax.eval_shape(init, params_sds)


def _count(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def active_params(cfg: ModelConfig, params_sds) -> int:
    """N_active for the 6·N·D rule: routed experts count top_k/E."""
    total = _count(params_sds)
    if not cfg.moe:
        return total
    flat, _ = jax.tree_util.tree_flatten_with_path(params_sds)
    routed = sum(
        int(l.size)
        for p, l in flat
        if any(hasattr(q, "key") and str(q.key) == "experts" for q in p)
    )
    return total - routed + routed * cfg.top_k // max(cfg.n_routed_experts, 1)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, optimizer: str = "adamw"):
    _, opt_update = make_optimizer(optimizer)

    def train_step(params, opt_state, batch):
        def lf(p):
            loss, _ = M.loss_fn(p, cfg, batch)
            return loss

        loss, grads = jax.value_and_grad(lf)(params)
        params, opt_state = opt_update(params, grads, opt_state, 3e-4)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ModelConfig, capacity: int):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch, capacity=capacity)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, cache, pos):
        return M.decode_step(params, cfg, tokens, cache, pos)

    return serve_step


def make_wash_step(cfg: ModelConfig, n: int, mcfg: MixingConfig, optimizer: str = "adamw",
                   mix_fn=None):
    """Population train step: vmapped member update + bucketed WASH shuffle.

    The stacked ens axis is sharded over the mesh's ens axis; the bucketed
    shuffle's jnp.roll over that axis lowers to collective-permute — the
    paper's peer-to-peer exchange, measurable in the HLO.
    """
    _, opt_update = make_optimizer(optimizer)
    params_sds = params_shapes(cfg)
    lids = infer_layer_ids(params_sds, cfg.num_layers)
    tl = total_layers(cfg.num_layers)

    def wash_step(pop, pop_opt, batch, key):
        def one(p, s, b):
            def lf(pp):
                loss, _ = M.loss_fn(pp, cfg, b)
                return loss

            loss, g = jax.value_and_grad(lf)(p)
            p2, s2 = opt_update(p, g, s, 3e-4)
            return p2, s2, loss

        pop, pop_opt, losses = jax.vmap(one)(pop, pop_opt, batch)
        if mix_fn is not None:
            pop, pop_opt, comm = mix_fn(pop, pop_opt, key)
        else:
            pop, pop_opt, comm = mix_once(key, pop, pop_opt, mcfg, lids, tl)
        return pop, pop_opt, jnp.mean(losses), comm

    return wash_step


def make_shardlocal_mixer(cfg: ModelConfig, mcfg: MixingConfig, mesh,
                          pop_specs, opt_specs):
    """§Perf: shard-local WASH shuffle under shard_map.

    The stacked-bucketed shuffle gathers globally-indexed coordinates,
    which breaks the parameter sharding and makes XLA replicate the
    selected payload over each member's chips before the ens-axis permute
    (measured: 0.18 GB/chip instead of ~0.7 MB/chip).  Thin delegator to
    the real subsystem, :func:`repro.core.shardplan.make_shardlocal_mixer`
    (which also fixed this prototype's bugs: plan keys now fold the *per
    leaf* shard position so replicas of an unsharded leaf stay consistent,
    and the comm count is the exact host-side total instead of a per-chip
    psum that double-counted data replicas).  Model-config adaptation is
    the only logic left here.
    """
    from repro.core.shardplan import make_shardlocal_mixer as _mk

    return _mk(mesh, mcfg, cfg.num_layers, pop_specs, opt_specs)


# ---------------------------------------------------------------------------
# single compile
# ---------------------------------------------------------------------------


def compile_once(cfg: ModelConfig, shape: InputShape, mesh, wash: int = 0,
                 mixing_kind: str = "wash"):
    """Lower + compile one step; return (stats_dict, memory_dict)."""
    import contextlib
    from repro.launch.mesh import data_axes
    from repro.sharding import hints

    chips = mesh.size
    params_sds = params_shapes(cfg)
    pspecs = rules.param_pspecs(params_sds, cfg, mesh)

    if cfg.shard_hints:
        # with_sharding_constraint(P(...)) needs an ambient mesh
        from repro.core.compat import use_mesh
        with use_mesh(mesh), hints.use_hints(data_axes(mesh), "model"):
            return _compile_inner(cfg, shape, mesh, wash, mixing_kind, chips,
                                  params_sds, pspecs)
    with contextlib.nullcontext():
        return _compile_inner(cfg, shape, mesh, wash, mixing_kind, chips,
                              params_sds, pspecs)


def _compile_inner(cfg, shape, mesh, wash, mixing_kind, chips, params_sds, pspecs):
    t0 = time.time()
    if shape.kind == "train" and not wash:
        step = make_train_step(cfg)
        opt_sds = opt_shapes(params_sds, "adamw")
        opt_specs = {"mu": pspecs, "nu": pspecs, "step": P()}
        bspecs = rules.batch_pspecs(cfg, mesh, shape.global_batch)
        specs = input_specs(cfg, shape)
        lowered = jax.jit(
            step,
            in_shardings=(
                rules.named(pspecs, mesh),
                rules.named(opt_specs, mesh),
                rules.named(bspecs, mesh),
            ),
            donate_argnums=(0, 1),
        ).lower(params_sds, opt_sds, specs)

    elif shape.kind == "train" and wash:
        local = mixing_kind.endswith("_local")
        base_kind = mixing_kind[:-6] if local else mixing_kind
        mcfg = MixingConfig(kind=base_kind, base_p=0.05, mode="bucketed")
        stack = lambda t: jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct((wash,) + x.shape, x.dtype), t
        )
        pop_sds = stack(params_sds)
        opt_sds = stack(opt_shapes(params_sds, "adamw"))
        add_ens = lambda tree: jax.tree_util.tree_map(
            lambda s: P(*(("ens",) + tuple(s))), tree,
            is_leaf=lambda x: isinstance(x, P),
        )
        pop_specs = add_ens(pspecs)
        opt_specs = {"mu": pop_specs, "nu": pop_specs, "step": P("ens")}
        mix_fn = (
            make_shardlocal_mixer(cfg, mcfg, mesh, pop_specs, opt_specs)
            if local else None
        )
        step = make_wash_step(cfg, wash, mcfg, mix_fn=mix_fn)
        per_member = shape.global_batch // wash
        batch_sds = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct((wash, per_member) + x.shape[1:], x.dtype),
            input_specs(cfg, dataclasses.replace(shape, global_batch=per_member)),
        )
        bspecs = add_ens(rules.batch_pspecs(cfg, mesh, per_member))
        key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
        lowered = jax.jit(
            step,
            in_shardings=(
                rules.named(pop_specs, mesh),
                rules.named(opt_specs, mesh),
                rules.named(bspecs, mesh),
                NamedSharding(mesh, P(None)),
            ),
            donate_argnums=(0, 1),
        ).lower(pop_sds, opt_sds, batch_sds, key_sds)

    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, shape.seq_len)
        bspecs = rules.batch_pspecs(cfg, mesh, shape.global_batch)
        specs = input_specs(cfg, shape)
        lowered = jax.jit(
            step, in_shardings=(rules.named(pspecs, mesh), rules.named(bspecs, mesh))
        ).lower(params_sds, specs)

    else:  # decode
        step = make_serve_step(cfg)
        specs = input_specs(cfg, shape)
        cache_specs = rules.cache_pspecs(specs["cache"], cfg, mesh, shape.global_batch)
        dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        nd = 1
        for a in dax:
            nd *= mesh.shape[a]
        tok_spec = (
            P(dax, None)
            if dax and shape.global_batch % max(nd, 1) == 0
            else P(None, None)
        )
        lowered = jax.jit(
            step,
            in_shardings=(
                rules.named(pspecs, mesh),
                NamedSharding(mesh, tok_spec),
                rules.named(cache_specs, mesh),
                NamedSharding(mesh, P()),
            ),
            donate_argnums=(2,),
        ).lower(params_sds, specs["tokens"], specs["cache"], specs["pos"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    # pod boundary: first mesh axis of the 512-chip meshes is pod/ens
    boundary = chips // 2 if chips == 512 else 0
    stats = hlo_stats.summarize(cost, compiled.as_text(), chips, boundary)
    stats["t_lower_s"] = round(t_lower, 2)
    stats["t_compile_s"] = round(t_compile, 2)

    mem = compiled.memory_analysis()

    def _mem(name):
        try:
            return int(getattr(mem, name, 0) or 0)
        except Exception:
            return 0

    memory = {
        "argument_size": _mem("argument_size_in_bytes"),
        "output_size": _mem("output_size_in_bytes"),
        "temp_size": _mem("temp_size_in_bytes"),
        "generated_code_size": _mem("generated_code_size_in_bytes"),
    }
    return stats, memory


# ---------------------------------------------------------------------------
# planner-only pipeline accounting (no devices, no compile)
# ---------------------------------------------------------------------------


def pipeline_report(arch_id: str, population: int, stages: int,
                    mixing_kind: str = "wash") -> dict:
    """Per-stage WASH comm for ``arch`` on an (ens, data, pipe) mesh.

    Runs the :mod:`repro.core.shardplan` planner on a *fake* mesh object —
    axis names + sizes are all it reads — so full-scale stage budgets
    (kimi 61 layers, internvl 80) come out of a laptop process with no
    devices and no compile.  Asserts the refactor's accounting contract:
    the per-stage volumes sum exactly to the pipeline plan's global, which
    never exceeds the single-stage plan's.
    """
    from types import SimpleNamespace

    from repro.core import shardplan

    cfg = get_arch(arch_id)
    params_sds = params_shapes(cfg)
    lids = infer_layer_ids(params_sds, cfg.num_layers)
    tl = total_layers(cfg.num_layers)
    member_specs = jax.tree_util.tree_map(lambda _: P(), params_sds)
    mcfg = MixingConfig(kind=mixing_kind, base_p=0.05, mode="bucketed")

    mesh = SimpleNamespace(
        axis_names=("ens", "data", "pipe"),
        shape={"ens": population, "data": 1, "pipe": stages},
    )
    staged_specs = rules.stage_member_specs(member_specs, lids, "pipe")
    pplan = shardplan.plan_population_mixing(
        mesh, params_sds, staged_specs, mcfg, lids, tl, population
    )
    per_stage = [
        shardplan.static_stage_mix_comm(pplan, s) for s in range(stages)
    ]
    total = shardplan.static_shard_mix_comm(pplan)

    mesh1 = SimpleNamespace(
        axis_names=("ens", "data"), shape={"ens": population, "data": 1}
    )
    plan1 = shardplan.plan_population_mixing(
        mesh1, params_sds, member_specs, mcfg, lids, tl, population
    )
    single = shardplan.static_shard_mix_comm(plan1)

    assert sum(per_stage) == total, (per_stage, total)
    assert total <= single + 1e-6, (total, single)
    return {
        "arch": arch_id,
        "population": population,
        "stages": stages,
        "mixing": mixing_kind,
        "num_layers": cfg.num_layers,
        "per_stage_scalars": per_stage,
        "total_scalars": total,
        "single_stage_scalars": single,
    }


# ---------------------------------------------------------------------------
# per-pair orchestration
# ---------------------------------------------------------------------------


def lower_pair(arch_id: str, shape_name: str, multi_pod: bool, wash: int = 0,
               mixing_kind: str = "wash", full_unroll: bool = False,
               overrides: dict = None):
    """``overrides``: §Perf hillclimb knobs applied on top of the baseline
    config (e.g. {"attn_impl": "chunked", "remat_blocks": True})."""
    shape = INPUT_SHAPES_BY_NAME[shape_name]
    cfg0 = get_arch(arch_id)
    cfg, note = variant_for(cfg0, shape)
    if cfg is None:
        return {"arch": arch_id, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skip", "note": note}
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh = (
        make_ensemble_mesh(wash, multi_pod=multi_pod)
        if wash
        else make_production_mesh(multi_pod=multi_pod)
    )
    chips = mesh.size
    params_sds = params_shapes(cfg)
    n_params = _count(params_sds)
    n_active = active_params(cfg, params_sds)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)

    if full_unroll:
        full_cfg = dataclasses.replace(cfg, scan_unroll=cfg.num_layers)
        stats, memory = compile_once(full_cfg, shape, mesh, wash, mixing_kind)
        anchors = {"mode": "full_unroll"}
    else:
        # proof compile: full depth, rolled
        stats_full, memory = compile_once(cfg, shape, mesh, wash, mixing_kind)
        # cost anchors: depth-1 / depth-2, unrolled
        s1, _ = compile_once(depth_variant(cfg, 1), shape, mesh, wash, mixing_kind)
        s2, _ = compile_once(depth_variant(cfg, 2), shape, mesh, wash, mixing_kind)
        L = cfg.num_layers
        stats = dict(stats_full)
        for k in _EXTRAP_KEYS:
            v1, v2 = float(s1.get(k, 0.0)), float(s2.get(k, 0.0))
            stats[k] = max(v1 + (L - 1) * (v2 - v1), 0.0)
        # recompute the time terms from the extrapolated primitives so the
        # three terms stay consistent with the byte/flop fields
        coll = sum(stats.get(f"bytes_{c}", 0.0) for c in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"))
        stats["collective_bytes"] = coll
        stats["compute_s"] = stats["hlo_flops"] / hlo_stats.PEAK_FLOPS
        stats["memory_s"] = stats["hlo_bytes"] / hlo_stats.HBM_BW
        stats["collective_s"] = coll / hlo_stats.ICI_BW
        stats["dominant"] = hlo_stats.dominant_term(stats)
        anchors = {
            "mode": "extrapolated",
            "rolled_full": {k: stats_full.get(k) for k in _EXTRAP_KEYS},
            "depth1": {k: s1.get(k) for k in _EXTRAP_KEYS},
            "depth2": {k: s2.get(k) for k in _EXTRAP_KEYS},
            "t_compile_anchors_s": [s1["t_compile_s"], s2["t_compile_s"]],
        }

    mf = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
    if wash:
        mf *= 1.0  # population step processes the same global token count

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": list(mesh.shape.values()),
        "axes": list(mesh.axis_names),
        "multi_pod": multi_pod,
        "wash": wash,
        "mixing": mixing_kind if wash else None,
        "status": "ok",
        "note": note,
        "chips": chips,
        "n_params": n_params,
        "n_active": n_active,
        "tokens": tokens,
        "model_flops": mf,
        **stats,
        **memory,
        "useful_flops_ratio": (
            mf / stats["global_flops"] if stats.get("global_flops") else None
        ),
        "anchors": anchors,
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower + compile every "
                    "(arch x shape x mesh) pair and emit roofline terms",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter,
    )
    ap.add_argument("--arch", default=None,
                    help="architecture name from repro.configs (omit with "
                         "--all to sweep every arch)")
    ap.add_argument("--shape", default=None,
                    help="input shape name: train_4k, prefill_32k, "
                         "decode_32k, long_500k (omit with --all to sweep)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh instead of the "
                         "single-pod 16x16")
    ap.add_argument("--wash", type=int, default=0,
                    help="population size (ens axis); 0 = no population, "
                         "plain data/model parallel compile")
    ap.add_argument("--mixing", default="wash",
                    choices=["wash", "wash_opt", "papa", "papa_all",
                             "wash_local", "wash_opt_local"],
                    help="mixing op compiled into the WASH step; *_local "
                         "variants build per-parameter-shard plans "
                         "(core.shardplan)")
    ap.add_argument("--full-unroll", action="store_true",
                    help="unroll all layers instead of the depth-1/depth-2 "
                         "extrapolation (slow; exact for WASH traffic)")
    ap.add_argument("--pp-stages", type=int, default=0,
                    help="planner-only pipeline report: partition --arch's "
                         "WASH plan into this many stages on a fake "
                         "(ens, data, pipe) mesh and print per-stage comm "
                         "(population = --wash, default 4; no devices, no "
                         "compile)")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape) pair")
    ap.add_argument("--out-dir", default="benchmarks/dryrun",
                    help="directory for the per-pair JSON records")
    ap.add_argument("--attn-impl", default=None, choices=["naive", "chunked"],
                    help="override cfg.attn_impl for the compile")
    ap.add_argument("--attn-chunk", type=int, default=None,
                    help="override cfg.attn_chunk (kv-chunk size)")
    ap.add_argument("--remat", action="store_true",
                    help="activation-checkpoint each block (training shapes)")
    ap.add_argument("--moe-impl", default=None, choices=["global", "grouped"],
                    help="override cfg.moe_impl for MoE archs")
    ap.add_argument("--hints", action="store_true",
                    help="enable in-model GSPMD sharding constraints")
    ap.add_argument("--tag", default=None, help="suffix for the output file")
    ap.add_argument("--metrics-out", default=None,
                    help="write the telemetry event stream (per-pair "
                         "lowering spans + final metric snapshots) as "
                         "JSONL here; validate with "
                         "tools/check_metrics_schema.py")
    args = ap.parse_args(argv)

    from repro import obs

    tel = obs.configure(jsonl=args.metrics_out)

    if args.pp_stages:
        if not args.arch:
            ap.error("--pp-stages needs --arch")
        base = args.mixing[:-6] if args.mixing.endswith("_local") else args.mixing
        rec = pipeline_report(args.arch, args.wash or 4, args.pp_stages, base)
        stages_str = " ".join(
            f"s{i}={v:.3e}" for i, v in enumerate(rec["per_stage_scalars"])
        )
        print(f"[pipeline] {rec['arch']} N={rec['population']} "
              f"S={rec['stages']} L={rec['num_layers']}: {stages_str}")
        print(f"[pipeline] total={rec['total_scalars']:.6e} "
              f"(= sum of stages) vs single-stage "
              f"{rec['single_stage_scalars']:.6e}")
        tel.event("dryrun.pipeline_report", arch=rec["arch"],
                  stages=rec["stages"], total_scalars=rec["total_scalars"])
        tel.finalize()
        sys.exit(0)

    overrides = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    if args.attn_chunk:
        overrides["attn_chunk"] = args.attn_chunk
    if args.remat:
        overrides["remat_blocks"] = True
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl
    if args.hints:
        overrides["shard_hints"] = True

    pairs = []
    if args.all:
        for aid in ARCHS:
            for sh in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
                pairs.append((aid, sh))
    else:
        pairs.append((args.arch, args.shape))

    os.makedirs(args.out_dir, exist_ok=True)
    ok = True
    for aid, sh in pairs:
        tag = f"{aid}_{sh}_{'mp' if args.multi_pod else 'sp'}" + (
            f"_wash{args.wash}_{args.mixing}" if args.wash else ""
        ) + ("_fu" if args.full_unroll else "") + (
            f"_{args.tag}" if args.tag else ""
        )
        path = os.path.join(args.out_dir, tag + ".json")
        if args.all and os.path.exists(path):
            print(f"[skip-cached] {tag}", flush=True)
            continue
        try:
            with tel.span("dryrun.lower_pair", arch=aid, shape=sh):
                rec = lower_pair(aid, sh, args.multi_pod, args.wash,
                                 args.mixing, args.full_unroll,
                                 overrides or None)
            rec["overrides"] = overrides
        except Exception as e:  # noqa
            rec = {
                "arch": aid, "shape": sh, "multi_pod": args.multi_pod,
                "wash": args.wash, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
            }
            ok = False
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        if rec["status"] == "ok":
            print(
                f"[ok] {tag}: compute={rec['compute_s']:.3e}s memory={rec['memory_s']:.3e}s "
                f"collective={rec['collective_s']:.3e}s dominant={rec['dominant']} "
                f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)} "
                f"(compile {rec['t_compile_s']}s)", flush=True,
            )
        elif rec["status"] == "skip":
            print(f"[skip] {tag}: {rec['note']}", flush=True)
        else:
            print(f"[ERROR] {tag}: {rec['error']}", flush=True)
    tel.finalize()
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
