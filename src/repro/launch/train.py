"""CLI launcher: train a WASH population of any assigned architecture.

CPU-scale entry point (reduced configs train locally; full configs are
exercised through the dry-run).  Copy-pasteable examples:

  python -m repro.launch.train --arch llama3.2-3b --reduced \\
      --population 4 --mixing wash --base-p 0.01 --steps 200

  python -m repro.launch.train --arch qwen3-4b --reduced --mixing papa \\
      --steps 100 --optimizer adamw --lr 3e-4

  python -m repro.launch.train --arch llama3.2-3b --reduced \\
      --engine shard_map --mesh ens_dp --steps 50 \\
      --ckpt-population /tmp/pop.npz

Every flag is documented with its default: ``--help``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES_BY_NAME, get_arch
from repro.configs.base import TrainConfig
from repro.core.mixing import MixingConfig
from repro.data import make_lm_task, sample_tokens
from repro.launch.specs import concrete_batch
from repro.models import transformer as M
from repro.serving import averaged_params
from repro.train import checkpoint, train_population


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.ArgumentDefaultsHelpFormatter,
    )
    ap.add_argument("--arch", required=True,
                    help="architecture name from repro.configs (e.g. "
                         "llama3.2-3b, qwen3-4b, deepseek-v2-lite-16b)")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-scale) variant")
    ap.add_argument("--population", type=int, default=4,
                    help="population size N (members trained in parallel)")
    ap.add_argument("--mixing", default="wash",
                    choices=["none", "wash", "wash_opt", "papa", "papa_all"],
                    help="mixing method: wash (paper Eq. 3), wash_opt "
                         "(shuffle optimizer moments too), papa/papa_all "
                         "(parameter-averaging baselines), none")
    ap.add_argument("--base-p", type=float, default=0.01,
                    help="WASH base shuffle probability p (paper Eq. 6)")
    ap.add_argument("--schedule", default="decreasing",
                    choices=["decreasing", "constant", "increasing"],
                    help="layer-wise shuffle-probability schedule")
    ap.add_argument("--mode", default="dense", choices=["dense", "bucketed"],
                    help="shuffle plan mode: dense per-coordinate permutes "
                         "or bucketed cyclic shifts (TPU-native)")
    ap.add_argument("--engine", default="vmap", choices=["vmap", "shard_map"],
                    help="vmap: two-jit reference loop; shard_map: fused "
                         "single-jit collective engine (forces bucketed "
                         "plans for wash kinds)")
    ap.add_argument("--steps", type=int, default=200,
                    help="total optimizer steps per member")
    ap.add_argument("--record-every", type=int, default=None,
                    help="history record period (default: steps // 10); also "
                         "the fused engine's chunk window length")
    ap.add_argument("--sync-staging", action="store_true",
                    help="shard_map engine: force synchronous per-chunk "
                         "staging; default auto-gates the double-buffered "
                         "staging thread (off on CPU when chunks are too "
                         "short to amortize the thread handoff)")
    ap.add_argument("--no-gate-split", action="store_true",
                    help="shard_map engine: keep one dispatch per record "
                         "window instead of splitting no-mix gate runs onto "
                         "the collective-free executable")
    ap.add_argument("--mesh", default="ens",
                    choices=["ens", "ens_dp", "ens_dp_mp", "ens_pp",
                             "ens_dp_pp"],
                    help="shard_map engine: host mesh layout (ens-only, "
                         "ens+data, or ens+data+model; clamped to the "
                         "host's device count).  ens_dp_mp also shards "
                         "params via repro.sharding.rules and mixes with "
                         "shard-local plans (core.shardplan).  ens_pp/"
                         "ens_dp_pp add a pipeline-stage axis and route "
                         "through the microbatched pipelined engine")
    ap.add_argument("--mesh-shape", default=None,
                    help="explicit comma-separated axis sizes matching the "
                         "--mesh kind's axes (e.g. 2,2,2 for ens_dp_mp, "
                         "2,4 for ens_pp) instead of the automatic fill; "
                         "must divide the host's device count")
    ap.add_argument("--pp-stages", type=int, default=None,
                    help="pipeline stages S for --mesh ens_pp/ens_dp_pp "
                         "(default 1; must divide the devices left after "
                         "the ens axis and the model's layer count)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="pipelined engine: microbatches M per optimizer "
                         "step (GPipe schedule of M+S-1 ticks; must divide "
                         "--batch-size)")
    ap.add_argument("--pallas-shuffle", action="store_true",
                    help="apply bucketed shuffles through the fused Pallas "
                         "kernel (kernels.wash_shuffle; interpret mode "
                         "auto-detects off-TPU hosts)")
    ap.add_argument("--batch-size", type=int, default=8,
                    help="per-member batch size (synthetic LM task)")
    ap.add_argument("--seq-len", type=int, default=64,
                    help="training sequence length")
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"],
                    help="member optimizer")
    ap.add_argument("--lr", type=float, default=0.05,
                    help="peak learning rate")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for init, data, and shuffle plans")
    ap.add_argument("--ckpt", default=None,
                    help="save the averaged model (soup) here (.npz)")
    ap.add_argument("--ckpt-population", default=None,
                    help="save the full stacked population here (.npz) — "
                         "the input format of repro.launch.serve --ckpt, "
                         "which needs all members for member/ensemble modes")
    ap.add_argument("--history", default=None,
                    help="dump the training history (loss/consensus/comm "
                         "per record window) as JSON here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the telemetry event stream (spans, compile "
                         "events, comm-volume checkpoints, final metric "
                         "snapshots) as JSONL here; validate with "
                         "tools/check_metrics_schema.py")
    ap.add_argument("--metrics-summary", action="store_true",
                    help="print a telemetry metric summary on exit "
                         "(repro.obs console sink)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the first "
                         "instrumented spans into this directory (bounded "
                         "window; view with TensorBoard or Perfetto)")
    args = ap.parse_args(argv)

    from repro import obs

    tel = obs.configure(jsonl=args.metrics_out,
                        console=args.metrics_summary,
                        profile_dir=args.profile_dir)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.key(args.seed)

    task = make_lm_task(jax.random.fold_in(key, 1), vocab=min(cfg.vocab_size, 512))

    def data_fn(m, step, k):
        b = concrete_batch(cfg, jax.random.fold_in(k, 10), args.batch_size, args.seq_len)
        b["tokens"] = sample_tokens(task, k, args.batch_size, args.seq_len) % cfg.vocab_size
        return b

    def loss_fn(params, batch):
        loss, _ = M.loss_fn(params, cfg, batch)
        return loss

    tcfg = TrainConfig(
        population=args.population, optimizer=args.optimizer, lr=args.lr,
        total_steps=args.steps, batch_size=args.batch_size, seq_len=args.seq_len,
        seed=args.seed,
    )
    mcfg = MixingConfig(kind=args.mixing, base_p=args.base_p,
                        schedule=args.schedule, mode=args.mode,
                        pallas_shuffle=args.pallas_shuffle)
    if (args.engine == "shard_map" and args.mixing in ("wash", "wash_opt")
            and args.mode != "bucketed"):
        print("note: engine=shard_map lowers bucketed plans only; "
              "switching --mode dense -> bucketed")
        mcfg = dataclasses.replace(mcfg, mode="bucketed")
    # read mcfg.mode, not args.mode: the shard_map engine auto-coerces
    # dense wash configs to bucketed just above
    if args.pallas_shuffle and mcfg.mode == "dense":
        ap.error("--pallas-shuffle fuses bucketed applies; use --mode bucketed")

    pipelined = args.mesh in ("ens_pp", "ens_dp_pp")
    if (args.pp_stages is not None or args.microbatches > 1) and not pipelined:
        ap.error("--pp-stages/--microbatches require --mesh ens_pp or "
                 "ens_dp_pp")
    mesh_shape = None
    if args.mesh_shape is not None:
        try:
            mesh_shape = tuple(int(x) for x in args.mesh_shape.split(","))
        except ValueError:
            ap.error(f"--mesh-shape {args.mesh_shape!r} is not a "
                     "comma-separated list of integers")

    engine_opts = None
    mesh = None
    if args.engine == "shard_map":
        engine_opts = {
            # False forces sync; None = engine.resolve_async_staging gate
            "async_staging": False if args.sync_staging else None,
            "split_gate_runs": not args.no_gate_split,
            "pallas_shuffle": args.pallas_shuffle,
        }
        if args.mesh != "ens" or mesh_shape is not None:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh(args.population, args.mesh,
                                  mesh_shape=mesh_shape,
                                  pp_stages=args.pp_stages)
            if "model" in mesh.axis_names and mesh.shape["model"] > 1:
                from repro.sharding import rules

                params_sds = jax.eval_shape(
                    lambda: M.init_params(jax.random.key(0), cfg)
                )
                engine_opts["param_specs"] = rules.param_pspecs(
                    params_sds, cfg, mesh
                )
            print(f"mesh: {dict(mesh.shape)}")
    elif (args.sync_staging or args.no_gate_split or args.mesh != "ens"
          or mesh_shape is not None):
        ap.error("--sync-staging/--no-gate-split/--mesh/--mesh-shape "
                 "require --engine shard_map")
    if args.record_every is not None and args.record_every < 1:
        ap.error("--record-every must be >= 1")
    record_every = (
        args.record_every if args.record_every is not None
        else max(args.steps // 10, 1)
    )
    if pipelined:
        from repro.train import StageFns, train_population_pipelined

        res = train_population_pipelined(
            key, lambda k: M.init_params(k, cfg),
            StageFns(*M.pipeline_stage_fns(cfg)), data_fn,
            tcfg, mcfg, cfg.num_layers, record_every=record_every,
            mesh=mesh, microbatches=args.microbatches,
            async_staging=engine_opts["async_staging"],
            split_gate_runs=engine_opts["split_gate_runs"],
            pallas_shuffle=engine_opts["pallas_shuffle"],
        )
    else:
        res = train_population(
            key, lambda k: M.init_params(k, cfg), loss_fn, data_fn,
            tcfg, mcfg, cfg.num_layers, record_every=record_every,
            engine=args.engine, mesh=mesh, engine_opts=engine_opts,
        )

    soup = averaged_params(res)
    print(f"arch={cfg.name} mixing={args.mixing} steps={args.steps} "
          f"engine={args.engine}")
    print(f"final mean member loss : {res.history['loss'][-1]:.4f}")
    print(f"consensus distance     : {res.history['consensus'][-1]:.4f}")
    print(f"scalars sent per member: {res.comm_scalars:.3e}")

    eval_batch = data_fn(0, 0, jax.random.fold_in(key, 777))
    loss_soup, _ = M.loss_fn(soup, cfg, eval_batch)
    print(f"averaged-model loss    : {float(loss_soup):.4f}")

    if args.ckpt:
        written = checkpoint.save(args.ckpt, soup)
        print(f"saved averaged model -> {written}")
    if args.ckpt_population:
        written = checkpoint.save(args.ckpt_population, res.population)
        print(f"saved population -> {written}")
    if args.history:
        os.makedirs(os.path.dirname(args.history) or ".", exist_ok=True)
        with open(args.history, "w") as f:
            json.dump(res.history, f, indent=2)

    tel.finalize()
    if args.metrics_out:
        print(f"wrote telemetry stream -> {args.metrics_out}")


if __name__ == "__main__":
    main()
