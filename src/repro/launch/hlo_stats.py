"""Extract roofline terms from compiled XLA artifacts.

``cost_analysis`` gives HLO FLOPs and bytes accessed; collective traffic is
NOT in cost_analysis, so we parse the optimized HLO text and sum the
result-shape bytes of every collective op (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute).
"""

from __future__ import annotations

import re
from typing import Dict

import numpy as np

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# one shape token: bf16[2048,512]{1,0:T(8,128)} etc.
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\]{},:()#* ]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start|-done)?\(",
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_RE = re.compile(r"replica_groups=\{(\{[\d,{} ]*\})\}|replica_groups=\[[^\]]*\]<=\[[^\]]*\]")
_PAIR_RE = re.compile(r"source_target_pairs=\{([\d,{} ]*)\}")


_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)


def _crosses(line: str, boundary: int) -> bool:
    """True if the op's communication groups span the pod boundary."""
    m = re.search(r"replica_groups=\{\{([\d,{} ]*)\}\}", line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "").split(",") if x.strip()]
            if ids and min(ids) < boundary <= max(ids):
                return True
        return False
    m = _PAIR_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).replace("{", " ").replace("}", " ").replace(",", " ").split()]
        pairs = list(zip(ids[::2], ids[1::2]))
        return any((a < boundary) != (b < boundary) for a, b in pairs)
    m = _IOTA_RE.search(line)
    if m:
        # iota list: ids = arange(prod(dims)).reshape(dims).transpose(perm)
        # flattened, then chunked into groups of size S.
        g, s_sz = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        flat = ids.reshape(-1)
        for i in range(g):
            grp = flat[i * s_sz : (i + 1) * s_sz]
            if grp.min() < boundary <= grp.max():
                return True
        return False
    return False


def collective_permute_pairs(hlo_text: str):
    """``source_target_pairs`` of every collective-permute in the module,
    as a list of per-op ``[(src, tgt), ...]`` lists.

    Tests use this to assert *where* permutes run, not just how many bytes
    they move — e.g. the pipeline engine's contract that every ens-ring
    hop stays inside one stage (``src % S == tgt % S`` on an (ens, pipe)
    mesh) and stage-boundary hops move exactly one stage forward."""
    out = []
    for line in hlo_text.splitlines():
        op = _OP_RE.match(line)
        if not op or op.group(3) != "collective-permute" or op.group(4) == "-done":
            continue  # pairs live on the sync op or the async -start line
        m = _PAIR_RE.search(line)
        if not m:
            continue
        ids = [
            int(x)
            for x in m.group(1).replace("{", " ").replace("}", " ")
            .replace(",", " ").split()
        ]
        out.append(list(zip(ids[::2], ids[1::2])))
    return out


def collective_bytes(hlo_text: str, pod_boundary: int = 0) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module.

    ``pod_boundary`` > 0 also attributes bytes of ops whose replica groups
    span partition ids [0, boundary) and [boundary, ...) — i.e. traffic
    that must cross the pod-to-pod links — under the key "crosspod".
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["crosspod"] = 0
    pending: Dict[str, bool] = {}  # async -start op name -> crosses boundary
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_str, kind, suffix = m.groups()
        if suffix == "-start":
            # Async pair: the -start op's result is an (operand, result,
            # ...) tuple, so summing its shape tokens would double count.
            # Bytes come from the matching -done op (whose result is
            # exactly the collective's output); the group metadata lives
            # only here, so remember whether it crosses the boundary.
            pending[name] = bool(pod_boundary) and _crosses(line, pod_boundary)
            continue
        b = _shape_bytes(shape_str)
        out[kind] += b
        if suffix == "-done":
            om = re.match(r"\s*%?([\w.\-]+)", line[m.end():])
            if om and pending.pop(om.group(1), False):
                out["crosspod"] += b
        elif pod_boundary and _crosses(line, pod_boundary):
            out["crosspod"] += b
    return out


def collective_counts(hlo_text: str) -> Dict[str, int]:
    """Number of collective ops per kind, async start/done pairs counted
    once.  Kinds with no ops are present with count 0, so callers can
    assert absence without ``.get``."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m or m.group(4) == "-start":
            continue
        out[m.group(3)] += 1
    return out


def collective_result_dtypes(hlo_text: str) -> Dict[str, set]:
    """Result element dtypes per collective kind actually present, e.g.
    ``{"all-reduce": {"f32"}}``.  Async pairs contribute the -done op's
    result dtype (the collective's real output)."""
    out: Dict[str, set] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m or m.group(4) == "-start":
            continue
        dts = out.setdefault(m.group(3), set())
        for dt, _ in _SHAPE_RE.findall(m.group(2)):
            if dt in _DTYPE_BYTES:
                dts.add(dt)
    return out


_ALIAS_ENTRY_RE = re.compile(r"\{[\d, ]*\}\s*:\s*\(\s*(\d+)\s*,")


def input_output_aliased_params(hlo_text: str) -> set:
    """Parameter numbers the compiler aliased to outputs, parsed from the
    HloModule header's ``input_output_alias={ {out}: (param, {}, kind) }``
    block.  Empty when donation was dropped or never requested — jit
    flattens pytree args, so each HLO parameter is one donated leaf."""
    m = re.search(r"input_output_alias=\{", hlo_text)
    if not m:
        return set()
    depth, i = 1, m.end()
    while depth and i < len(hlo_text):
        c = hlo_text[i]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
        i += 1
    block = hlo_text[m.end() : i - 1]
    return {int(p) for p in _ALIAS_ENTRY_RE.findall(block)}


def roofline_terms(
    flops: float, bytes_hbm: float, coll: Dict[str, int], chips: int
) -> Dict[str, float]:
    """All inputs are PER-DEVICE: ``compiled.cost_analysis()`` and
    ``compiled.as_text()`` describe the per-partition program, so the
    per-chip roofline terms divide by single-chip peaks only.  (``chips``
    is kept for the global-FLOPs cross-check ``flops * chips ≈ MODEL_FLOPS``.)
    """
    cbytes = float(sum(coll.values()))
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": cbytes / ICI_BW,
        "collective_bytes": cbytes,
        "global_flops": flops * chips,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    t = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
    return max(t, key=t.get)


def model_flops(n_params: int, n_active: int, tokens: int) -> float:
    """6·N·D rule (dense) / 6·N_active·D (MoE) per the assignment."""
    return 6.0 * n_active * tokens


def summarize(cost: dict, hlo_text: str, chips: int, pod_boundary: int = 0) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0))
    bts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text, pod_boundary)
    cross = coll.pop("crosspod", 0)
    terms = roofline_terms(flops, bts, coll, chips)
    terms.update(
        {
            "hlo_flops": flops,
            "hlo_bytes": bts,
            "dominant": dominant_term(terms),
            "bytes_crosspod": float(cross),
            **{f"bytes_{k}": float(v) for k, v in coll.items()},
        }
    )
    return terms
