"""Production meshes.

All constructors are FUNCTIONS — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).

  single pod : (16, 16)        ("data", "model")            — 256 v5e chips
  multi-pod  : (2, 16, 16)     ("pod", "data", "model")     — 512 chips
  ensemble   : (N, 256//N, 16) ("ens", "data", "model")     — WASH population
               single-pod; multi-pod WASH maps ens onto the pod axis.
"""

from __future__ import annotations

import jax

from repro.core.compat import make_mesh


def _mk(shape, axes):
    return make_mesh(shape, axes)


def _largest_divisor(x: int, cap: int) -> int:
    """Largest divisor of ``x`` that is <= ``cap`` (>= 1)."""
    return max(s for s in range(1, max(min(x, cap), 1) + 1) if x % s == 0)


def make_host_ensemble_mesh(population: int):
    """Ens-only mesh over this host's actual devices (fused-engine default).

    One member per device when the population divides the device count;
    otherwise the largest divisor of the population that fits (1-device CPU
    fallback: the whole population is one shard_map block and every
    ppermute degenerates to a local roll)."""
    return _mk((_largest_divisor(population, len(jax.devices())),), ("ens",))


HOST_MESH_AXES = {
    "ens": ("ens",),
    "ens_dp": ("ens", "data"),
    "ens_dp_mp": ("ens", "data", "model"),
    "ens_pp": ("ens", "pipe"),
    "ens_dp_pp": ("ens", "data", "pipe"),
}


def make_host_mesh(
    population: int,
    kind: str = "ens",
    *,
    mesh_shape=None,
    pp_stages: int = None,
):
    """Host-device-count-clamped multi-axis mesh for the fused engine.

      ens        (E,)        — the existing ens-only default
      ens_dp     (E, D)      — population + data axes
      ens_dp_mp  (E, D, M)   — population + data + model axes
      ens_pp     (E, S)      — population + pipeline-stage axes
      ens_dp_pp  (E, D, S)   — population + data + pipeline-stage axes

    Automatic fill: E is the largest divisor of the population that fits
    the host (as in :func:`make_host_ensemble_mesh`); for ``ens_pp``/
    ``ens_dp_pp`` the pipe axis takes ``pp_stages`` (which must divide the
    remaining devices; default 1); the model axis takes the largest
    divisor of what is left (replacing the old hard-coded 2-or-1 fill);
    the data axis absorbs the remainder.  Axes are never padded past the
    host's device count, so a 1-device host degenerates every kind to the
    all-ones mesh.

    ``mesh_shape`` overrides the fill entirely: a tuple matching the
    kind's axes exactly (e.g. ``(2, 2, 2)`` for ``ens_dp_mp``), validated
    against the host's device count with a clear error when it does not
    divide.
    """
    if kind not in HOST_MESH_AXES:
        raise ValueError(f"unknown host mesh kind {kind!r}")
    axes = HOST_MESH_AXES[kind]
    ndev = len(jax.devices())
    if mesh_shape is not None:
        shape = tuple(int(s) for s in mesh_shape)
        if len(shape) != len(axes) or any(s < 1 for s in shape):
            raise ValueError(
                f"mesh shape {shape} does not match mesh kind {kind!r} "
                f"(axes {axes}: need {len(axes)} sizes >= 1)"
            )
        total = 1
        for s in shape:
            total *= s
        if ndev % total:
            raise ValueError(
                f"mesh shape {shape} needs {total} devices, which does not "
                f"divide this host's {ndev}"
            )
        if population % shape[0]:
            raise ValueError(
                f"population {population} must divide over ens axis of "
                f"size {shape[0]}"
            )
        return _mk(shape, axes)
    if kind == "ens":
        return make_host_ensemble_mesh(population)
    e = _largest_divisor(population, ndev)
    rest = ndev // e
    sizes = {"ens": e}
    if "pipe" in axes:
        s = 1 if pp_stages is None else int(pp_stages)
        if s < 1 or rest % s:
            raise ValueError(
                f"pp_stages={s} must divide the {rest} devices left after "
                f"ens={e} (host has {ndev} devices); pass mesh_shape for "
                f"an explicit layout"
            )
        sizes["pipe"] = s
        rest //= s
    if "model" in axes:
        sizes["model"] = _largest_divisor(rest, rest)
        rest //= sizes["model"]
    if "data" in axes:
        sizes["data"] = rest
    return _mk(tuple(sizes[a] for a in axes), axes)


def make_host_data_mesh():
    """Data-only mesh over every device on this host (serving default).

    The serving engine shards the request batch over ``data`` and
    replicates params — the natural layout for soup/member/ensemble modes,
    where each model instance fits a chip and throughput comes from batch
    parallelism.  A 1-device host degenerates to the (1,) mesh."""
    return _mk((len(jax.devices()),), ("data",))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_ensemble_mesh(population: int = 4, *, multi_pod: bool = False):
    """Mesh with an explicit ens axis for WASH population training.

    Multi-pod: the population IS the pod axis (the paper's distributed
    story — shuffle crosses the pod boundary, everything else stays inside
    a pod).  Single-pod: the data axis is split (ens, data).
    """
    if multi_pod:
        assert population == 2, "multi-pod ensemble maps members onto 2 pods"
        return _mk((2, 16, 16), ("ens", "data", "model"))
    assert 256 % (population * 16) == 0, "population must divide the data axis"
    return _mk((population, 256 // (population * 16), 16), ("ens", "data", "model"))


def data_axes(mesh) -> tuple:
    """Axes over which the global batch is sharded."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
