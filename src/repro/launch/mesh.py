"""Production meshes.

All constructors are FUNCTIONS — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).

  single pod : (16, 16)        ("data", "model")            — 256 v5e chips
  multi-pod  : (2, 16, 16)     ("pod", "data", "model")     — 512 chips
  ensemble   : (N, 256//N, 16) ("ens", "data", "model")     — WASH population
               single-pod; multi-pod WASH maps ens onto the pod axis.
"""

from __future__ import annotations

import jax

from repro.core.compat import make_mesh


def _mk(shape, axes):
    return make_mesh(shape, axes)


def make_host_ensemble_mesh(population: int):
    """Ens-only mesh over this host's actual devices (fused-engine default).

    One member per device when the population divides the device count;
    otherwise the largest divisor of the population that fits (1-device CPU
    fallback: the whole population is one shard_map block and every
    ppermute degenerates to a local roll)."""
    ndev = len(jax.devices())
    size = max(
        s for s in range(1, min(population, ndev) + 1) if population % s == 0
    )
    return _mk((size,), ("ens",))


def make_host_mesh(population: int, kind: str = "ens"):
    """Host-device-count-clamped multi-axis mesh for the fused engine.

      ens        (E,)        — the existing ens-only default
      ens_dp     (E, D)      — population + data axes
      ens_dp_mp  (E, D, M)   — population + data + model axes

    E is the largest divisor of the population that fits the host (as in
    :func:`make_host_ensemble_mesh`); the remaining devices fill the model
    axis (2 when it divides, for ``ens_dp_mp``) then the data axis.  Axes
    are never padded past the host's device count, so the constructors are
    safe on any CPU/TPU host; a 1-device host degenerates every kind to
    the (1,)/(1,1)/(1,1,1) mesh.
    """
    if kind == "ens":
        return make_host_ensemble_mesh(population)
    if kind not in ("ens_dp", "ens_dp_mp"):
        raise ValueError(f"unknown host mesh kind {kind!r}")
    ndev = len(jax.devices())
    e = max(
        s for s in range(1, min(population, ndev) + 1) if population % s == 0
    )
    rest = ndev // e
    m = 2 if kind == "ens_dp_mp" and rest % 2 == 0 else 1
    d = rest // m
    shape = (e, d) if kind == "ens_dp" else (e, d, m)
    axes = ("ens", "data") if kind == "ens_dp" else ("ens", "data", "model")
    return _mk(shape, axes)


def make_host_data_mesh():
    """Data-only mesh over every device on this host (serving default).

    The serving engine shards the request batch over ``data`` and
    replicates params — the natural layout for soup/member/ensemble modes,
    where each model instance fits a chip and throughput comes from batch
    parallelism.  A 1-device host degenerates to the (1,) mesh."""
    return _mk((len(jax.devices()),), ("data",))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_ensemble_mesh(population: int = 4, *, multi_pod: bool = False):
    """Mesh with an explicit ens axis for WASH population training.

    Multi-pod: the population IS the pod axis (the paper's distributed
    story — shuffle crosses the pod boundary, everything else stays inside
    a pod).  Single-pod: the data axis is split (ens, data).
    """
    if multi_pod:
        assert population == 2, "multi-pod ensemble maps members onto 2 pods"
        return _mk((2, 16, 16), ("ens", "data", "model"))
    assert 256 % (population * 16) == 0, "population must divide the data axis"
    return _mk((population, 256 // (population * 16), 16), ("ens", "data", "model"))


def data_axes(mesh) -> tuple:
    """Axes over which the global batch is sharded."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
