"""CLI launcher: serve a WASH population through the fused scan engine.

Loads (or random-inits / quick-trains) a population of the assigned
architecture and serves batches of synthetic prompts under a serving mode,
reporting tokens/sec and the engine's compile behavior.  Examples:

  python -m repro.launch.serve --arch llama3.2-3b --reduced \\
      --population 4 --mode soup --batch-size 8 --max-new 32

  python -m repro.launch.serve --arch qwen3-4b --reduced --mode ensemble \\
      --temperature 0.7 --seed 3 --mesh data

  python -m repro.launch.serve --arch llama3.2-3b --reduced --compare

``--ckpt`` restores a *population* checkpoint (a stacked pytree written by
``repro.train.checkpoint.save``, e.g. ``--ckpt-population`` from the train
CLI); without it members are random-init (throughput numbers are
weight-independent) unless ``--train-steps`` quick-trains first.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.configs.base import TrainConfig
from repro.core.mixing import MixingConfig
from repro.launch.specs import concrete_batch
from repro.models import transformer as M
from repro.serving import engine as serving
from repro.train import checkpoint, train_population


def _population(args, cfg, key):
    init = lambda k: M.init_params(k, cfg)  # noqa: E731
    if args.ckpt:
        # restore only reads shapes/dtypes from the template: eval_shape
        # costs nothing, vs actually random-initializing N full models
        like = jax.eval_shape(
            lambda: jax.vmap(init)(jax.random.split(key, args.population))
        )
        popn = checkpoint.restore(args.ckpt, like)
        print(f"restored population <- {args.ckpt}")
        return popn
    if args.train_steps > 0:
        from repro.data import make_lm_task, sample_tokens

        task = make_lm_task(jax.random.fold_in(key, 1),
                            vocab=min(cfg.vocab_size, 512))

        def data_fn(m, step, k):
            b = concrete_batch(cfg, jax.random.fold_in(k, 10), 8, 32)
            b["tokens"] = sample_tokens(task, k, 8, 32) % cfg.vocab_size
            return b

        def loss_fn(params, batch):
            loss, _ = M.loss_fn(params, cfg, batch)
            return loss

        res = train_population(
            key, init, loss_fn, data_fn,
            TrainConfig(population=args.population, optimizer="sgd", lr=0.05,
                        total_steps=args.train_steps),
            MixingConfig(kind="wash", base_p=0.05, mode="bucketed"),
            cfg.num_layers, record_every=max(args.train_steps // 2, 1),
        )
        return res.population
    return jax.vmap(init)(jax.random.split(key, args.population))


def _serve_once(popn, cfg, batch, args, mode, mesh, key):
    # resolve the mode's params ONCE (soup averaging / member slicing is
    # per-deployment work, not per-request work), then time generate —
    # the steady-state number measures the decode engine alone
    params = serving.serving_params(popn, mode, args.member)
    gen_mode = "ensemble" if mode == "ensemble" else "soup"

    def request():
        out = serving.generate(
            params, cfg, batch, args.max_new, temperature=args.temperature,
            key=key, mode=gen_mode, mesh=mesh,
        )
        jax.block_until_ready(out)
        return out

    t0 = time.time()
    out = request()
    warm = time.time() - t0
    t0 = time.time()
    out = request()
    dt = max(time.time() - t0, 1e-9)
    toks = args.batch_size * args.max_new
    print(f"mode={mode:9s} {toks / dt:9.1f} tok/s  "
          f"(compile+first {warm:.2f}s, steady {dt:.3f}s/req, "
          f"decode traces {serving.decode_trace_count()}, "
          f"executables {serving.executable_cache_size()})")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--population", type=int, default=4)
    ap.add_argument("--mode", default="soup", choices=list(serving.MODES))
    ap.add_argument("--member", type=int, default=0,
                    help="which member --mode member serves")
    ap.add_argument("--mesh", default="none", choices=["none", "data"],
                    help="data: shard the request batch over every host "
                         "device (launch.mesh.make_host_data_mesh)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None,
                    help="restore a stacked-population .npz")
    ap.add_argument("--train-steps", type=int, default=0,
                    help="quick-train the population this many steps first")
    ap.add_argument("--compare", action="store_true",
                    help="serve the same batch under every mode (the "
                         "soup-vs-ensemble accuracy/latency trade, measured)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.key(args.seed)
    if args.temperature > 0.0:
        sample_key = jax.random.fold_in(key, 999)
    else:
        sample_key = None

    popn = _population(args, cfg, key)
    batch = concrete_batch(cfg, jax.random.fold_in(key, 2),
                           args.batch_size, args.seq_len)

    mesh = None
    if args.mesh == "data":
        from repro.launch.mesh import make_host_data_mesh

        mesh = make_host_data_mesh()
        print(f"mesh: {dict(mesh.shape)}")

    print(f"arch={cfg.name} population={args.population} "
          f"B={args.batch_size} S={args.seq_len} new={args.max_new} "
          f"temperature={args.temperature}")
    serving.reset_trace_counts()
    modes = list(serving.MODES) if args.compare else [args.mode]
    outs = {m: _serve_once(popn, cfg, batch, args, m, mesh, sample_key)
            for m in modes}
    if args.compare:
        import numpy as np

        soup, ens = np.asarray(outs["soup"]), np.asarray(outs["ensemble"])
        agree = float((soup[:, args.seq_len:] == ens[:, args.seq_len:]).mean())
        print(f"soup/ensemble token agreement: {agree:.0%}")


if __name__ == "__main__":
    main()
