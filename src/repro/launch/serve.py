"""CLI launcher: serve a WASH population (scan engine or continuous batching).

Loads (or random-inits / quick-trains) a population of the assigned
architecture and serves synthetic prompts under a serving mode, reporting
tokens/sec and the engine's compile behavior.  Two runtimes:

  * default — the fused scan engine (`repro.serving.engine`): one compiled
    decode program per request shape, for shape-uniform batches;
  * ``--continuous`` — the continuous-batching runtime over a paged KV
    cache (`repro.serving.batching`): a mixed-length request stream is
    admitted into ``--max-slots`` slots and decoded with exactly one
    compiled step program, page tables and all lengths traced;
  * ``--driver`` — the async request driver (`repro.serving.driver`) on
    top of the continuous runtime: timed (``--arrival-rate``) arrivals,
    chunked prefill (``--prefill-chunk``) interleaved with in-flight
    decode, LRU page retention (``--retain-pages``), and per-request
    TTFT/latency percentiles instead of aggregate tokens/sec alone.

Copy-pasteable examples:

  python -m repro.launch.serve --arch llama3.2-3b --reduced \\
      --population 4 --mode soup --batch-size 8 --max-new 32

  python -m repro.launch.serve --arch qwen3-4b --reduced --mode ensemble \\
      --temperature 0.7 --seed 3 --mesh data

  python -m repro.launch.serve --arch llama3.2-3b --reduced --compare

  python -m repro.launch.serve --arch llama3.2-3b --reduced --continuous \\
      --requests 16 --max-slots 4 --page-size 16 --max-new 32

  python -m repro.launch.serve --arch llama3.2-3b --reduced --driver \\
      --arrival-rate 50 --prefill-chunk 16 --retain-pages --requests 16

``--ckpt`` restores a *population* checkpoint (a stacked pytree written by
``repro.train.checkpoint.save``, e.g. ``--ckpt-population`` from the train
CLI); without it members are random-init (throughput numbers are
weight-independent) unless ``--train-steps`` quick-trains first.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.configs.base import TrainConfig
from repro.core.mixing import MixingConfig
from repro.launch.specs import concrete_batch
from repro.models import transformer as M
from repro.serving import batching
from repro.serving import engine as serving
from repro.train import checkpoint, train_population


def _population(args, cfg, key):
    init = lambda k: M.init_params(k, cfg)  # noqa: E731
    if args.ckpt:
        # restore only reads shapes/dtypes from the template: eval_shape
        # costs nothing, vs actually random-initializing N full models
        like = jax.eval_shape(
            lambda: jax.vmap(init)(jax.random.split(key, args.population))
        )
        popn = checkpoint.restore(args.ckpt, like)
        print(f"restored population <- {args.ckpt}")
        return popn
    if args.train_steps > 0:
        from repro.data import make_lm_task, sample_tokens

        task = make_lm_task(jax.random.fold_in(key, 1),
                            vocab=min(cfg.vocab_size, 512))

        def data_fn(m, step, k):
            b = concrete_batch(cfg, jax.random.fold_in(k, 10), 8, 32)
            b["tokens"] = sample_tokens(task, k, 8, 32) % cfg.vocab_size
            return b

        def loss_fn(params, batch):
            loss, _ = M.loss_fn(params, cfg, batch)
            return loss

        res = train_population(
            key, init, loss_fn, data_fn,
            TrainConfig(population=args.population, optimizer="sgd", lr=0.05,
                        total_steps=args.train_steps),
            MixingConfig(kind="wash", base_p=0.05, mode="bucketed"),
            cfg.num_layers, record_every=max(args.train_steps // 2, 1),
        )
        return res.population
    return jax.vmap(init)(jax.random.split(key, args.population))


def _serve_once(popn, cfg, batch, args, mode, mesh, key):
    # resolve the mode's params ONCE (soup averaging / member slicing is
    # per-deployment work, not per-request work), then time generate —
    # the steady-state number measures the decode engine alone
    params = serving.serving_params(popn, mode, args.member)
    gen_mode = "ensemble" if mode == "ensemble" else "soup"

    def request():
        out = serving.generate(
            params, cfg, batch, args.max_new, temperature=args.temperature,
            key=key, mode=gen_mode, mesh=mesh,
        )
        jax.block_until_ready(out)
        return out

    t0 = time.time()
    out = request()
    warm = time.time() - t0
    t0 = time.time()
    out = request()
    dt = max(time.time() - t0, 1e-9)
    toks = args.batch_size * args.max_new
    print(f"mode={mode:9s} {toks / dt:9.1f} tok/s  "
          f"(compile+first {warm:.2f}s, steady {dt:.3f}s/req, "
          f"decode traces {serving.decode_trace_count()}, "
          f"executables {serving.executable_cache_size()})")
    return out


def mixed_stream(cfg, n_requests: int, max_prompt: int, max_new: int,
                 seed: int, temperature: float = 0.0,
                 share_prefix_every: int = 0):
    """A synthetic mixed-length request stream: prompt lengths and token
    budgets drawn uniformly, per-request keys when sampling — the traffic
    shape the static engine cannot serve without padding or re-compiling.

    ``share_prefix_every=k`` makes every k-th request reuse one common
    prompt prefix, so the runtime's prefix-page dedup has something to
    find (benchmarks use this; the CLI default leaves prompts independent).
    The single source of the traffic shape — ``benchmarks/serving_bench``
    consumes this function, so the CLI and the bench measure one stream.
    """
    rng = np.random.default_rng(seed)
    common = rng.integers(0, cfg.vocab_size, size=(max_prompt,)).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        S = int(rng.integers(max(2, max_prompt // 4), max_prompt + 1))
        mn = int(rng.integers(max(1, max_new // 4), max_new + 1))
        if share_prefix_every and i % share_prefix_every == 0:
            prompt = common[:S].copy()
        else:
            prompt = rng.integers(0, cfg.vocab_size, size=(S,)).astype(np.int32)
        key = jax.random.key(1000 + i) if temperature > 0 else None
        reqs.append(batching.Request(i, prompt, mn, key=key))
    return reqs


def _serve_continuous(popn, cfg, args):
    # page-table width = the stream's worst-case context, not the whole
    # pool: the attend is O(max_pages_per_slot * page_size) per slot
    max_pages = -(-(args.seq_len + args.max_new) // args.page_size)
    server = batching.ContinuousServer.from_trained(
        popn, cfg, mode=args.mode, member=args.member,
        temperature=args.temperature, page_size=args.page_size,
        max_slots=args.max_slots, num_pages=args.num_pages,
        max_pages_per_slot=max_pages, speculative=args.speculative,
        draft_k=args.draft_k, kv_dtype=args.kv_dtype,
    )
    reqs = mixed_stream(cfg, args.requests, args.seq_len, args.max_new,
                        args.seed, args.temperature)
    batching.reset_trace_counts()
    t0 = time.time()
    out = server.run(reqs)
    dt = max(time.time() - t0, 1e-9)
    toks = sum(r.max_new for r in reqs)
    st = server.stats
    print(f"continuous mode={args.mode} requests={len(reqs)} "
          f"slots={args.max_slots} page_size={args.page_size} "
          f"pool={args.num_pages} kv_dtype={args.kv_dtype or 'param'}")
    print(f"  {toks / dt:9.1f} tok/s  ({dt:.2f}s stream, "
          f"{st['decode_steps']} decode steps, "
          f"decode traces {batching.decode_trace_count()}, "
          f"prefill traces {batching.prefill_trace_count()})")
    print(f"  pages: allocated {st['pages_allocated']}, "
          f"shared {st['pages_shared']}, peak {st['peak_pages_in_use']}")
    if args.speculative:
        drafted = max(st["spec_drafted"], 1)
        print(f"  speculative draft_k={args.draft_k}: accepted "
              f"{st['spec_accepted']}/{st['spec_drafted']} drafts "
              f"({st['spec_accepted'] / drafted:.0%})")
    assert len(out) == len(reqs)
    return out


def _serve_driver(popn, cfg, args):
    """Serve the mixed stream through the async request driver: timed
    (Poisson or back-to-back) arrivals, chunked prefill interleaved with
    decode, per-request TTFT/latency percentiles from the driver's
    metrics — the SLO view of the same runtime ``--continuous`` measures
    for throughput."""
    from repro.serving.driver import RequestDriver, poisson_arrivals, summarize

    max_pages = -(-(args.seq_len + args.max_new) // args.page_size)
    server = batching.ContinuousServer.from_trained(
        popn, cfg, mode=args.mode, member=args.member,
        temperature=args.temperature, page_size=args.page_size,
        max_slots=args.max_slots, num_pages=args.num_pages,
        max_pages_per_slot=max_pages, retain_pages=args.retain_pages,
        speculative=args.speculative, draft_k=args.draft_k,
        kv_dtype=args.kv_dtype,
    )
    reqs = mixed_stream(cfg, args.requests, args.seq_len, args.max_new,
                        args.seed, args.temperature, share_prefix_every=4)
    chunk = args.prefill_chunk if args.prefill_chunk > 0 else None
    driver = RequestDriver(server, prefill_chunk=chunk)
    arrivals = (poisson_arrivals(reqs, args.arrival_rate, seed=args.seed)
                if args.arrival_rate > 0 else reqs)
    batching.reset_trace_counts()
    metrics = driver.run(arrivals)
    s = summarize(metrics)
    st = server.stats
    print(f"driver mode={args.mode} requests={s['requests']} "
          f"slots={args.max_slots} chunk={chunk} "
          f"arrival_rate={args.arrival_rate or 'back-to-back'}")
    print(f"  {s['tokens_per_s']:9.1f} tok/s  "
          f"ttft p50 {s['ttft_p50_ms']:.1f}ms p99 {s['ttft_p99_ms']:.1f}ms  "
          f"intertoken p99 {s['intertoken_p99_ms']:.2f}ms  "
          f"latency p99 {s['latency_p99_ms']:.1f}ms")
    print(f"  decode traces {batching.decode_trace_count()}, "
          f"prefill traces {batching.prefill_trace_count()}, "
          f"prefill tokens {st['prefill_tokens']} "
          f"(prefix reused {st['prefix_tokens_reused']}), "
          f"lru hits {st['lru_hits']} evictions {st['lru_evictions']}")
    assert s["requests"] == len(reqs)
    # suffix-prefill configs decode through ONE program for the stream
    # (a fresh process compiles it exactly once — the CI driver smoke
    # rides on this)
    assert batching.decode_trace_count() <= 1
    return metrics


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.ArgumentDefaultsHelpFormatter,
    )
    ap.add_argument("--arch", required=True,
                    help="architecture name from repro.configs (e.g. "
                         "llama3.2-3b, qwen3-4b)")
    ap.add_argument("--reduced", action="store_true",
                    help="serve the reduced (CPU-scale) config variant")
    ap.add_argument("--population", type=int, default=4,
                    help="population size N (members to init/restore)")
    ap.add_argument("--mode", default="soup", choices=list(serving.MODES),
                    help="serving mode: soup (1x cost), member (one member), "
                         "ensemble (Nx decode, averaged logits)")
    ap.add_argument("--member", type=int, default=0,
                    help="which member --mode member serves")
    ap.add_argument("--mesh", default="none", choices=["none", "data"],
                    help="data: shard the request batch over every host "
                         "device (launch.mesh.make_host_data_mesh); static "
                         "engine only")
    ap.add_argument("--pp-stages", type=int, default=0,
                    help="stage-split decode over this many pipeline stages "
                         "on a (pipe,) mesh: blocks + KV cache sliced 1/S "
                         "per chip, bitwise-identical tokens; static engine "
                         "only, attn families, num_layers %% S == 0")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy (keyless)")
    ap.add_argument("--max-new", type=int, default=32,
                    help="new tokens per request (continuous: the maximum "
                         "of the per-request budget range)")
    ap.add_argument("--batch-size", type=int, default=8,
                    help="static engine: requests per shape-uniform batch")
    ap.add_argument("--seq-len", type=int, default=32,
                    help="prompt length (continuous: the maximum of the "
                         "per-request prompt-length range)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for weights, prompts, and stream shape")
    ap.add_argument("--ckpt", default=None,
                    help="restore a stacked-population .npz (from "
                         "launch.train --ckpt-population)")
    ap.add_argument("--train-steps", type=int, default=0,
                    help="quick-train the population this many steps first")
    ap.add_argument("--compare", action="store_true",
                    help="static engine: serve the same batch under every "
                         "mode (the soup-vs-ensemble trade, measured)")
    ap.add_argument("--continuous", action="store_true",
                    help="serve a mixed-length request stream through the "
                         "continuous-batching paged-KV runtime")
    ap.add_argument("--requests", type=int, default=16,
                    help="continuous: number of requests in the stream")
    ap.add_argument("--max-slots", type=int, default=4,
                    help="continuous: in-flight request slots (the decode "
                         "step's batch size)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="continuous: tokens per KV page")
    ap.add_argument("--num-pages", type=int, default=256,
                    help="continuous: KV page-pool size shared by all slots")
    ap.add_argument("--driver", action="store_true",
                    help="serve the stream through the async request driver "
                         "(timed arrivals, chunked prefill interleaved with "
                         "decode, TTFT/latency percentiles)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="driver: Poisson arrival rate in requests/sec "
                         "(0 = submit the whole stream back-to-back)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="driver: prefill at most this many prompt tokens "
                         "per tick, interleaved with decode steps "
                         "(0 = whole remaining suffix in one program)")
    ap.add_argument("--speculative", action="store_true",
                    help="continuous/driver: population-powered speculative "
                         "decoding — the soup drafts --draft-k tokens per "
                         "step, the ensemble verifies them in one batched "
                         "step (bitwise the plain path at fp32 KV)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative draft length (tokens proposed per "
                         "decode call; one executable per distinct value)")
    ap.add_argument("--kv-dtype", default=None, choices=["int8"],
                    help="quantize the paged KV pools (int8, one scale per "
                         "page — double the effective pool capacity; "
                         "default: the model's param dtype, bitwise)")
    ap.add_argument("--retain-pages", action="store_true",
                    help="driver: keep refcount-0 prefix pages on an LRU "
                         "list (evicted only under pool pressure) so "
                         "recurring prompts skip their prefill compute")
    ap.add_argument("--metrics-out", default=None,
                    help="write the telemetry event stream (spans, compile "
                         "events, SLO histograms, final metric snapshots) "
                         "as JSONL here; validate with "
                         "tools/check_metrics_schema.py")
    ap.add_argument("--metrics-summary", action="store_true",
                    help="print a telemetry metric summary on exit "
                         "(repro.obs console sink)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the first "
                         "instrumented spans into this directory (bounded "
                         "window; view with TensorBoard or Perfetto)")
    args = ap.parse_args(argv)

    from repro import obs

    tel = obs.configure(jsonl=args.metrics_out,
                        console=args.metrics_summary,
                        profile_dir=args.profile_dir)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if (args.speculative or args.kv_dtype) and not (args.continuous
                                                    or args.driver):
        ap.error("--speculative/--kv-dtype are continuous-runtime knobs; "
                 "add --continuous or --driver")

    key = jax.random.key(args.seed)
    if args.temperature > 0.0:
        sample_key = jax.random.fold_in(key, 999)
    else:
        sample_key = None

    popn = _population(args, cfg, key)

    if args.driver:
        if args.mesh != "none" or args.pp_stages:
            ap.error("--driver does not take --mesh/--pp-stages "
                     "(single-host runtime)")
        try:
            _serve_driver(popn, cfg, args)
        finally:
            tel.finalize()
            if args.metrics_out:
                print(f"wrote telemetry stream -> {args.metrics_out}")
        return

    if args.continuous:
        if args.mesh != "none" or args.pp_stages:
            ap.error("--continuous does not take --mesh/--pp-stages "
                     "(single-host runtime)")
        try:
            _serve_continuous(popn, cfg, args)
        finally:
            tel.finalize()
            if args.metrics_out:
                print(f"wrote telemetry stream -> {args.metrics_out}")
        return

    batch = concrete_batch(cfg, jax.random.fold_in(key, 2),
                           args.batch_size, args.seq_len)

    mesh = None
    if args.pp_stages:
        if args.mesh != "none":
            ap.error("--pp-stages builds its own (pipe,) mesh; drop --mesh")
        from repro.core.compat import make_mesh

        if args.pp_stages < 1 or args.pp_stages > len(jax.devices()):
            ap.error(f"--pp-stages {args.pp_stages} needs that many "
                     f"devices; this host has {len(jax.devices())}")
        mesh = make_mesh((args.pp_stages,), ("pipe",))
        print(f"mesh: {dict(mesh.shape)}")
    elif args.mesh == "data":
        from repro.launch.mesh import make_host_data_mesh

        mesh = make_host_data_mesh()
        print(f"mesh: {dict(mesh.shape)}")

    print(f"arch={cfg.name} population={args.population} "
          f"B={args.batch_size} S={args.seq_len} new={args.max_new} "
          f"temperature={args.temperature}")
    serving.reset_trace_counts()
    modes = list(serving.MODES) if args.compare else [args.mode]
    outs = {m: _serve_once(popn, cfg, batch, args, m, mesh, sample_key)
            for m in modes}
    if args.compare:
        soup, ens = np.asarray(outs["soup"]), np.asarray(outs["ensemble"])
        agree = float((soup[:, args.seq_len:] == ens[:, args.seq_len:]).mean())
        print(f"soup/ensemble token agreement: {agree:.0%}")

    tel.finalize()
    if args.metrics_out:
        print(f"wrote telemetry stream -> {args.metrics_out}")


if __name__ == "__main__":
    main()
