"""Input specifications for every (architecture × input shape).

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for the dry-run; ``concrete_batch`` materializes
small real batches for smoke tests.

Modality stubs (the one allowed carve-out): audio provides precomputed
frame embeddings (B, num_frames, d_model); vision provides patch
embeddings (B, num_patches, d_model).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as M

SDS = jax.ShapeDtypeStruct


def _extra_specs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        out["frames"] = SDS((batch, cfg.num_frames, cfg.d_model), dt)
    if cfg.frontend == "vision":
        out["patches"] = SDS((batch, cfg.num_patches, cfg.d_model), dt)
    return out


def train_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    return {"tokens": SDS((b, s), jnp.int32), **_extra_specs(cfg, b)}


def prefill_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    return train_specs(cfg, shape)


def decode_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """serve_step inputs: one new token + a cache of `seq_len` context."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
    return {"tokens": SDS((b, 1), jnp.int32), "cache": cache, "pos": SDS((), jnp.int32)}


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)


# ---------------------------------------------------------------------------
# concrete batches (smoke tests / examples)
# ---------------------------------------------------------------------------


def concrete_batch(cfg: ModelConfig, key: jax.Array, batch: int, seq: int):
    ks = jax.random.split(key, 3)
    out = {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)}
    if cfg.frontend == "audio":
        out["frames"] = jax.random.normal(
            ks[1], (batch, cfg.num_frames, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.frontend == "vision":
        out["patches"] = jax.random.normal(
            ks[1], (batch, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return out
