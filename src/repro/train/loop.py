"""Population training loop (paper Alg. 1).

Alternates per step:  (1) an independent optimizer step per member on its
own data stream (vmapped over the stacked ens axis), then (2) the
configured mixing op (WASH shuffle / PAPA EMA / PAPA-all average / none).

The loop works for any model: the caller supplies ``loss_fn(params, batch)``
and ``data_fn(member, step, key) -> batch``.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import TrainConfig
from repro.core import population as pop
from repro.core.consensus import avg_distance_to_consensus
from repro.core.layer_index import infer_layer_ids, total_layers
from repro.core.mixing import MixingConfig, mix_once, mixing_due, static_mix_comm
from repro.core.prng import step_key
from repro.optim import cosine_lr, make_optimizer

PyTree = Any


@dataclasses.dataclass
class TrainResult:
    population: PyTree
    opt_state: PyTree
    history: Dict[str, List[float]]
    comm_scalars: float  # total scalars sent per member over training


def train_population(
    key: jax.Array,
    init_fn: Callable[[jax.Array], PyTree],
    loss_fn: Callable[[PyTree, Any], jax.Array],
    data_fn: Callable[[int, int, jax.Array], Any],
    tcfg: TrainConfig,
    mcfg: MixingConfig,
    num_blocks: int,
    record_every: int = 25,
    record_fn: Optional[Callable[[int, PyTree], Dict[str, float]]] = None,
    engine: str = "vmap",
    mesh=None,
    engine_opts: Optional[Dict[str, Any]] = None,
) -> TrainResult:
    """Train a population.  ``engine="vmap"`` is this module's two-jit
    reference loop; ``engine="shard_map"`` dispatches to the fused
    single-jit collective engine (:mod:`repro.train.engine`), which also
    receives ``mesh`` (an ``ens``-only or ``(ens[, data][, model])``
    mesh) and any ``engine_opts`` (``async_staging``/``split_gate_runs``/
    ``param_specs``/``pallas_shuffle``)."""
    if engine == "shard_map":
        from repro.train.engine import train_population_sharded

        return train_population_sharded(
            key, init_fn, loss_fn, data_fn, tcfg, mcfg, num_blocks,
            record_every=record_every, record_fn=record_fn, mesh=mesh,
            **(engine_opts or {}),
        )
    if engine != "vmap":
        raise ValueError(f"unknown engine {engine!r}")
    if mesh is not None:
        raise ValueError(
            "mesh= is only consumed by engine='shard_map'; the vmap "
            "reference loop runs on the default device"
        )
    if engine_opts:
        raise ValueError(
            f"engine_opts={sorted(engine_opts)} are only consumed by "
            "engine='shard_map'"
        )
    n = tcfg.population
    population = pop.init_population(init_fn, key, n, same_init=tcfg.same_init)
    lids = infer_layer_ids(pop.member(population, 0), num_blocks)
    tl = total_layers(num_blocks)

    opt_init, opt_update = make_optimizer(
        tcfg.optimizer, momentum=tcfg.momentum, weight_decay=tcfg.weight_decay
    )
    opt_state = jax.vmap(opt_init)(population)

    @jax.jit
    def train_step(population, opt_state, batches, lr):
        def one(p, s, b):
            loss, g = jax.value_and_grad(loss_fn)(p, b)
            p2, s2 = opt_update(p, g, s, lr)
            return p2, s2, loss

        p2, s2, losses = jax.vmap(one, in_axes=(0, 0, 0))(population, opt_state, batches)
        return p2, s2, jnp.mean(losses)

    @functools.partial(jax.jit, static_argnames=())
    def mix_step(population, opt_state, k):
        return mix_once(k, population, opt_state, mcfg, lids, tl)

    # exact float64 comm per mixing step from the static plan sizes; None
    # for dense WASH (data-dependent Bernoulli masks → use the device value)
    member_tpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), population
    )
    static_comm = static_mix_comm(
        member_tpl, mcfg, lids, tl, n, opt_state=opt_state
    )

    history: Dict[str, List[float]] = {
        "step": [], "loss": [], "consensus": [], "comm": []
    }
    comm_total = 0.0
    base_key = jax.random.fold_in(key, 1234)
    data_key = jax.random.fold_in(key, 5678)

    tel = obs.get()
    # mirrors comm_total add-for-add so the counter bit-equals the exact
    # host-side accounting (see the fused engine's identical mirror)
    comm_counter = tel.registry.counter("train.comm_scalars") if tel.enabled else None

    t0 = time.time()
    for step in range(tcfg.total_steps):
        lr = cosine_lr(step, tcfg.total_steps, tcfg.lr, tcfg.min_lr, tcfg.warmup_steps)
        dk = jax.random.fold_in(data_key, step)
        batches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[data_fn(m, step, jax.random.fold_in(dk, m)) for m in range(n)],
        )
        with tel.span("train.step", step=step):
            population, opt_state, loss = train_step(
                population, opt_state, batches, lr
            )

        if mixing_due(step, mcfg):
            population, opt_state, comm = mix_step(
                population, opt_state, step_key(base_key, step)
            )
            comm_step = float(comm) if static_comm is None else static_comm
            comm_total += comm_step
            if comm_counter is not None:
                comm_counter.inc(comm_step)
                tel.event("train.comm_volume", comm_per_mix_step=comm_step,
                          mix_steps=1, comm_total=comm_total)

        if step % record_every == 0 or step == tcfg.total_steps - 1:
            history["step"].append(step)
            history["loss"].append(float(loss))
            history["consensus"].append(float(avg_distance_to_consensus(population)))
            history["comm"].append(comm_total)
            extras = {}
            if record_fn is not None:
                for k_, v in record_fn(step, population).items():
                    history.setdefault(k_, []).append(v)
                    extras[k_] = v
            if tel.enabled:
                tel.registry.gauge("train.loss").set(history["loss"][-1])
                wall = time.time() - t0
                if wall > 0:
                    tel.registry.gauge("train.steps_per_s").set(
                        (step + 1) / wall
                    )
                # record_fn outputs become metric samples alongside the event
                for k_, v in extras.items():
                    tel.registry.gauge(f"train.record.{k_}").set(v)
                tel.event("train.record", step=step,
                          loss=history["loss"][-1],
                          consensus=history["consensus"][-1],
                          comm=comm_total, **extras)

    history["wall_s"] = [time.time() - t0]
    if tel.enabled:
        tel.registry.gauge("train.wall_s").set(history["wall_s"][0])
    return TrainResult(population, opt_state, history, comm_total)
