"""Minimal npz checkpointing for pytrees (no orbax in this container)."""

from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

from repro.core.population import host_gather

PyTree = Any
_SEP = "::"


def _flat_paths(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        # multi-device leaves (fused shard_map engine output) are gathered
        # explicitly before np.asarray sees them
        out[key] = np.asarray(host_gather(leaf))
    return out


def save(path: str, tree: PyTree) -> str:
    """Write ``tree`` as an npz archive and return the path actually
    written.  numpy appends ``.npz`` when the suffix is missing, so the
    path is normalized here — callers report the returned path, never the
    one they passed in."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flat_paths(tree))
    return path


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes must match).

    Leaves come off the npz as host numpy; whenever the matching ``like``
    leaf is a committed ``jax.Array`` the restored leaf is ``device_put``
    onto that leaf's sharding.  Without this, feeding a restored population
    straight into the fused shard_map engine works but silently re-uploads
    (and for multi-device shardings re-shards) every leaf on each step —
    the round-trip must hand back device arrays in the original layout.
    ``like`` trees made of plain numpy leaves restore to numpy, unchanged.
    """
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(q.key) if hasattr(q, "key") else str(q.idx) for q in p)
        arr = data[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        arr = arr.astype(leaf.dtype)
        if isinstance(leaf, jax.Array):
            arr = jax.device_put(arr, leaf.sharding)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
