"""Minimal npz checkpointing for pytrees (no orbax in this container)."""

from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "::"


def _gather(leaf):
    """Explicitly fetch a leaf to host memory before ``np.asarray``.

    The fused shard_map engine returns populations whose leaves are
    sharded over several devices; ``np.asarray`` on those either errors
    (non-fully-addressable arrays) or triggers an implicit cross-device
    transfer inside numpy.  ``jax.device_get`` assembles the shards
    explicitly on the host instead."""
    if isinstance(leaf, jax.Array) and len(leaf.sharding.device_set) > 1:
        return jax.device_get(leaf)
    return leaf


def _flat_paths(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out[key] = np.asarray(_gather(leaf))
    return out


def save(path: str, tree: PyTree) -> str:
    """Write ``tree`` as an npz archive and return the path actually
    written.  numpy appends ``.npz`` when the suffix is missing, so the
    path is normalized here — callers report the returned path, never the
    one they passed in."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flat_paths(tree))
    return path


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes must match)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(q.key) if hasattr(q, "key") else str(q.idx) for q in p)
        arr = data[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
