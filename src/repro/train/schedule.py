"""Chunk scheduler for the fused shard_map engine.

The engine dispatches one fused jit per *chunk* of steps.  PR 1 cut chunks
at ``record_every`` boundaries only, which left two costs on the table:

  * chunk lengths varied (1 / ``record_every`` / ragged tail), so the
    donated jit recompiled for up to three scan lengths per run — minutes
    of wasted XLA time each at real model scale;
  * the gated collective still executed ``ppermute``/``pmean`` on steps
    where :func:`repro.core.mixing.mixing_due` is False (cheap for WASH,
    which mixes every step, but wasteful for PAPA with a large period T —
    exactly the overhead the paper criticizes PAPA-style methods for).

This module plans the whole run up front, host-side, from the three
static inputs ``(total_steps, record_every, mcfg)``:

  1. **Record windows** (:func:`chunk_ranges`) cut at the reference loop's
     host-sync points, exactly as before.
  2. **Gate-run splitting**: each window is split along maximal runs of
     equal ``mixing_due`` value, so no-mix spans dispatch on a
     collective-free executable.  WASH (mixing every step) keeps its
     single dispatch per window; ``none`` collapses to one collective-free
     dispatch per window; PAPA alternates between the two variants.
  3. **Fixed pad lengths**: every chunk of a variant is padded to that
     variant's maximum run length, so each variant compiles **exactly
     once** — at most two traces per run, one when no gate-split applies.
     The per-slot valid mask (1 on real steps, 0 on pads —
     :meth:`ChunkPlan.padded_valid`) is always a prefix of ones, so the
     engine lowers it to the traced trip count of its fused
     ``fori_loop``: pad slots sit past the bound and never execute, which
     keeps the executed per-step dataflow identical to the unpadded scan
     (bitwise parity) and spends zero FLOPs on padding.

Only the *last* chunk of each record window carries ``record=True``; the
host reads losses/consensus there, so the history schedule stays
identical to the reference loop's.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.core.mixing import MixingConfig, mixing_due


def record_boundaries(total_steps: int, record_every: int) -> List[int]:
    """Steps at which the reference loop records (its host-sync points)."""
    return [
        s for s in range(total_steps)
        if s % record_every == 0 or s == total_steps - 1
    ]


def chunk_ranges(total_steps: int, record_every: int) -> List[Tuple[int, int]]:
    """``[(start, stop))`` chunks covering ``range(total_steps)``, each
    ending on a record boundary, so the fused scan only returns to the host
    where the reference loop would have synced anyway."""
    out, start = [], 0
    for b in record_boundaries(total_steps, record_every):
        out.append((start, b + 1))
        start = b + 1
    return out


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """One fused dispatch: steps ``[start, stop)`` padded to ``pad_len``.

    ``gates`` holds the per-real-step ``mixing_due`` results; ``mixing``
    selects the compiled variant (collective vs collective-free) and is
    True iff any gate is set.  ``record`` marks the chunk whose last real
    step is a reference-loop record boundary.
    """

    start: int
    stop: int
    gates: Tuple[bool, ...]
    mixing: bool
    record: bool
    pad_len: int

    @property
    def length(self) -> int:
        return self.stop - self.start

    @property
    def steps(self) -> range:
        return range(self.start, self.stop)

    @property
    def pad(self) -> int:
        return self.pad_len - self.length

    def padded_gates(self) -> List[float]:
        """Gate vector for the scan: mixing_due per real step, 0 on pads."""
        return [1.0 if g else 0.0 for g in self.gates] + [0.0] * self.pad

    def padded_valid(self) -> List[float]:
        """Per-slot valid mask: 1 on real steps, 0 on pad slots.  Always
        a ones-prefix, which is why the engine encodes it as the fused
        loop's trip count (``chunk.length``) rather than a select mask."""
        return [1.0] * self.length + [0.0] * self.pad


@dataclasses.dataclass(frozen=True)
class Schedule:
    """The run's full dispatch plan (host-side, static)."""

    chunks: Tuple[ChunkPlan, ...]
    mix_pad_len: int    # scan length of the collective variant (0 if unused)
    nomix_pad_len: int  # scan length of the collective-free variant (0 if unused)

    def variants(self) -> Tuple[bool, ...]:
        """Distinct executables this schedule dispatches (≤ 2)."""
        return tuple(sorted({c.mixing for c in self.chunks}))

    def num_padded_steps(self) -> int:
        return sum(c.pad for c in self.chunks)


def num_pipeline_ticks(num_micro: int, num_stages: int) -> int:
    """Forward ticks of one GPipe-scheduled optimizer step: ``M + S - 1``
    (fill + steady state + drain).  At tick ``t`` stage ``s`` processes
    microbatch ``t - s`` when that index is live; the pipelined engine
    masks the fill/drain bubbles, so per-step FLOPs scale by
    ``(M + S - 1) / M`` — the classic GPipe bubble fraction."""
    if num_micro < 1 or num_stages < 1:
        raise ValueError(
            f"need num_micro >= 1 and num_stages >= 1; got "
            f"({num_micro}, {num_stages})"
        )
    return num_micro + num_stages - 1


def split_microbatch_sizes(batch_size: int, num_micro: int) -> Tuple[int, int]:
    """``(num_micro, batch_size // num_micro)`` with an exact-split check.

    Equal microbatches make the pipelined loss (mean of per-microbatch
    means) equal the single-shot batch mean, which is what the S>1
    tolerance-parity contract relies on."""
    if num_micro < 1 or batch_size % num_micro:
        raise ValueError(
            f"batch dim {batch_size} does not split into {num_micro} "
            f"equal microbatches"
        )
    return num_micro, batch_size // num_micro


def _gate_runs(
    wstart: int, wstop: int, gates: List[bool]
) -> List[Tuple[int, int]]:
    """Maximal ``[start, stop)`` runs of equal gate value inside a window."""
    runs, rs = [], wstart
    for s in range(wstart + 1, wstop):
        if gates[s - wstart] != gates[rs - wstart]:
            runs.append((rs, s))
            rs = s
    runs.append((rs, wstop))
    return runs


def build_schedule(
    total_steps: int,
    record_every: int,
    mcfg: MixingConfig,
    *,
    split_gate_runs: bool = True,
) -> Schedule:
    """Plan every fused dispatch for a run.

    ``split_gate_runs=False`` keeps PR 1's one-dispatch-per-window shape
    (useful for A/B benchmarks); chunks whose window mixes anywhere then
    dispatch on the collective variant with their inner gates zeroed on
    no-mix steps.  Either way, chunk lengths are padded so each variant
    compiles exactly once.
    """
    raw = []  # (start, stop, gates, mixing, record)
    for wstart, wstop in chunk_ranges(total_steps, record_every):
        gates = [mixing_due(s, mcfg) for s in range(wstart, wstop)]
        if split_gate_runs:
            pieces = _gate_runs(wstart, wstop, gates)
        else:
            pieces = [(wstart, wstop)]
        for a, b in pieces:
            g = tuple(gates[a - wstart:b - wstart])
            raw.append((a, b, g, any(g), b == wstop))

    mix_pad = max((b - a for a, b, _, mix, _ in raw if mix), default=0)
    nomix_pad = max((b - a for a, b, _, mix, _ in raw if not mix), default=0)
    chunks = tuple(
        ChunkPlan(a, b, g, mix, rec, mix_pad if mix else nomix_pad)
        for a, b, g, mix, rec in raw
    )
    return Schedule(chunks, mix_pad, nomix_pad)
