"""Population training loop + checkpointing."""

from repro.train.loop import TrainResult, train_population
from repro.train.engine import (
    StageFns,
    train_population_pipelined,
    train_population_sharded,
)
from repro.train import checkpoint

__all__ = [
    "train_population", "train_population_sharded",
    "train_population_pipelined", "StageFns", "TrainResult", "checkpoint",
]
