"""Population training loop + checkpointing."""

from repro.train.loop import TrainResult, train_population
from repro.train import checkpoint

__all__ = ["train_population", "TrainResult", "checkpoint"]
