"""Population training loop + checkpointing."""

from repro.train.loop import TrainResult, train_population
from repro.train.engine import train_population_sharded
from repro.train import checkpoint

__all__ = [
    "train_population", "train_population_sharded", "TrainResult", "checkpoint",
]
