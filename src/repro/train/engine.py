"""Fused shard_map training engine: one donated jit per record window.

The reference loop (:mod:`repro.train.loop`) dispatches two separate jits
per step (optimizer update, then mixing) from a Python loop, so the WASH
communication story is simulated on a stacked array rather than exercised.
This engine runs the whole train+mix step as ONE donated jit under
``shard_map`` over an ``ens`` mesh axis:

  * each mesh shard holds a contiguous block of n_local = N / mesh_ens
    members (one member per device on a TPU ensemble mesh; the whole
    population on the 1-device CPU fallback),
  * WASH shuffles travel over the real ``ppermute`` path
    (:func:`repro.core.shuffle.bucketed_apply_collective_blocked`) and
    PAPA pulls over ``pmean``, instead of the stacked gather,
  * ``lax.scan`` chunks every step between two ``record_every`` boundaries
    into a single dispatch, so the host is only re-entered where the
    reference loop would have synced anyway,
  * the mixing schedule (:func:`repro.core.mixing.mixing_due` per step) is
    threaded through the scan as a static-shaped gate vector, and the WASH
    plan is built once per step from the shared key and replayed on the
    optimizer moments (WASH+Opt) inside the fused step.

WASH kinds always use the ``bucketed`` plan mode here (the dense mode has
no collective lowering); everything else — init, data order, key
derivation, optimizer arithmetic, comm accounting — matches the reference
loop exactly, which `tests/test_engine_parity.py` asserts.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.core import population as pop
from repro.core.compat import shard_map
from repro.core.consensus import avg_distance_to_consensus
from repro.core.layer_index import infer_layer_ids, total_layers
from repro.core.mixing import MixingConfig, mix_collective_blocked, mixing_due
from repro.core.prng import step_key
from repro.optim import cosine_lr, make_optimizer
from repro.train.loop import TrainResult

PyTree = Any


def record_boundaries(total_steps: int, record_every: int) -> List[int]:
    """Steps at which the reference loop records (its host-sync points)."""
    return [
        s for s in range(total_steps)
        if s % record_every == 0 or s == total_steps - 1
    ]


def chunk_ranges(total_steps: int, record_every: int):
    """``[(start, stop))`` chunks covering ``range(total_steps)``, each
    ending on a record boundary, so the fused scan only returns to the host
    where the reference loop would have synced anyway."""
    out, start = [], 0
    for b in record_boundaries(total_steps, record_every):
        out.append((start, b + 1))
        start = b + 1
    return out


def make_fused_chunk_fn(
    mesh,
    mcfg: MixingConfig,
    layer_ids: PyTree,
    tl: int,
    opt_update: Callable,
    loss_fn: Callable[[PyTree, Any], jax.Array],
    pspec: PyTree,
    ospec: PyTree,
    bspecs: PyTree,
    *,
    donate: bool = True,
):
    """Build the engine's fused chunk dispatch: one donated jit scanning
    (per-member update → gated collective mix) over a chunk of steps under
    shard_map.  Exposed so benchmarks time the SHIPPED engine body rather
    than a copy (``benchmarks/kernels_bench.py``; pass ``donate=False``
    there so repeated timing calls can reuse their inputs)."""

    def chunk_fn(population, opt_state, batches, lrs, keydata, gates):
        def body(carry, xs):
            p, s = carry
            batch, lr, kd, gate = xs

            def one(pm, sm, bm):
                loss, g = jax.value_and_grad(loss_fn)(pm, bm)
                p2, s2 = opt_update(pm, g, sm, lr)
                return p2, s2, loss

            p2, s2, losses = jax.vmap(one)(p, s, batch)
            k = jax.random.wrap_key_data(kd)
            p3, s3, comm = mix_collective_blocked(
                k, p2, s2, mcfg, layer_ids, tl, "ens", gate
            )
            loss_mean = lax.pmean(jnp.mean(losses), "ens")
            return (p3, s3), (loss_mean, comm)

        (p, s), (losses, comms) = lax.scan(
            body, (population, opt_state), (batches, lrs, keydata, gates)
        )
        # per-step comms returned unsummed: the host accumulates in float64
        # (a float32 chunk sum loses integer exactness past 2^24 scalars,
        # breaking comm parity with the reference loop at real model scale)
        return p, s, losses, comms

    f = shard_map(
        chunk_fn,
        mesh,
        in_specs=(pspec, ospec, bspecs, P(), P(), P()),
        out_specs=(pspec, ospec, P(), P()),
        check_vma=False,
    )
    return jax.jit(f, donate_argnums=(0, 1) if donate else ())


def train_population_sharded(
    key: jax.Array,
    init_fn: Callable[[jax.Array], PyTree],
    loss_fn: Callable[[PyTree, Any], jax.Array],
    data_fn: Callable[[int, int, jax.Array], Any],
    tcfg: TrainConfig,
    mcfg: MixingConfig,
    num_blocks: int,
    record_every: int = 25,
    record_fn: Optional[Callable[[int, PyTree], Dict[str, float]]] = None,
    mesh=None,
) -> TrainResult:
    """Drop-in replacement for :func:`repro.train.loop.train_population`
    running the fused shard_map engine.  Same signature plus an optional
    ``mesh`` (an ``ens``-axis mesh; default: the host's devices)."""
    if mcfg.kind in ("wash", "wash_opt") and mcfg.mode != "bucketed":
        raise ValueError(
            f"engine='shard_map' only lowers bucketed WASH plans; got "
            f"mode={mcfg.mode!r}.  Use mode='bucketed' (identical in "
            f"expectation, Eq. 4) or engine='vmap' for dense plans."
        )
    n = tcfg.population
    if mesh is None:
        from repro.launch.mesh import make_host_ensemble_mesh

        mesh = make_host_ensemble_mesh(n)
    m = int(mesh.shape["ens"])
    assert n % m == 0, f"population {n} must divide over ens axis of size {m}"

    population = pop.init_population(init_fn, key, n, same_init=tcfg.same_init)
    lids = infer_layer_ids(pop.member(population, 0), num_blocks)
    tl = total_layers(num_blocks)

    opt_init, opt_update = make_optimizer(
        tcfg.optimizer, momentum=tcfg.momentum, weight_decay=tcfg.weight_decay
    )
    opt_state = jax.vmap(opt_init)(population)

    pspec = jax.tree_util.tree_map(lambda _: P("ens"), population)
    ospec = jax.tree_util.tree_map(lambda _: P("ens"), opt_state)

    fused = None  # built lazily once the batch pytree structure is known

    def get_fused(batches):
        nonlocal fused
        if fused is None:
            bspecs = jax.tree_util.tree_map(lambda _: P(None, "ens"), batches)
            fused = make_fused_chunk_fn(
                mesh, mcfg, lids, tl, opt_update, loss_fn,
                pspec, ospec, bspecs,
            )
        return fused

    history: Dict[str, List[float]] = {
        "step": [], "loss": [], "consensus": [], "comm": []
    }
    comm_total = 0.0
    base_key = jax.random.fold_in(key, 1234)
    data_key = jax.random.fold_in(key, 5678)

    t0 = time.time()
    for start, stop in chunk_ranges(tcfg.total_steps, record_every):
        steps = range(start, stop)
        per_step = []
        for step in steps:
            dk = jax.random.fold_in(data_key, step)
            per_step.append(jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[data_fn(mm, step, jax.random.fold_in(dk, mm)) for mm in range(n)],
            ))
        batches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per_step
        )
        lrs = jnp.stack([
            cosine_lr(s, tcfg.total_steps, tcfg.lr, tcfg.min_lr, tcfg.warmup_steps)
            for s in steps
        ])
        keydata = jnp.stack(
            [jax.random.key_data(step_key(base_key, s)) for s in steps]
        )
        gates = jnp.asarray(
            [1.0 if mixing_due(s, mcfg) else 0.0 for s in steps], jnp.float32
        )

        population, opt_state, losses, comms = get_fused(batches)(
            population, opt_state, batches, lrs, keydata, gates
        )
        for c in list(comms):  # per-step float64 adds, as the reference does
            comm_total += float(c)

        step = stop - 1  # chunk boundary == record boundary
        history["step"].append(step)
        history["loss"].append(float(losses[-1]))
        history["consensus"].append(float(avg_distance_to_consensus(population)))
        history["comm"].append(comm_total)
        if record_fn is not None:
            for k_, v in record_fn(step, population).items():
                history.setdefault(k_, []).append(v)

    history["wall_s"] = [time.time() - t0]
    return TrainResult(population, opt_state, history, comm_total)
