"""Fused shard_map training engine: one donated jit per schedule chunk.

The reference loop (:mod:`repro.train.loop`) dispatches two separate jits
per step (optimizer update, then mixing) from a Python loop, so the WASH
communication story is simulated on a stacked array rather than exercised.
This engine runs the whole train+mix step as ONE donated jit under
``shard_map`` over an ``ens`` mesh axis:

  * each mesh shard holds a contiguous block of n_local = N / mesh_ens
    members (one member per device on a TPU ensemble mesh; the whole
    population on the 1-device CPU fallback),
  * WASH shuffles travel over the real ``ppermute`` path
    (:func:`repro.core.shuffle.bucketed_apply_collective_blocked`) and
    PAPA pulls over ``pmean``, instead of the stacked gather,
  * ``lax.scan`` runs each chunk of the host-side dispatch plan
    (:mod:`repro.train.schedule`) in a single dispatch.  Chunks are padded
    to one fixed scan length per compiled variant and split along
    ``mixing_due`` gate runs, so the engine traces **at most two**
    executables per run (one collective, one collective-free) no matter
    how ``(total_steps, record_every)`` fall — and exactly one when the
    gates never change inside a record window (WASH, ``none``),
  * the mixing schedule (:func:`repro.core.mixing.mixing_due` per step) is
    threaded through the fused loop as a static-shaped gate vector, the
    per-step ``valid`` mask lowers to the loop's traced trip count (pad
    slots sit past it and never execute), and the WASH plan is built once
    per step from the shared key and replayed on the optimizer moments
    (WASH+Opt) inside the fused step,
  * batches for chunk k+1 are stacked and ``device_put`` on a staging
    thread while chunk k executes (double buffering), instead of PR 1's
    synchronous per-chunk host loop,
  * communication is accounted host-side in exact float64 from the static
    plan sizes (:func:`repro.core.mixing.static_mix_comm`) — a float32
    scalar carried through ``lax.scan`` truncates past 2^24 scalars per
    step, far below real model sizes.

WASH kinds always use the ``bucketed`` plan mode here (the dense mode has
no collective lowering); everything else — init, data order, key
derivation, optimizer arithmetic, comm accounting — matches the reference
loop exactly, which `tests/test_engine_parity.py` asserts.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.configs.base import TrainConfig
from repro.core import population as pop
from repro.core import shardplan
from repro.core.compat import shard_map
from repro.core.consensus import avg_distance_to_consensus
from repro.core.layer_index import infer_layer_ids, total_layers
from repro.core.mixing import (
    MixingConfig,
    mix_collective_blocked,
    static_mix_comm,
)
from repro.core.prng import step_key
from repro.sharding import rules as sharding_rules
from repro.optim import cosine_lr, make_optimizer
from repro.train.loop import TrainResult
from repro.train.schedule import (  # noqa: F401  (re-exported API)
    ChunkPlan,
    Schedule,
    build_schedule,
    chunk_ranges,
    num_pipeline_ticks,
    record_boundaries,
    split_microbatch_sizes,
)

PyTree = Any

# Counts traces of the fused chunk body (shard_map+jit trace the Python
# body exactly once per compiled executable, so this IS the compile count;
# asserted ≤ 2 per run by tests/test_schedule.py).
_CHUNK_TRACES = [0]


def reset_chunk_trace_count() -> None:
    _CHUNK_TRACES[0] = 0


def chunk_trace_count() -> int:
    return _CHUNK_TRACES[0]


# Below this average steps-per-chunk, CPU runs are FASTER synchronous:
# the per-chunk thread handoff outweighs the overlapped staging work
# (measured ~0.91x sync at 1-step chunks with heavy batch leaves; break
# even by ~3 steps).  On accelerators the host stages while the device
# computes, so the overlap always pays once there is a chunk to overlap.
ASYNC_STAGING_MIN_CHUNK_STEPS = 2


def resolve_async_staging(async_staging: Optional[bool],
                          chunks: List[ChunkPlan],
                          backend: Optional[str] = None) -> bool:
    """Tri-state gate for double-buffered staging.  Explicit True/False
    wins.  ``None`` auto-resolves: off with nothing to overlap (< 2
    chunks), off on CPU when the schedule's average chunk is shorter
    than :data:`ASYNC_STAGING_MIN_CHUNK_STEPS` real steps (the staging
    thread's handoff costs more than it hides there), on otherwise."""
    if async_staging is not None:
        return bool(async_staging)
    if len(chunks) < 2:
        return False
    if backend is None:
        backend = jax.default_backend()
    if backend == "cpu":
        avg = sum(c.length for c in chunks) / len(chunks)
        return avg >= ASYNC_STAGING_MIN_CHUNK_STEPS
    return True


def make_fused_chunk_fn(
    mesh,
    mcfg: MixingConfig,
    layer_ids: PyTree,
    tl: int,
    opt_update: Callable,
    loss_fn: Callable[[PyTree, Any], jax.Array],
    pspec: PyTree,
    ospec: PyTree,
    bspecs: PyTree,
    *,
    with_mixing: bool = True,
    donate: bool = True,
    pplan: Optional[shardplan.PopulationPlan] = None,
    use_pallas: bool = False,
):
    """Build the engine's fused chunk dispatch: one donated jit scanning
    (per-member update → gated collective mix) over a chunk of steps under
    shard_map.  ``with_mixing=False`` builds the collective-free variant
    dispatched on no-mix gate runs (the only other executable the engine
    ever compiles).  Exposed so benchmarks time the SHIPPED engine body
    rather than a copy (``benchmarks/kernels_bench.py``; pass
    ``donate=False`` there so repeated timing calls can reuse inputs).

    ``pplan`` (a :class:`repro.core.shardplan.PopulationPlan`) switches the
    body to the multi-axis mesh layout: the population is sharded over
    ``pplan.pop_axes``, members over ``pplan.dp_axes``-split batches with
    gradients ``pmean``-ed back, model-sharded leaves are all-gathered for
    the black-box ``loss_fn`` and re-sliced for the shard-local optimizer
    update, and mixing runs on shard-local plans
    (:func:`repro.core.shardplan.mix_collective_sharded`).  ``pplan=None``
    keeps the single-``ens``-axis body bit-for-bit unchanged."""
    pop_axes = pplan.pop_axes if pplan is not None else ("ens",)
    dp_axes = pplan.dp_axes if pplan is not None else ()
    # gather/slice only when something actually needs it, so the trivial
    # multi-axis case keeps the exact single-axis dataflow (bitwise parity)
    gathered = pplan is not None and (pplan.any_sharded or bool(dp_axes))
    loss_axes = "ens" if pplan is None else pop_axes + dp_axes

    def chunk_fn(population, opt_state, batches, lrs, keydata, gates, n_valid):
        _CHUNK_TRACES[0] += 1
        # host-side effect at trace time only: the compile counter mirrors
        # the ≤2-executables contract _CHUNK_TRACES guards
        obs.get().record_compile("train_chunk", mixing=bool(with_mixing))

        # the loss rides the fori_loop carry, whose dtype is fixed up
        # front — derive it from loss_fn so non-f32 losses (x64, bf16)
        # keep working like they did under lax.scan's unconstrained ys.
        # Member templates use the FULL member shapes (loss_fn sees
        # gathered leaves when the members are model-sharded); batch
        # templates stay local (loss_fn sees this chip's batch shard).
        if pplan is not None:
            member_sds = jax.tree_util.tree_unflatten(
                pplan.treedef,
                [jax.ShapeDtypeStruct(info.member_shape, x.dtype)
                 for info, x in zip(
                     pplan.infos, jax.tree_util.tree_flatten(population)[0]
                 )],
            )
        else:
            member_sds = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                population,
            )
        loss_sds = jax.eval_shape(
            loss_fn,
            member_sds,
            jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape[2:], x.dtype), batches
            ),
        )

        def body(i, carry):
            p, s, _ = carry
            batch, lr, kd, gate = jax.tree_util.tree_map(
                lambda x: lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
                (batches, lrs, keydata, gates),
            )

            if gathered:
                # FSDP-style step for model-sharded members: gather full
                # leaves for the black-box loss (an exact reconstruction),
                # pmean gradients over any batch-splitting data axes, then
                # slice this chip's shard back for the elementwise
                # optimizer update — bitwise equal to updating the same
                # shard of an unsharded member.
                p_full = shardplan.all_gather_population(p, pplan)
                losses, g_full = jax.vmap(
                    lambda pm, bm: jax.value_and_grad(loss_fn)(pm, bm)
                )(p_full, batch)
                if dp_axes:
                    g_full = jax.tree_util.tree_map(
                        lambda x: lax.pmean(x, dp_axes), g_full
                    )
                g_loc = shardplan.shard_population(g_full, pplan)
                p2, s2 = jax.vmap(
                    lambda pm, gm, sm: opt_update(pm, gm, sm, lr)
                )(p, g_loc, s)
            else:
                def one(pm, sm, bm):
                    loss, g = jax.value_and_grad(loss_fn)(pm, bm)
                    p2_, s2_ = opt_update(pm, g, sm, lr)
                    return p2_, s2_, loss

                p2, s2, losses = jax.vmap(one)(p, s, batch)

            if with_mixing:
                k = jax.random.wrap_key_data(kd)
                if pplan is not None:
                    p3, s3 = shardplan.mix_collective_sharded(
                        k, p2, s2, mcfg, pplan, gate, use_pallas=use_pallas
                    )
                else:
                    p3, s3 = mix_collective_blocked(
                        k, p2, s2, mcfg, layer_ids, tl, "ens", gate,
                        use_pallas=use_pallas,
                    )
            else:
                p3, s3 = p2, s2
            loss_mean = lax.pmean(jnp.mean(losses), loss_axes)
            if loss_mean.dtype != loss_sds.dtype or getattr(
                loss_mean.aval, "weak_type", False
            ):
                # normalize odd loss dtypes so the carry signature is
                # stable; trace-time check keeps the common path's graph
                # free of an extra convert
                loss_mean = loss_mean.astype(loss_sds.dtype)
            return (p3, s3, loss_mean)

        # A bounded fori_loop, not lax.scan: inputs are padded to the
        # variant's fixed length but pad slots NEVER execute — the traced
        # trip count stops the loop after the chunk's real steps.  This
        # keeps one compile per variant without select-masking the
        # optimizer update (a masking `where` changes XLA's fusion of the
        # update arithmetic by ~1ulp, breaking the bitwise-parity
        # contract) and spends zero FLOPs on pad slots.  lax.scan lowers
        # to the same while+dynamic-slice structure, so the executed
        # per-step dataflow is unchanged.
        p, s, loss_last = lax.fori_loop(
            0, n_valid, body,
            (population, opt_state, jnp.zeros((), loss_sds.dtype)),
        )
        return p, s, loss_last

    f = shard_map(
        chunk_fn,
        mesh,
        in_specs=(pspec, ospec, bspecs, P(), P(), P(), P()),
        out_specs=(pspec, ospec, P()),
        check_vma=False,
    )
    return jax.jit(f, donate_argnums=(0, 1) if donate else ())


class StageFns(NamedTuple):
    """The three pieces of a member's loss for the pipelined engine.

    The engine never inspects where the blocks live — stage-splitting is
    done entirely by the PartitionSpecs
    (:func:`repro.sharding.rules.stage_member_specs`), so ``blocks``
    receives the full member params and reads its (stage-local, under
    ``shard_map``) stacked-blocks leaves itself.
    """

    embed: Callable[[PyTree, Any], jax.Array]          # (params, batch) -> x
    blocks: Callable[[PyTree, jax.Array], jax.Array]   # (params, x) -> x
    head: Callable[[PyTree, jax.Array, Any], jax.Array]  # -> scalar loss


def make_pipelined_chunk_fn(
    mesh,
    mcfg: MixingConfig,
    layer_ids: PyTree,
    tl: int,
    opt_update: Callable,
    stage_fns: StageFns,
    pspec: PyTree,
    ospec: PyTree,
    bspecs: PyTree,
    *,
    num_micro: int,
    pplan: shardplan.PopulationPlan,
    with_mixing: bool = True,
    donate: bool = True,
    use_pallas: bool = False,
):
    """Pipeline-parallel variant of :func:`make_fused_chunk_fn`.

    One donated jit scanning (microbatched pipelined update → gated
    collective mix) over a chunk of steps under ``shard_map`` on a mesh
    with a ``pipe`` axis.  Each step runs a GPipe-style schedule of
    ``num_micro + S - 1`` ticks inside a ``lax.scan``: at tick ``t``
    stage ``s`` runs microbatch ``t - s`` through its block slice and
    ships the boundary activation to stage ``s + 1`` with a single
    ``ppermute`` over ``pipe``; ticks outside a stage's live window
    compute masked junk that never reaches the loss.  Reverse-mode AD
    transposes the ``ppermute`` chain into the backward pipeline
    automatically, so one ``value_and_grad`` gives exact microbatch-
    accumulated gradients (mean of per-microbatch means — equal
    microbatch sizes are enforced by the driver).  Pipe-replicated
    leaves (embed/head/norms) get their gradients ``psum``-med over
    ``pipe`` (each stage contributes only its own, mostly-zero slice of
    the chain rule), which also keeps their replicas bitwise in sync.

    The ≤2-trace contract, the donated-buffer discipline, and the
    fori_loop trip-count padding are inherited unchanged.
    """
    S = int(mesh.shape["pipe"])
    num_ticks = num_pipeline_ticks(num_micro, S)
    pipe_perm = [(s_, s_ + 1) for s_ in range(S - 1)]
    dp_axes = pplan.dp_axes
    loss_axes = pplan.pop_axes + dp_axes
    flat_lids = jax.tree_util.tree_flatten(layer_ids)[0]

    def _sync_pipe_grads(g):
        """psum pipe-replicated (non-stage-split) leaves' grads over pipe."""
        flat, td = jax.tree_util.tree_flatten(g)
        out = [
            gl if not isinstance(lid, int) else lax.psum(gl, "pipe")
            for gl, lid in zip(flat, flat_lids)
        ]
        return jax.tree_util.tree_unflatten(td, out)

    def chunk_fn(population, opt_state, batches, lrs, keydata, gates, n_valid):
        _CHUNK_TRACES[0] += 1
        obs.get().record_compile(
            "train_chunk_pipelined", mixing=bool(with_mixing)
        )
        sid = lax.axis_index("pipe")

        def member_loss(pm, mb):
            # mb leaves are (num_micro, b, ...); losses accumulate in f32
            x_sds = jax.eval_shape(
                stage_fns.embed, pm,
                jax.tree_util.tree_map(lambda x: x[0], mb),
            )

            def tick(carry, t):
                recv, acc = carry
                m = t - sid
                mi = jnp.clip(m, 0, num_micro - 1)
                mbt = jax.tree_util.tree_map(
                    lambda x: lax.dynamic_index_in_dim(
                        x, mi, 0, keepdims=False
                    ),
                    mb,
                )
                x0 = stage_fns.embed(pm, mbt)
                y = stage_fns.blocks(pm, jnp.where(sid == 0, x0, recv))
                lv = stage_fns.head(pm, y, mbt)
                active = (m >= 0) & (m < num_micro) & (sid == S - 1)
                acc = acc + jnp.where(active, lv.astype(jnp.float32), 0.0)
                sent = lax.ppermute(y, "pipe", perm=pipe_perm)
                return (sent, acc), None

            (_, acc), _ = lax.scan(
                tick,
                (jnp.zeros(x_sds.shape, x_sds.dtype),
                 jnp.zeros((), jnp.float32)),
                jnp.arange(num_ticks, dtype=jnp.int32),
            )
            # nonzero only on the last stage; _sync_pipe_grads/psum below
            # restore the global view
            return acc / num_micro

        def body(i, carry):
            p, s, _ = carry
            batch, lr, kd, gate = jax.tree_util.tree_map(
                lambda x: lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
                (batches, lrs, keydata, gates),
            )
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(
                    (x.shape[0], num_micro, x.shape[1] // num_micro)
                    + x.shape[2:]
                ),
                batch,
            )
            losses, g = jax.vmap(
                lambda pm, bm: jax.value_and_grad(member_loss)(pm, bm)
            )(p, micro)
            g = _sync_pipe_grads(g)
            if dp_axes:
                g = jax.tree_util.tree_map(
                    lambda x: lax.pmean(x, dp_axes), g
                )
            p2, s2 = jax.vmap(
                lambda pm, gm, sm: opt_update(pm, gm, sm, lr)
            )(p, g, s)
            if with_mixing:
                k = jax.random.wrap_key_data(kd)
                p3, s3 = shardplan.mix_collective_sharded(
                    k, p2, s2, mcfg, pplan, gate, use_pallas=use_pallas
                )
            else:
                p3, s3 = p2, s2
            loss_mean = lax.pmean(
                jnp.mean(lax.psum(losses, "pipe")), loss_axes
            )
            return (p3, s3, loss_mean.astype(jnp.float32))

        p, s, loss_last = lax.fori_loop(
            0, n_valid, body,
            (population, opt_state, jnp.zeros((), jnp.float32)),
        )
        return p, s, loss_last

    f = shard_map(
        chunk_fn,
        mesh,
        in_specs=(pspec, ospec, bspecs, P(), P(), P(), P()),
        out_specs=(pspec, ospec, P()),
        check_vma=False,
    )
    return jax.jit(f, donate_argnums=(0, 1) if donate else ())


def train_population_sharded(
    key: jax.Array,
    init_fn: Callable[[jax.Array], PyTree],
    loss_fn: Callable[[PyTree, Any], jax.Array],
    data_fn: Callable[[int, int, jax.Array], Any],
    tcfg: TrainConfig,
    mcfg: MixingConfig,
    num_blocks: int,
    record_every: int = 25,
    record_fn: Optional[Callable[[int, PyTree], Dict[str, float]]] = None,
    mesh=None,
    async_staging: Optional[bool] = None,
    split_gate_runs: bool = True,
    param_specs=None,
    pallas_shuffle: bool = False,
) -> TrainResult:
    """Drop-in replacement for :func:`repro.train.loop.train_population`
    running the fused shard_map engine.  Same signature plus an optional
    ``mesh`` (default: the host's ``ens``-only mesh; 2D/3D
    ``(ens[, data][, model])`` meshes route mixing through the shard-local
    planner — see :mod:`repro.core.shardplan` — and shard batches over the
    data axes), ``async_staging`` (double-buffer chunk k+1's batches on a
    staging thread while chunk k executes; ``None`` auto-gates via
    :func:`resolve_async_staging` — off on CPU schedules whose chunks are
    too short to amortize the thread handoff), ``split_gate_runs`` (dispatch
    no-mix spans on the collective-free executable; see
    :mod:`repro.train.schedule`), ``param_specs`` (member-level
    ``PartitionSpec``s, e.g. from :func:`repro.sharding.rules.param_pspecs`;
    requires a mesh with the named axes) and ``pallas_shuffle`` (apply
    bucketed shuffles through the fused Pallas kernel where the exchange
    is chip-local)."""
    if mcfg.kind in ("wash", "wash_opt") and mcfg.mode != "bucketed":
        raise ValueError(
            f"engine='shard_map' only lowers bucketed WASH plans; got "
            f"mode={mcfg.mode!r}.  Use mode='bucketed' (identical in "
            f"expectation, Eq. 4) or engine='vmap' for dense plans."
        )
    n = tcfg.population
    if mesh is None:
        from repro.launch.mesh import make_host_ensemble_mesh

        mesh = make_host_ensemble_mesh(n)
    multi = len(mesh.axis_names) > 1
    if param_specs is not None and not multi:
        raise ValueError(
            "param_specs shard members over mesh axes; pass a multi-axis "
            "mesh (e.g. repro.launch.mesh.make_host_mesh) along with them"
        )

    population = pop.init_population(init_fn, key, n, same_init=tcfg.same_init)
    lids = infer_layer_ids(pop.member(population, 0), num_blocks)
    tl = total_layers(num_blocks)

    opt_init, opt_update = make_optimizer(
        tcfg.optimizer, momentum=tcfg.momentum, weight_decay=tcfg.weight_decay
    )
    opt_state = jax.vmap(opt_init)(population)

    # exact per-mix-step comm from the static plan sizes (member template:
    # shapes only, no data copy); never None here — dense WASH was rejected
    member_tpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), population
    )

    use_pallas = pallas_shuffle or mcfg.pallas_shuffle
    if multi:
        member_specs = (
            param_specs if param_specs is not None
            else jax.tree_util.tree_map(lambda _: P(), member_tpl)
        )
        pplan = shardplan.plan_population_mixing(
            mesh, member_tpl, member_specs, mcfg, lids, tl, n
        )
        pspec = sharding_rules.population_pspecs(member_specs, pplan.pop_axes)
        ospec = sharding_rules.opt_pspecs(opt_state, pspec, pplan.pop_axes)
        comm_per_mix_step = shardplan.static_shard_mix_comm(
            pplan, opt_state=opt_state
        )
        pop_entry = (
            pplan.pop_axes[0] if len(pplan.pop_axes) == 1
            else tuple(pplan.pop_axes)
        )
        dp_sizes = 1
        for a in pplan.dp_axes:
            dp_sizes *= pplan.size(a)
    else:
        pplan = None
        m = int(mesh.shape["ens"])
        assert n % m == 0, f"population {n} must divide over ens axis of size {m}"
        pspec = jax.tree_util.tree_map(lambda _: P("ens"), population)
        ospec = jax.tree_util.tree_map(lambda _: P("ens"), opt_state)
        comm_per_mix_step = static_mix_comm(
            member_tpl, mcfg, lids, tl, n, opt_state=opt_state
        )
        pop_entry = "ens"
        dp_sizes = 1
    assert comm_per_mix_step is not None

    # Leftover data axes split each member's batch only when EVERY batch
    # leaf's leading dim divides (all-or-nothing, so a split leaf never
    # pairs with a replicated one inside a shard); otherwise batches
    # replicate over dp and the gradient pmean is an exact identity.
    split_batch_over_dp = False
    if pplan is not None and pplan.dp_axes:
        try:
            probe = jax.eval_shape(
                lambda k: data_fn(0, 0, k), jax.random.fold_in(key, 0)
            )
        except Exception:  # non-traceable data_fn: probe with a real call
            probe = data_fn(0, 0, jax.random.fold_in(key, 0))
        split_batch_over_dp = all(
            leaf.shape and leaf.shape[0] % dp_sizes == 0
            for leaf in jax.tree_util.tree_leaves(probe)
        )

    def _batch_leaf_spec(shape) -> P:
        """(pad_len, n, B, ...) leaf: member axis over the population axes,
        the per-member batch over leftover data axes when they split."""
        if split_batch_over_dp:
            return P(None, pop_entry, tuple(pplan.dp_axes))
        return P(None, pop_entry)

    sched = build_schedule(
        tcfg.total_steps, record_every, mcfg, split_gate_runs=split_gate_runs
    )

    fused: Dict[bool, Callable] = {}  # variant (with_mixing) -> donated jit

    def get_fused(chunk: ChunkPlan, batches):
        if chunk.mixing not in fused:
            bspecs = jax.tree_util.tree_map(
                lambda x: _batch_leaf_spec(x.shape), batches
            )
            fused[chunk.mixing] = make_fused_chunk_fn(
                mesh, mcfg, lids, tl, opt_update, loss_fn,
                pspec, ospec, bspecs, with_mixing=chunk.mixing,
                pplan=pplan, use_pallas=use_pallas,
            )
        return fused[chunk.mixing]

    return _run_chunked_schedule(
        mesh=mesh, n=n, tcfg=tcfg, data_fn=data_fn, sched=sched,
        get_fused=get_fused, population=population, opt_state=opt_state,
        comm_per_mix_step=comm_per_mix_step, record_fn=record_fn,
        batch_leaf_spec=_batch_leaf_spec, key=key,
        async_staging=async_staging,
    )


def _run_chunked_schedule(
    *,
    mesh,
    n: int,
    tcfg: TrainConfig,
    data_fn: Callable,
    sched: Schedule,
    get_fused: Callable,
    population: PyTree,
    opt_state: PyTree,
    comm_per_mix_step: float,
    record_fn,
    batch_leaf_spec: Callable,
    key: jax.Array,
    async_staging: Optional[bool],
) -> TrainResult:
    """The engines' shared dispatch loop: stage each chunk's inputs
    (double-buffered on a staging thread when
    :func:`resolve_async_staging` allows), run its donated executable,
    accumulate exact host-side comm, and record history at the reference
    loop's boundaries.  Shared verbatim by the single-stage and pipelined
    engines — key derivation, padding, and staging are identical, so the
    pipelined engine inherits the bitwise data order."""
    base_key = jax.random.fold_in(key, 1234)
    data_key = jax.random.fold_in(key, 5678)
    rep_sharding = NamedSharding(mesh, P())

    def stage(chunk: ChunkPlan):
        """Stack a chunk's inputs, pad to the variant's fixed scan length
        (pad slots replicate the last real step; they sit past the fused
        loop's trip count and never execute), and start the device
        transfers.  Runs on the staging thread."""
        steps = list(chunk.steps)
        member_batches = []
        for step in steps:
            dk = jax.random.fold_in(data_key, step)
            member_batches += [
                data_fn(mm, step, jax.random.fold_in(dk, mm)) for mm in range(n)
            ]
        member_batches += member_batches[-n:] * chunk.pad
        # one stack per leaf for the whole (pad_len, n, ...) block — not a
        # stack per step then per chunk.  data_fn outputs live on device
        # (jax.random), so host-side np.stack would force a sync instead
        # of saving one; the single device stack keeps staging dispatches
        # at one per leaf.
        batches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs).reshape(
                (chunk.pad_len, n) + xs[0].shape
            ),
            *member_batches,
        )
        lr_list = [
            cosine_lr(s, tcfg.total_steps, tcfg.lr, tcfg.min_lr, tcfg.warmup_steps)
            for s in steps
        ]
        lrs = jnp.stack(lr_list + [lr_list[-1]] * chunk.pad)
        kd_list = [jax.random.key_data(step_key(base_key, s)) for s in steps]
        keydata = jnp.stack(kd_list + [kd_list[-1]] * chunk.pad)
        gates = jnp.asarray(chunk.padded_gates(), jnp.float32)
        # trip count of the fused fori_loop: pad slots past it never execute
        n_valid = jnp.asarray(chunk.length, jnp.int32)

        batches = jax.device_put(batches, jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, batch_leaf_spec(x.shape)), batches
        ))
        lrs, keydata, gates, n_valid = jax.device_put(
            (lrs, keydata, gates, n_valid), rep_sharding
        )
        return batches, lrs, keydata, gates, n_valid

    history: Dict[str, List[float]] = {
        "step": [], "loss": [], "consensus": [], "comm": []
    }
    comm_total = 0.0
    chunks = sched.chunks
    executor = (
        ThreadPoolExecutor(max_workers=1, thread_name_prefix="wash-stage")
        if resolve_async_staging(async_staging, chunks) and len(chunks) > 1
        else None
    )

    tel = obs.get()
    # mirrors comm_total add-for-add (same value, same order, from 0.0),
    # so the counter snapshot bit-equals the exact float64 accounting
    comm_counter = tel.registry.counter("train.comm_scalars") if tel.enabled else None

    def staged_timed(chunk: ChunkPlan):
        # runs on the staging thread when double-buffered: the histogram's
        # total vs wall time is the staging-thread occupancy
        with tel.span("train.stage", step=chunk.stop - 1):
            return stage(chunk)

    t0 = time.time()
    try:
        nxt = executor.submit(staged_timed, chunks[0]) if executor else None
        for i, chunk in enumerate(chunks):
            staged = nxt.result() if executor else staged_timed(chunk)
            if executor and i + 1 < len(chunks):
                # double buffering: the staging thread builds chunk i+1's
                # inputs while the devices execute chunk i
                nxt = executor.submit(staged_timed, chunks[i + 1])

            with tel.span("train.chunk_execute", step=chunk.stop - 1,
                          mixing=chunk.mixing):
                population, opt_state, loss_last = get_fused(
                    chunk, staged[0]
                )(population, opt_state, *staged)
            mix_steps = 0
            for g in chunk.gates:  # per-step float64 adds, as the reference
                if g:
                    comm_total += comm_per_mix_step
                    mix_steps += 1
                    if comm_counter is not None:
                        comm_counter.inc(comm_per_mix_step)
            if mix_steps and tel.enabled:
                tel.event("train.comm_volume",
                          comm_per_mix_step=comm_per_mix_step,
                          mix_steps=mix_steps, comm_total=comm_total)

            if chunk.record:
                step = chunk.stop - 1  # chunk boundary == record boundary
                history["step"].append(step)
                history["loss"].append(float(loss_last))
                history["consensus"].append(
                    float(avg_distance_to_consensus(population))
                )
                history["comm"].append(comm_total)
                extras = {}
                if record_fn is not None:
                    for k_, v in record_fn(step, population).items():
                        history.setdefault(k_, []).append(v)
                        extras[k_] = v
                if tel.enabled:
                    wall = time.time() - t0
                    if wall > 0:
                        tel.registry.gauge("train.steps_per_s").set(
                            chunk.stop / wall
                        )
                    tel.event("train.record", step=step,
                              loss=history["loss"][-1],
                              consensus=history["consensus"][-1],
                              comm=comm_total, **extras)
    finally:
        if executor is not None:
            executor.shutdown(wait=True)

    history["wall_s"] = [time.time() - t0]
    if tel.enabled:
        tel.registry.gauge("train.wall_s").set(history["wall_s"][0])
    return TrainResult(population, opt_state, history, comm_total)


def train_population_pipelined(
    key: jax.Array,
    init_fn: Callable[[jax.Array], PyTree],
    stage_fns,
    data_fn: Callable[[int, int, jax.Array], Any],
    tcfg: TrainConfig,
    mcfg: MixingConfig,
    num_blocks: int,
    record_every: int = 25,
    record_fn: Optional[Callable[[int, PyTree], Dict[str, float]]] = None,
    mesh=None,
    microbatches: int = 1,
    async_staging: Optional[bool] = None,
    split_gate_runs: bool = True,
    param_specs=None,
    pallas_shuffle: bool = False,
) -> TrainResult:
    """Pipeline-parallel counterpart of :func:`train_population_sharded`.

    Takes :class:`StageFns` ``(embed, blocks, head)`` instead of a
    monolithic ``loss_fn`` so the engine can cut the forward pass at the
    stage boundaries; ``mesh`` must carry a ``pipe`` axis
    (``launch.mesh`` kinds ``ens_pp``/``ens_dp_pp``).  Each member's
    stacked-blocks leaves are sharded over ``pipe``
    (:func:`repro.sharding.rules.stage_member_specs`) into contiguous
    stages; every optimizer step splits its batch into ``microbatches``
    equal microbatches and runs the GPipe schedule of
    :func:`make_pipelined_chunk_fn`.  WASH mixing runs on per-stage
    plans whose ppermute rings stay inside each stage's ens slice
    (:mod:`repro.core.shardplan`).

    Parity contract (asserted by ``tests/test_pipeline.py``): with one
    stage and one microbatch this delegates to the fused single-stage
    engine and is bitwise-identical to it; with ``S > 1`` the result
    matches to numerical tolerance (microbatch gradient accumulation is
    a mean of per-microbatch means, which reorders float sums).
    """
    if microbatches < 1:
        raise ValueError(f"microbatches must be >= 1, got {microbatches}")
    n = tcfg.population
    if mesh is None:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(n, "ens_pp")
    if "pipe" not in mesh.axis_names:
        raise ValueError(
            f"the pipelined engine needs a mesh with a 'pipe' axis "
            f"(launch.mesh kinds ens_pp/ens_dp_pp); got {mesh.axis_names}"
        )
    sf = StageFns(*stage_fns)
    S = int(mesh.shape["pipe"])

    if S == 1 and microbatches == 1:
        # the degenerate pipeline IS the single-stage engine: compose the
        # loss and delegate, so (E, 1, 1, S=1) meshes are bitwise-identical
        # to the existing fused path (size-1 axes drop out of the
        # classification and the specs)
        def loss_fn(pm, b):
            return sf.head(pm, sf.blocks(pm, sf.embed(pm, b)), b)

        return train_population_sharded(
            key, init_fn, loss_fn, data_fn, tcfg, mcfg, num_blocks,
            record_every=record_every, record_fn=record_fn, mesh=mesh,
            async_staging=async_staging, split_gate_runs=split_gate_runs,
            param_specs=param_specs, pallas_shuffle=pallas_shuffle,
        )

    if mcfg.kind in ("wash", "wash_opt") and mcfg.mode != "bucketed":
        raise ValueError(
            f"engine='shard_map' only lowers bucketed WASH plans; got "
            f"mode={mcfg.mode!r}."
        )

    population = pop.init_population(init_fn, key, n, same_init=tcfg.same_init)
    lids = infer_layer_ids(pop.member(population, 0), num_blocks)
    tl = total_layers(num_blocks)

    flat_lids = jax.tree_util.tree_flatten(lids)[0]
    if not any(not isinstance(l, int) for l in flat_lids):
        raise ValueError(
            "stage-split training needs stacked-blocks leaves (one leaf "
            "spanning all blocks along axis 0); this member has only "
            "per-block leaves, which cannot be sharded over the pipe axis"
        )
    for lid, leaf in zip(flat_lids, jax.tree_util.tree_leaves(
            pop.member(population, 0))):
        if not isinstance(lid, int) and leaf.shape[0] % S:
            raise ValueError(
                f"stacked-blocks leaf of {leaf.shape[0]} layers does not "
                f"split evenly over {S} pipeline stages"
            )

    opt_init, opt_update = make_optimizer(
        tcfg.optimizer, momentum=tcfg.momentum, weight_decay=tcfg.weight_decay
    )
    opt_state = jax.vmap(opt_init)(population)

    member_tpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), population
    )
    member_specs = (
        param_specs if param_specs is not None
        else jax.tree_util.tree_map(lambda _: P(), member_tpl)
    )
    stage_specs = sharding_rules.stage_member_specs(member_specs, lids, "pipe")
    pplan = shardplan.plan_population_mixing(
        mesh, member_tpl, stage_specs, mcfg, lids, tl, n
    )
    pspec = sharding_rules.population_pspecs(stage_specs, pplan.pop_axes)
    ospec = sharding_rules.opt_pspecs(opt_state, pspec, pplan.pop_axes)
    comm_per_mix_step = shardplan.static_shard_mix_comm(
        pplan, opt_state=opt_state
    )
    pop_entry = (
        pplan.pop_axes[0] if len(pplan.pop_axes) == 1
        else tuple(pplan.pop_axes)
    )
    dp_sizes = 1
    for a in pplan.dp_axes:
        dp_sizes *= pplan.size(a)

    try:
        probe = jax.eval_shape(
            lambda k: data_fn(0, 0, k), jax.random.fold_in(key, 0)
        )
    except Exception:  # non-traceable data_fn: probe with a real call
        probe = data_fn(0, 0, jax.random.fold_in(key, 0))
    split_batch_over_dp = bool(pplan.dp_axes) and all(
        leaf.shape and leaf.shape[0] % dp_sizes == 0
        for leaf in jax.tree_util.tree_leaves(probe)
    )
    for leaf in jax.tree_util.tree_leaves(probe):
        local_b = leaf.shape[0] // (dp_sizes if split_batch_over_dp else 1)
        split_microbatch_sizes(local_b, microbatches)

    def _batch_leaf_spec(shape) -> P:
        if split_batch_over_dp:
            return P(None, pop_entry, tuple(pplan.dp_axes))
        return P(None, pop_entry)

    sched = build_schedule(
        tcfg.total_steps, record_every, mcfg, split_gate_runs=split_gate_runs
    )
    use_pallas = pallas_shuffle or mcfg.pallas_shuffle

    fused: Dict[bool, Callable] = {}

    def get_fused(chunk: ChunkPlan, batches):
        if chunk.mixing not in fused:
            bspecs = jax.tree_util.tree_map(
                lambda x: _batch_leaf_spec(x.shape), batches
            )
            fused[chunk.mixing] = make_pipelined_chunk_fn(
                mesh, mcfg, lids, tl, opt_update, sf,
                pspec, ospec, bspecs, num_micro=microbatches,
                with_mixing=chunk.mixing, pplan=pplan,
                use_pallas=use_pallas,
            )
        return fused[chunk.mixing]

    return _run_chunked_schedule(
        mesh=mesh, n=n, tcfg=tcfg, data_fn=data_fn, sched=sched,
        get_fused=get_fused, population=population, opt_state=opt_state,
        comm_per_mix_step=comm_per_mix_step, record_fn=record_fn,
        batch_leaf_spec=_batch_leaf_spec, key=key,
        async_staging=async_staging,
    )
