"""Optimizers built from scratch (no optax in this container).

State layout is a dict {"mu": pytree, ["nu": pytree], "step": scalar} —
``mu``/``nu`` mirror the parameter structure so WASH+Opt can replay the
parameter shuffle plan on them verbatim (see repro.core.mixing).
"""

from repro.optim.optimizers import (
    adamw_init,
    adamw_update,
    cosine_lr,
    make_optimizer,
    sgd_init,
    sgd_update,
)

__all__ = [
    "sgd_init",
    "sgd_update",
    "adamw_init",
    "adamw_update",
    "cosine_lr",
    "make_optimizer",
]
