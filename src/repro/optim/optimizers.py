"""SGD+momentum (the paper's optimizer) and AdamW, plus cosine annealing."""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _zeros_like_f32(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


# ---------------------------------------------------------------------------
# SGD with momentum + decoupled weight decay (paper §4: SGD, momentum, wd 1e-4)
# ---------------------------------------------------------------------------


def sgd_init(params: PyTree) -> dict:
    return {"mu": _zeros_like_f32(params), "step": jnp.zeros((), jnp.int32)}


def sgd_update(
    params: PyTree,
    grads: PyTree,
    state: dict,
    lr,
    momentum: float = 0.9,
    weight_decay: float = 1e-4,
) -> Tuple[PyTree, dict]:
    def upd(p, g, m):
        gf = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m = momentum * m + gf
        return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state["mu"])[0]
    new = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_p = jax.tree_util.tree_unflatten(treedef, [a for a, _ in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [b for _, b in new])
    return new_p, {"mu": new_m, "step": state["step"] + 1}


# ---------------------------------------------------------------------------
# AdamW (for the LLM examples; WASH+Opt shuffles both moments)
# ---------------------------------------------------------------------------


def adamw_init(params: PyTree) -> dict:
    return {
        "mu": _zeros_like_f32(params),
        "nu": _zeros_like_f32(params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params: PyTree,
    grads: PyTree,
    state: dict,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[PyTree, dict]:
    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        update = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state["mu"])[0]
    flat_v = jax.tree_util.tree_flatten(state["nu"])[0]
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    return (
        jax.tree_util.tree_unflatten(treedef, [a for a, _, _ in new]),
        {
            "mu": jax.tree_util.tree_unflatten(treedef, [b for _, b, _ in new]),
            "nu": jax.tree_util.tree_unflatten(treedef, [c for _, _, c in new]),
            "step": step,
        },
    )


# ---------------------------------------------------------------------------
# schedules / factory
# ---------------------------------------------------------------------------


def cosine_lr(step, total_steps: int, base_lr: float, min_lr: float, warmup: int = 0):
    """Cosine annealing with optional linear warmup (paper: 0.1 -> 1e-4)."""
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
    cos = min_lr + 0.5 * (base_lr - min_lr) * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)


def make_optimizer(name: str, **kw) -> Tuple[Callable, Callable]:
    if name == "sgd":
        return sgd_init, lambda p, g, s, lr: sgd_update(
            p, g, s, lr,
            momentum=kw.get("momentum", 0.9),
            weight_decay=kw.get("weight_decay", 1e-4),
        )
    if name == "adamw":
        return adamw_init, lambda p, g, s, lr: adamw_update(
            p, g, s, lr, weight_decay=kw.get("weight_decay", 0.1)
        )
    raise ValueError(f"unknown optimizer {name!r}")
