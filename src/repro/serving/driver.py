"""Async request driver over the continuous-batching runtime.

``ContinuousServer`` is a scheduler: give it a batch of requests and it
drains them.  Production traffic is not a batch — requests arrive on
their own clock, want their first token quickly even when someone else's
4k-token prompt is mid-prefill, and read tokens as a stream, not a final
array.  The driver adds that front-end:

  * **Request queue + admission control** — ``submit`` validates and
    queues; admission into the server is strictly FIFO (the property
    tests assert it), and an optional token budget
    (``max_queued_tokens``) pushes back on producers with
    :class:`QueueFull` instead of letting the queue grow unboundedly.
  * **Chunked prefill, interleaved** — each :meth:`tick` runs at most ONE
    prompt chunk (``prefill_chunk`` tokens, round-robin across every
    admission in progress) and ONE decode step for the in-flight set.
    A long prompt therefore stalls running streams for one chunk, not
    one prompt, bounding inter-token gaps — and short prompts admitted
    behind it finish their own (single-chunk) prefills between its
    chunks, bounding their TTFT.  ``benchmarks/serving_bench.py``
    measures exactly these two tails against whole-prompt prefill.
  * **Streaming callbacks** — per-request ``on_token(uid, token)`` /
    ``on_finish(uid, result)``; :meth:`astream` adapts them to an asyncio
    generator (with :meth:`start`'s pump thread doing the jax work, so an
    event loop never blocks on a decode step).
  * **Metrics** — per-request arrival/admission/first-token/finish
    timestamps and per-token times; :func:`summarize` folds them into
    p50/p99 TTFT, p99 inter-token gap, and tokens/sec.

The driver changes WHEN programs run, never WHAT they compute: per-request
tokens stay bitwise-identical to ``generate_reference``, the decode step
still compiles once per pool geometry, and prefill compiles once per
chunk length (``tests/test_driver_properties.py`` holds all three under
randomized streams, cancellations included).

Example::

    server = ContinuousServer(params, cfg, page_size=16, max_slots=8,
                              retain_pages=True)
    driver = RequestDriver(server, prefill_chunk=64)
    driver.submit(Request(0, prompt, max_new=32),
                  on_token=lambda uid, tok: print(tok))
    driver.drain()                       # or: driver.run(timed_arrivals)
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.serving.batching import ContinuousServer, Request, Result

__all__ = ["QueueFull", "RequestMetrics", "RequestDriver",
           "poisson_arrivals", "summarize"]

#: bucket edges for the speculative burst-size histogram (tokens emitted
#: to one stream by one tick; bounded by the server's draft_k)
SPEC_BURST_EDGES = (1.5, 2.5, 3.5, 4.5, 6.5, 8.5, 16.5)


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the queued-token budget is exhausted —
    the backpressure signal; retry after tokens drain."""


@dataclasses.dataclass
class RequestMetrics:
    """Wall-clock accounting for one request (all times from the driver's
    ``clock``, typically ``time.perf_counter``)."""

    uid: Any
    arrival: float
    admitted: Optional[float] = None      # pages + slot reserved
    first_token: Optional[float] = None   # prefill done, token0 sampled
    finished: Optional[float] = None
    cancelled: bool = False
    tokens: Optional[np.ndarray] = None   # prompt + generated, on finish
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        return (None if self.first_token is None
                else self.first_token - self.arrival)

    @property
    def latency(self) -> Optional[float]:
        return None if self.finished is None else self.finished - self.arrival


@dataclasses.dataclass
class _Stream:
    """Driver-side state of one submitted request."""

    request: Request
    on_token: Optional[Callable[[Any, int], None]]
    on_finish: Optional[Callable[[Any, Optional[Result]], None]]
    emitted: int = 0  # generated tokens already delivered


def _cost(req: Request) -> int:
    return int(np.asarray(req.tokens).size) + int(req.max_new)


class RequestDriver:
    """Ticks a :class:`ContinuousServer` under live traffic.

    Parameters
    ----------
    server : the continuous-batching runtime to drive.  Construct it with
        ``retain_pages=True`` to keep shared-prompt pages warm across
        requests (the driver is the long-lived use case LRU retention is
        for).
    prefill_chunk : max tokens per prefill program call (None = each
        admission's whole uncached suffix in one call — the "whole-prompt
        prefill" baseline).  Ignored when the server's config forces the
        legacy whole-prompt admit (``server.suffix_prefill`` False).
    max_queued_tokens : queued-token budget — the sum of ``S + max_new``
        over not-yet-admitted requests ``submit`` may hold before raising
        :class:`QueueFull`.  None = unbounded.  A request that alone
        exceeds the budget is still accepted on an empty queue (it could
        otherwise never be served).
    clock : timestamp source for metrics (injectable for tests).
    """

    def __init__(self, server: ContinuousServer, *,
                 prefill_chunk: Optional[int] = None,
                 max_queued_tokens: Optional[int] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1 (or None)")
        self.server = server
        self.prefill_chunk = prefill_chunk
        self.max_queued_tokens = max_queued_tokens
        self._clock = clock
        self._lock = threading.RLock()
        self._pending: deque = deque()           # validated, not admitted
        self._queued_tokens = 0
        self._prefilling: deque = deque()        # _Prefill handles, RR order
        self._streams: Dict[Any, _Stream] = {}   # submitted, not finished
        self.metrics: Dict[Any, RequestMetrics] = {}
        self.admitted_order: List[Any] = []      # FIFO-fairness witness
        self._pump: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- submission ------------------------------------------------------

    def submit(self, request: Request, *,
               on_token: Optional[Callable[[Any, int], None]] = None,
               on_finish: Optional[Callable[[Any, Optional[Result]], None]]
               = None) -> None:
        """Queue a request.  Raises :class:`QueueFull` when the token
        budget is exhausted, ``ValueError`` on invalid requests (empty
        prompt, missing sample key, duplicate pending uid, oversized)."""
        with self._lock:
            cost = _cost(request)
            if (self.max_queued_tokens is not None and self._pending
                    and self._queued_tokens + cost > self.max_queued_tokens):
                raise QueueFull(
                    f"queued-token budget exhausted "
                    f"({self._queued_tokens}/{self.max_queued_tokens} held, "
                    f"request {request.uid!r} needs {cost})")
            # server.validate covers slots + prefills in progress; only the
            # driver-side queue is invisible to it
            request = self.server.validate(
                request, pending={r.uid for r in self._pending})
            self._pending.append(request)
            self._queued_tokens += cost
            self._streams[request.uid] = _Stream(request, on_token, on_finish)
            self.metrics[request.uid] = RequestMetrics(
                uid=request.uid, arrival=self._clock())

    def cancel(self, uid: Any) -> bool:
        """Drop a request wherever it is (queued / prefilling / decoding).
        Its pages and slot are released; no result is produced and
        ``on_finish(uid, None)`` fires.  False for unknown uids."""
        with self._lock:
            stream = self._streams.get(uid)
            if stream is None:
                return False
            for req in self._pending:
                if req.uid == uid:
                    self._pending.remove(req)
                    self._queued_tokens -= _cost(req)
                    break
            else:
                for pf in self._prefilling:
                    if pf.uid == uid:
                        self._prefilling.remove(pf)
                        break
                self.server.cancel(uid)
            rec = self.metrics[uid]
            rec.cancelled = True
            rec.finished = self._clock()
            tel = obs.get()
            if tel.enabled:
                tel.registry.counter("serve.requests_cancelled").inc()
            del self._streams[uid]
            if stream.on_finish is not None:
                stream.on_finish(uid, None)
            return True

    # -- the tick --------------------------------------------------------

    @property
    def has_work(self) -> bool:
        # taken under the lock: run() polls this from the caller's thread
        # while the pump thread mutates _pending/_prefilling (RLock, so
        # lock-held callers like drain() re-enter freely)
        with self._lock:
            return bool(self._pending or self._prefilling
                        or self.server.active_slots)

    def tick(self) -> bool:
        """One scheduling round: admit whatever fits (FIFO), run ONE
        prefill chunk (round-robin over admissions in progress), run ONE
        decode step for the in-flight set.  Returns False when there was
        nothing to do."""
        with self._lock:
            return self._tick()

    def _tick(self) -> bool:
        srv = self.server
        worked = False

        # 1. admission — strictly FIFO; a blocked head blocks everyone
        while self._pending:
            req = self._pending[0]
            if srv.suffix_prefill:
                pf = srv._begin_admit(req)
                if pf is None:
                    break
                self._prefilling.append(pf)
            else:
                if not srv._try_admit_legacy(req):
                    break
            self._pending.popleft()
            self._queued_tokens -= _cost(req)
            self.admitted_order.append(req.uid)
            self.metrics[req.uid].admitted = self._clock()
            if not srv.suffix_prefill:  # legacy admit prefilled in full
                self._after_prefill(req.uid)
            worked = True

        # 2. one prefill chunk, round-robin across admissions in progress
        if self._prefilling:
            pf = self._prefilling.popleft()
            if srv._prefill_step(pf, self.prefill_chunk):
                self._after_prefill(pf.uid)
            else:
                self._prefilling.append(pf)
            worked = True

        # 3. one decode step for everyone in flight
        if srv.active_slots:
            retired = srv.step()  # server queue is empty: no hidden admits
            now = self._clock()
            for slot in srv._slots:
                if slot is not None and slot.uid in self._streams:
                    self._emit(slot.uid, slot.out, now)
            for uid in retired:
                if uid in self._streams:
                    result = srv._results[uid]
                    S = len(self._streams[uid].request.tokens)
                    self._emit(uid, result.tokens[S:], now)
                    self._finish(uid, result, now)
            worked = True
        return worked

    def _after_prefill(self, uid: Any) -> None:
        """Prefill completed this tick: token0 exists — stream it, and
        close out max_new==1 requests (already retired by the server)."""
        now = self._clock()
        srv = self.server
        for slot in srv._slots:
            if slot is not None and slot.uid == uid:
                self._emit(uid, slot.out, now)
                return
        result = srv._results.get(uid)  # max_new == 1: retired at admit
        if result is not None and uid in self._streams:
            S = result.tokens.size - self._streams[uid].request.max_new
            self._emit(uid, result.tokens[S:], now)
            self._finish(uid, result, now)

    def _emit(self, uid: Any, generated: Sequence[int], now: float) -> None:
        stream = self._streams[uid]
        rec = self.metrics[uid]
        burst = 0
        for tok in list(generated)[stream.emitted:]:
            if rec.first_token is None:
                rec.first_token = now
            rec.token_times.append(now)
            if stream.on_token is not None:
                stream.on_token(uid, int(tok))
            stream.emitted += 1
            burst += 1
        # speculative servers emit multi-token bursts (the accepted draft
        # prefix lands at once); the burst size IS the per-stream view of
        # the accept rate, so track its distribution
        if burst and getattr(self.server, "speculative", False):
            tel = obs.get()
            if tel.enabled:
                tel.registry.histogram(
                    "serve.spec_burst", SPEC_BURST_EDGES
                ).observe(burst)

    def _finish(self, uid: Any, result: Result, now: float) -> None:
        stream = self._streams.pop(uid)
        rec = self.metrics[uid]
        rec.finished = now
        rec.tokens = result.tokens
        self._observe(rec)
        if stream.on_finish is not None:
            stream.on_finish(uid, result)

    @staticmethod
    def _observe(rec: RequestMetrics) -> None:
        """Fold one finished request into the telemetry registry — the
        live view of what ``summarize`` computes offline."""
        tel = obs.get()
        if not tel.enabled:
            return
        reg = tel.registry
        reg.counter("serve.requests_finished").inc()
        reg.counter("serve.tokens_generated").inc(len(rec.token_times))
        if rec.ttft is not None:
            reg.histogram("serve.ttft_s").observe(rec.ttft)
        if rec.latency is not None:
            reg.histogram("serve.latency_s").observe(rec.latency)
        if len(rec.token_times) > 1:
            h = reg.histogram("serve.intertoken_s")
            for gap in np.diff(rec.token_times):
                h.observe(float(gap))
        tel.event("serve.request_finished", uid=str(rec.uid),
                  ttft_s=rec.ttft, latency_s=rec.latency,
                  tokens=len(rec.token_times))

    # -- synchronous serving loops --------------------------------------

    def drain(self) -> Dict[Any, RequestMetrics]:
        """Tick until every submitted request finished (or cancelled)."""
        while True:
            with self._lock:
                if not self.has_work:
                    return dict(self.metrics)
                worked = self._tick()
                if not worked and self._pending and not (
                        self._prefilling or self.server.active_slots):
                    raise RuntimeError(
                        f"driver stalled with {len(self._pending)} queued "
                        "requests on an idle server")

    def run(self, arrivals: Sequence) -> Dict[Any, RequestMetrics]:
        """Serve a timed workload: ``arrivals`` is a sequence of
        ``(delay_seconds, Request)`` pairs (or bare Requests, meaning
        arrive-at-0), submitted relative to the call's start time while
        ticking continuously.  Returns the metrics dict when everything
        submitted has finished."""
        sched: List[Tuple[float, Request]] = sorted(
            [(0.0, a) if isinstance(a, Request) else (float(a[0]), a[1])
             for a in arrivals], key=lambda p: p[0])
        i, t0 = 0, self._clock()
        while i < len(sched) or self.has_work:
            now = self._clock() - t0
            while i < len(sched) and sched[i][0] <= now:
                self.submit(sched[i][1])
                i += 1
            if not self.tick() and i < len(sched):
                time.sleep(min(1e-3, max(0.0, sched[i][0]
                                         - (self._clock() - t0))))
        with self._lock:
            return dict(self.metrics)

    # -- async front-end -------------------------------------------------

    def start(self) -> None:
        """Run the tick loop on a daemon pump thread (all jax work happens
        there; ``submit``/``cancel`` stay safe from any thread)."""
        if self._pump is not None:
            return
        self._stop.clear()

        def pump():
            while not self._stop.is_set():
                if not self.tick():
                    time.sleep(1e-3)

        self._pump = threading.Thread(target=pump, name="serve-driver",
                                      daemon=True)
        self._pump.start()

    def stop(self) -> None:
        if self._pump is None:
            return
        self._stop.set()
        self._pump.join()
        self._pump = None

    async def astream(self, request: Request):
        """Async generator of ``request``'s generated tokens — the asyncio
        face of the callback API.  Requires :meth:`start` (or another
        thread ticking).  Propagates ``submit`` errors synchronously."""
        import asyncio

        loop = asyncio.get_running_loop()
        q: "asyncio.Queue" = asyncio.Queue()
        done = object()
        self.submit(
            request,
            on_token=lambda uid, tok:
                loop.call_soon_threadsafe(q.put_nowait, tok),
            on_finish=lambda uid, res:
                loop.call_soon_threadsafe(q.put_nowait, done),
        )
        while True:
            item = await q.get()
            if item is done:
                return
            yield item


# ---------------------------------------------------------------------------
# workloads + metric summaries
# ---------------------------------------------------------------------------


def poisson_arrivals(requests: Sequence[Request], rate: float, seed: int = 0
                     ) -> List[Tuple[float, Request]]:
    """Timestamp ``requests`` with exponential inter-arrival gaps (a
    Poisson process at ``rate`` requests/sec) for :meth:`RequestDriver.run`."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for req in requests:
        out.append((t, req))
        t += float(rng.exponential(1.0 / rate))
    return out


# thin alias kept for older callers — the math now lives in repro.obs
# (exact raw-sample percentiles, None-safe: empty input returns None, a
# single sample answers every q with itself)
_pct_ms = obs.percentile_ms


def summarize(metrics: Dict[Any, RequestMetrics]) -> Dict[str, Any]:
    """SLO view of a finished run: TTFT percentiles, inter-token-gap
    percentiles, end-to-end latency, and generated tokens/sec.

    Built on :func:`repro.obs.percentile` so every degenerate shape is
    guarded in one place: an empty metrics dict, all-cancelled runs,
    zero-token requests (empty ``token_times``), and single-sample p99s
    all produce ``None``/0 fields instead of raising."""
    done = [m for m in metrics.values()
            if m.finished is not None and not m.cancelled]
    ttfts = [m.ttft for m in done]            # None-safe: obs drops holes
    gaps: List[float] = []
    for m in done:
        if len(m.token_times) > 1:            # zero/one-token requests
            gaps.extend(np.diff(m.token_times).tolist())
    lats = [m.latency for m in done]
    n_tok = sum(len(m.token_times) for m in done)
    span = (max(m.finished for m in done) - min(m.arrival for m in done)
            if done else 0.0)
    return {
        "requests": len(done),
        "cancelled": sum(m.cancelled for m in metrics.values()),
        "generated_tokens": n_tok,
        "tokens_per_s": n_tok / span if span > 0 else None,
        "ttft_p50_ms": obs.percentile_ms(ttfts, 50),
        "ttft_p99_ms": obs.percentile_ms(ttfts, 99),
        "intertoken_p99_ms": obs.percentile_ms(gaps, 99),
        "latency_p99_ms": obs.percentile_ms(lats, 99),
    }
