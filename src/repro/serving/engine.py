"""Batched greedy/temperature generation on top of prefill + decode_step.

Handles the position bookkeeping for multimodal prefixes (VLM patches are
part of the internal sequence, so decode positions are offset by
``num_patches``).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import averaging
from repro.models import transformer as M

PyTree = Any


def internal_prefix(cfg: ModelConfig) -> int:
    return cfg.num_patches if cfg.frontend == "vision" else 0


def averaged_params(trained: Any) -> PyTree:
    """Serving params (uniform soup) from either training engine's output.

    Accepts a :class:`repro.train.loop.TrainResult` or a bare stacked
    population pytree.  The fused shard_map engine returns leaves sharded
    over the ``ens`` mesh axis; the ens-axis mean runs on the sharded
    arrays FIRST (1× model size moves, not N×), then the single averaged
    member is gathered so the serving path can feed it to
    ``prefill``/``decode_step`` on any mesh.
    """
    population = getattr(trained, "population", trained)
    soup = averaging.uniform_soup(population)

    def _gather(x):
        devs = getattr(getattr(x, "sharding", None), "device_set", None)
        if devs is not None and len(devs) > 1:
            return jnp.asarray(jax.device_get(x))
        return x

    return jax.tree_util.tree_map(_gather, soup)


def generate_from_population(
    trained: Any,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    max_new_tokens: int,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Serve the averaged model of a trained population (either engine)."""
    return generate(
        averaged_params(trained), cfg, batch, max_new_tokens,
        temperature=temperature, key=key,
    )


def generate(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    max_new_tokens: int,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """batch: {"tokens": (B,S), ["patches"|"frames"]: ...} -> (B, S+max_new)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    prefix = internal_prefix(cfg)
    capacity = prefix + S + max_new_tokens

    logits, cache = M.prefill(params, cfg, batch, capacity=capacity)

    def sample(lg, k):
        if temperature <= 0.0:
            return jnp.argmax(lg[:, -1], axis=-1)
        return jax.random.categorical(k, lg[:, -1] / temperature)

    decode = jax.jit(
        lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos)
    )

    out = [tokens]
    k = key if key is not None else jax.random.key(0)
    nxt = sample(logits, k)
    for i in range(max_new_tokens):
        out.append(nxt[:, None])
        if i == max_new_tokens - 1:
            break
        pos = prefix + S + i
        logits, cache = decode(params, nxt[:, None], cache, pos)
        k = jax.random.fold_in(k, i)
        nxt = sample(logits, k)
    return jnp.concatenate(out, axis=1)
