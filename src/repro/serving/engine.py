"""Fused scan-based serving engine: one compiled decode program per shape.

The legacy path (kept as :func:`generate_reference` for parity tests and
benchmarks) built a fresh ``jax.jit`` closure inside every ``generate``
call and drove it from a Python token loop — every *request* re-traced
``decode_step`` from scratch and every *token* paid a host dispatch plus a
list concat.  The engine here compiles the whole generation once:

  * prefill and decode are jitted top-level programs cached in a
    module-level **executable cache** keyed on
    ``(cfg, mode, B, S, max_new, capacity, greedy, mesh, stages)`` — one
    trace per shape for the lifetime of the process, reused across
    requests;
  * decode runs as a single ``lax.scan`` over token positions
    (:func:`repro.models.transformer.decode_scan`) with ``pos`` traced and
    the ``(B, S+max_new)`` token buffer preallocated and filled in-program;
  * the KV cache is **donated** to the decode program on backends whose
    runtime supports buffer donation (TPU/GPU; on CPU donation is a no-op
    and jax warns, so it is skipped there);
  * sampling happens in-scan: greedy, or temperature sampling with
    **per-request keys** (``jax.random.split(key, B)`` then a per-step
    ``fold_in``), so two requests in one batch never share a sample stream;
  * trace counters (:func:`decode_trace_count` — same pattern as
    ``train.engine.chunk_trace_count``) let tests assert that a 64-token
    generation compiles decode exactly once.

Serving **modes** (the paper's end-of-training evaluation strategies, made
first-class at serve time):

  soup      uniform weight average of the population — single-model cost,
            today's default (paper "Averaged").
  member    serve member *i* unaveraged (baseline / A-B debugging).
  ensemble  run all N members' prefill+decode under ``vmap`` and average
            their logits (``averaging.balanced_mean``) before sampling —
            the paper's accuracy ceiling at N× compute.

Batch sharding: pass a ``mesh`` with a ``data`` axis (e.g.
``launch.mesh.make_host_data_mesh``) and the token batch is sharded over
the data axes while params replicate — serving scales past one chip
without touching the program.

**Stage-split decode**: pass a ``("pipe",)`` mesh (``--pp-stages`` on the
serve CLI) and ``params["blocks"]`` plus the layer-leading KV cache are
sliced over ``pipe`` into ``S`` contiguous stages.  Each decode step runs
``S`` hops: every stage applies its local ``L/S`` blocks, the activation
crosses the stage boundary via ``ppermute``, and the last stage's logits
are ``psum``-broadcast so all stages sample the same token — staged
output is bitwise-identical to the unstaged engine (pure data movement).
Per-chip FLOPs match the replicated engine (``S`` hops x ``L/S`` layers);
the win is *memory* — each chip holds ``1/S`` of the blocks and cache, so
a model (or capacity) that does not fit one chip serves on ``S``.
:func:`repro.models.transformer.staged_decode_supported` gates the path
to the plain attention families (GQA/MLA); ensemble mode is rejected.

Handles the position bookkeeping for multimodal prefixes (VLM patches are
part of the internal sequence, so decode positions are offset by
``num_patches``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.configs.base import ModelConfig
from repro.core import averaging
from repro.core.compat import donate_argnums, shard_map
from repro.core import population as pop
from repro.models import transformer as M

PyTree = Any

MODES = ("soup", "member", "ensemble")


def internal_prefix(cfg: ModelConfig) -> int:
    return cfg.num_patches if cfg.frontend == "vision" else 0


# ---------------------------------------------------------------------------
# trace counters + executable cache
# ---------------------------------------------------------------------------

# Counts traces of the fused decode/prefill program bodies (jit traces the
# Python body exactly once per compiled executable, so these ARE the
# compile counts; tests/test_serving.py asserts decode == 1 for a whole
# generation and stays 1 across same-shape requests).
_DECODE_TRACES = [0]
_PREFILL_TRACES = [0]
# Traces of the legacy reference loop's per-request jit closure.
_REFERENCE_TRACES = [0]

_EXEC_CACHE: Dict[Tuple, Callable] = {}


def reset_trace_counts() -> None:
    _DECODE_TRACES[0] = 0
    _PREFILL_TRACES[0] = 0
    _REFERENCE_TRACES[0] = 0


def decode_trace_count() -> int:
    return _DECODE_TRACES[0]


def prefill_trace_count() -> int:
    return _PREFILL_TRACES[0]


def reference_trace_count() -> int:
    return _REFERENCE_TRACES[0]


def executable_cache_size() -> int:
    return len(_EXEC_CACHE)


def clear_executable_cache() -> None:
    """Drop cached executables (tests use this to measure traces from cold)."""
    _EXEC_CACHE.clear()


# donation argnums, or () on CPU where donation is an ignored no-op
_donate = donate_argnums


# ---------------------------------------------------------------------------
# sampling (shared by the scan program and the reference loop)
# ---------------------------------------------------------------------------


def _request_keys(key: Optional[jax.Array], batch: int,
                  temperature: float) -> jax.Array:
    """Per-request sample keys.  Greedy decoding is keyless; temperature
    sampling REQUIRES an explicit key — a silent default key would make
    every temperature>0 request stream identical."""
    if temperature > 0.0:
        if key is None:
            raise ValueError(
                "generate(temperature>0) requires an explicit PRNG key: a "
                "default key would make all sampled requests identical. "
                "Pass key=jax.random.key(...) (greedy decoding stays keyless)."
            )
        return jax.random.split(key, batch)
    # unused by the greedy program; keeps one program signature per shape
    return jax.random.split(jax.random.key(0), batch)


def _sample(logits, keys, step, temperature, greedy: bool):
    """Next-token ids (B,) from last-position logits (B,1,V).

    ``step`` is folded into each request's key, so the stream at step t is
    independent of max_new_tokens and of the other requests in the batch.
    """
    last = logits[:, -1]
    if greedy:
        return jnp.argmax(last, axis=-1).astype(jnp.int32)
    ks = jax.vmap(lambda k: jax.random.fold_in(k, step))(keys)
    return jax.vmap(
        lambda lg, k: jax.random.categorical(k, lg)
    )(last.astype(jnp.float32) / temperature, ks).astype(jnp.int32)


# ---------------------------------------------------------------------------
# compiled programs
# ---------------------------------------------------------------------------


def _ensemble_step(cfg: ModelConfig):
    """Population decode step: vmap members, average logits before sampling
    (balanced-tree mean — same reduction as the weight soup)."""

    def step(params, cache, tokens, pos):
        lgs, cache = jax.vmap(
            lambda p, c: M.decode_step(p, cfg, tokens, c, pos)
        )(params, cache)
        return averaging.balanced_mean(lgs), cache

    return step


def _build_prefill(cfg: ModelConfig, ensemble: bool, capacity: int):
    def program(params, batch):
        _PREFILL_TRACES[0] += 1
        # trace-time host effect mirroring the one-trace-per-shape contract
        obs.get().record_compile("serve_prefill", capacity=capacity)
        if ensemble:
            return jax.vmap(
                lambda p: M.prefill(p, cfg, batch, capacity=capacity)
            )(params)
        return M.prefill(params, cfg, batch, capacity=capacity)

    return jax.jit(program)


def _decode_program(cfg: ModelConfig, ensemble: bool, S: int, max_new: int,
                    greedy: bool):
    """The raw (unjitted) scan-decode program body.

    Split from :func:`_build_decode` so the contract matrix
    (``repro.analysis.matrix``) can jit it with *explicit* donation and
    verify the KV-cache alias from optimized HLO even on CPU, where the
    serving path's :func:`repro.core.compat.donate_argnums` is a no-op.
    The cache is argument 2 — the donation contract's subject: the
    program returns ``(tokens, final_cache)`` so XLA can alias the
    donated input cache to the output (a donated buffer with no matching
    output is silently unusable — the contract matrix caught exactly
    that); :func:`generate` drops the cache half."""
    prefix = internal_prefix(cfg)

    def program(params, tokens, cache, first_logits, keys, temperature):
        _DECODE_TRACES[0] += 1
        obs.get().record_compile("serve_decode", S=S, max_new=max_new)
        B = tokens.shape[0]
        if ensemble:
            first_logits = averaging.balanced_mean(first_logits)
        nxt = _sample(first_logits, keys, 0, temperature, greedy)

        # preallocated (B, S+max_new) output buffer: prompt + every sampled
        # token is written in-program, no per-token host round-trip.
        buf = jnp.zeros((B, S + max_new), jnp.int32)
        buf = jax.lax.dynamic_update_slice(buf, tokens.astype(jnp.int32), (0, 0))
        buf = buf.at[:, S].set(nxt)

        new_toks, cache = M.decode_scan(
            params, cfg, nxt, cache, prefix + S, max_new - 1,
            lambda lg, i: _sample(lg, keys, i + 1, temperature, greedy),
            step_fn=_ensemble_step(cfg) if ensemble else None,
        )
        return jax.lax.dynamic_update_slice(buf, new_toks, (0, S + 1)), cache

    return program


def _build_decode(cfg: ModelConfig, ensemble: bool, S: int, max_new: int,
                  greedy: bool):
    program = _decode_program(cfg, ensemble, S, max_new, greedy)
    return jax.jit(program, donate_argnums=_donate((2,)))


# ---------------------------------------------------------------------------
# stage-split programs (pipeline serving over a ("pipe",) mesh)
# ---------------------------------------------------------------------------


def _staged_param_specs(params) -> PyTree:
    """Member-param specs for the pipe mesh: stacked ``blocks`` leaves are
    stage-sliced on the scanned layer axis, everything else replicates."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [
        P("pipe") if any(getattr(p, "key", None) == "blocks" for p in path)
        else P()
        for path, _ in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def _staged_cache_specs(cfg: ModelConfig, stages: int, B: int, capacity: int):
    """(local_cfg, cache pspecs): every cache leaf leads with the layer
    axis, so the per-stage cache is the global one sharded by ``pipe``."""
    local_cfg = dataclasses.replace(cfg, num_layers=cfg.num_layers // stages)
    shapes = jax.eval_shape(lambda: M.init_cache(local_cfg, B, capacity))
    return local_cfg, jax.tree_util.tree_map(lambda _: P("pipe"), shapes)


def _staged_step_fn(cfg: ModelConfig, local_cfg: ModelConfig, stages: int):
    """decode_step over the pipe axis: ``S`` hops of local blocks + a
    boundary ``ppermute``; the last stage's logits are psum-broadcast so
    every stage samples the identical token (the psum adds exact zeros, so
    staged tokens are bitwise the unstaged engine's)."""
    perm = [(s, s + 1) for s in range(stages - 1)]

    def step_fn(params, cache, tokens, pos):
        sid = jax.lax.axis_index("pipe")
        h = M.decode_embed(params, cfg, tokens, pos)
        y = h
        for tau in range(stages):
            y, kv = M.decode_blocks(params["blocks"], local_cfg, h, cache, pos)
            cache = jax.tree_util.tree_map(
                lambda new, old: jnp.where(sid == tau, new, old), kv, cache
            )
            if tau < stages - 1:
                h = jax.lax.ppermute(y, "pipe", perm)
        logits = M.lm_logits(params, cfg, y)
        return jax.lax.psum(
            jnp.where(sid == stages - 1, logits, jnp.zeros_like(logits)),
            "pipe",
        ), cache

    return step_fn


def _build_staged_prefill(cfg: ModelConfig, stages: int, B: int, S: int,
                          capacity: int, mesh, pspecs):
    """Staged prefill: same hop structure as the decode step, on the whole
    prompt.  Only stage ``tau``'s cache write survives hop ``tau``, so the
    per-stage KV ring ends bitwise-identical to its slice of the unstaged
    cache.  Every chip runs ``S`` hops of ``L/S`` layers — replicated-
    prefill FLOPs, ``1/S`` of its memory."""
    local_cfg, cspecs = _staged_cache_specs(cfg, stages, B, capacity)
    perm = [(s, s + 1) for s in range(stages - 1)]

    def program(params, batch):
        _PREFILL_TRACES[0] += 1
        obs.get().record_compile("serve_prefill_staged", stages=stages,
                                 capacity=capacity)
        sid = jax.lax.axis_index("pipe")
        cache = M.init_cache(local_cfg, batch["tokens"].shape[0], capacity)
        h = M.prefill_embed(params, cfg, batch)
        y = h
        for tau in range(stages):
            y, kv = M.prefill_blocks(params["blocks"], local_cfg, h, cache)
            cache = jax.tree_util.tree_map(
                lambda new, old: jnp.where(sid == tau, new, old), kv, cache
            )
            if tau < stages - 1:
                h = jax.lax.ppermute(y, "pipe", perm)
        logits = M.lm_logits(params, cfg, y[:, -1:])
        logits = jax.lax.psum(
            jnp.where(sid == stages - 1, logits, jnp.zeros_like(logits)),
            "pipe",
        )
        return logits, cache

    bspecs = {"tokens": P()}
    f = shard_map(
        program, mesh=mesh, in_specs=(pspecs, bspecs),
        out_specs=(P(), cspecs), check_vma=False,
    )
    return jax.jit(f)


def _build_staged_decode(cfg: ModelConfig, stages: int, B: int, S: int,
                         max_new: int, capacity: int, greedy: bool, mesh,
                         pspecs):
    local_cfg, cspecs = _staged_cache_specs(cfg, stages, B, capacity)
    step_fn = _staged_step_fn(cfg, local_cfg, stages)

    def program(params, tokens, cache, first_logits, keys, temperature):
        _DECODE_TRACES[0] += 1
        obs.get().record_compile("serve_decode_staged", stages=stages,
                                 S=S, max_new=max_new)
        nxt = _sample(first_logits, keys, 0, temperature, greedy)
        buf = jnp.zeros((B, S + max_new), jnp.int32)
        buf = jax.lax.dynamic_update_slice(buf, tokens.astype(jnp.int32), (0, 0))
        buf = buf.at[:, S].set(nxt)
        new_toks, cache = M.decode_scan(
            params, cfg, nxt, cache, S, max_new - 1,
            lambda lg, i: _sample(lg, keys, i + 1, temperature, greedy),
            step_fn=step_fn,
        )
        return jax.lax.dynamic_update_slice(buf, new_toks, (0, S + 1)), cache

    f = shard_map(
        program, mesh=mesh,
        in_specs=(pspecs, P(), cspecs, P(), P(), P()),
        out_specs=(P(), cspecs), check_vma=False,
    )
    return jax.jit(f, donate_argnums=_donate((2,)))


def _staged_request(params, cfg: ModelConfig, mode: str, mesh) -> None:
    """Validate a pipe-mesh request (stage count >= 2)."""
    names = tuple(getattr(mesh, "axis_names", ()))
    extra = [a for a in names if a != "pipe" and mesh.shape[a] > 1]
    if extra:
        raise ValueError(
            f"stage-split serving wants a pipe-only mesh; axes {extra} have "
            "size > 1 (shard the batch on a separate data mesh instead)"
        )
    if mode == "ensemble":
        raise ValueError(
            "mode='ensemble' is not supported with stage-split decode: the "
            "vmapped population step and the pipe hops do not compose; "
            "serve the soup or a member on the pipe mesh"
        )
    reason = M.staged_decode_supported(cfg)
    if reason is not None:
        raise NotImplementedError(f"staged decode: {reason}")
    stages = mesh.shape["pipe"]
    if cfg.num_layers % stages:
        raise ValueError(
            f"num_layers={cfg.num_layers} does not split evenly over "
            f"{stages} pipeline stages"
        )


def _shard_staged_request(params, batch, keys, mesh, pspecs):
    """Place a staged request: blocks leaves stage-sliced over ``pipe``,
    batch/keys/other params replicated."""
    params = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspecs
    )
    rep = NamedSharding(mesh, P())
    batch = {k: jax.device_put(v, rep) for k, v in batch.items()}
    keys = jax.device_put(keys, rep)
    return params, batch, keys


def _programs(cfg: ModelConfig, ensemble: bool, B: int, S: int, max_new: int,
              capacity: int, greedy: bool, mesh, stages: int = 1,
              params=None):
    """Executable-cache lookup: one (prefill, decode) pair per shape key.

    ``cfg`` is a frozen dataclass and ``mesh`` is hashable, so the key is
    exact — a new shape compiles once, every later request with the same
    key reuses the executable (0 additional traces).  ``stages > 1``
    selects the stage-split program pair (and keys the cache on it)."""
    key = ("serve", cfg, ensemble, B, S, max_new, capacity, greedy, mesh,
           stages)
    if key not in _EXEC_CACHE:
        if stages > 1:
            pspecs = _staged_param_specs(params)
            _EXEC_CACHE[key] = (
                _build_staged_prefill(cfg, stages, B, S, capacity, mesh,
                                      pspecs),
                _build_staged_decode(cfg, stages, B, S, max_new, capacity,
                                     greedy, mesh, pspecs),
            )
        else:
            _EXEC_CACHE[key] = (
                _build_prefill(cfg, ensemble, capacity),
                _build_decode(cfg, ensemble, S, max_new, greedy),
            )
    return _EXEC_CACHE[key]


def _shard_request(params, batch, keys, cfg: ModelConfig, mesh):
    """Place the request on a serving mesh: batch over the data axes,
    params (and sample keys) replicated.  GSPMD propagates the batch
    sharding through prefill/decode; the KV cache comes out batch-sharded
    without an explicit spec."""
    from repro.sharding import rules

    bspecs = rules.batch_pspecs(cfg, mesh, batch["tokens"].shape[0])
    batch = {
        k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
        for k, v in batch.items()
    }
    rep = NamedSharding(mesh, P())
    params = jax.tree_util.tree_map(lambda x: jax.device_put(x, rep), params)
    keys = jax.device_put(keys, rep)
    return params, batch, keys


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def averaged_params(trained: Any) -> PyTree:
    """Serving params (uniform soup) from either training engine's output.

    Accepts a :class:`repro.train.loop.TrainResult` or a bare stacked
    population pytree.  The fused shard_map engine returns leaves sharded
    over the ``ens`` mesh axis; the ens-axis mean runs on the sharded
    arrays FIRST (1× model size moves, not N×), then the single averaged
    member is gathered so the serving path can feed it to
    ``prefill``/``decode_step`` on any mesh.
    """
    population = getattr(trained, "population", trained)
    soup = averaging.uniform_soup(population)
    return jax.tree_util.tree_map(_gather_leaf, soup)


def _gather_leaf(x):
    # shared multi-device predicate+gather (core.population.host_gather);
    # re-wrapped as a device array so serving never feeds numpy to jit
    return jnp.asarray(pop.host_gather(x))


def serving_params(trained: Any, mode: str = "soup", member: int = 0) -> PyTree:
    """Params for a serving mode from either training engine's output.

    soup → averaged member; member → member *i*; ensemble → the full
    stacked population (gathered off any training mesh so the serving
    programs can place it on the serving mesh)."""
    if mode not in MODES:
        raise ValueError(f"unknown serving mode {mode!r}; expected one of {MODES}")
    population = getattr(trained, "population", trained)
    if mode == "soup":
        return averaged_params(population)
    if mode == "member":
        return jax.tree_util.tree_map(
            _gather_leaf, pop.member(population, member)
        )
    return jax.tree_util.tree_map(_gather_leaf, population)


def generate_from_population(
    trained: Any,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    max_new_tokens: int,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
    mode: str = "soup",
    member: int = 0,
    mesh=None,
) -> jax.Array:
    """Serve a trained population (either engine) under a serving mode."""
    return generate(
        serving_params(trained, mode, member), cfg, batch, max_new_tokens,
        temperature=temperature, key=key,
        mode="ensemble" if mode == "ensemble" else "soup", mesh=mesh,
    )


def generate(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    max_new_tokens: int,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
    mode: str = "soup",
    mesh=None,
) -> jax.Array:
    """batch: {"tokens": (B,S), ["patches"|"frames"]: ...} -> (B, S+max_new).

    ``mode="soup"``/``"member"`` serve ``params`` as a single model (the
    two differ only in how the caller picked the params); ``"ensemble"``
    expects a stacked (N, ...) population and averages member logits
    in-scan.  ``mesh`` (optional) shards the batch over its data axes —
    or, with a ``("pipe",)`` mesh, stage-splits the blocks and KV cache
    over ``mesh.shape["pipe"]`` pipeline stages (bitwise-identical
    tokens, ``1/S`` the per-chip blocks+cache memory).
    """
    if mode not in MODES:
        raise ValueError(f"unknown serving mode {mode!r}; expected one of {MODES}")
    if max_new_tokens < 1:
        raise ValueError("max_new_tokens must be >= 1")
    ensemble = mode == "ensemble"
    tokens = batch["tokens"]
    B, S = tokens.shape
    capacity = internal_prefix(cfg) + S + max_new_tokens
    greedy = temperature <= 0.0

    staged = mesh is not None and "pipe" in tuple(getattr(mesh, "axis_names", ()))
    stages = mesh.shape["pipe"] if staged else 1
    if stages > 1:
        _staged_request(params, cfg, mode, mesh)

    keys = _request_keys(key, B, temperature)
    if mesh is not None and stages == 1:
        params, batch, keys = _shard_request(params, batch, keys, cfg, mesh)
        tokens = batch["tokens"]

    prefill_fn, decode_fn = _programs(
        cfg, ensemble, B, S, max_new_tokens, capacity, greedy, mesh,
        stages=stages, params=params,
    )
    if stages > 1:
        params, batch, keys = _shard_staged_request(
            params, batch, keys, mesh, _staged_param_specs(params)
        )
        tokens = batch["tokens"]
    tel = obs.get()
    with tel.span("serve.prefill", S=S, B=B):
        logits, cache = prefill_fn(params, batch)
    with tel.span("serve.decode", S=S, max_new=max_new_tokens):
        out, _ = decode_fn(params, tokens, cache, logits, keys,
                           jnp.float32(max(temperature, 1e-6)))
        return out


# ---------------------------------------------------------------------------
# legacy reference loop (parity tests + serving_bench baseline)
# ---------------------------------------------------------------------------


def generate_reference(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    max_new_tokens: int,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """The pre-engine serving path, preserved verbatim in structure: a fresh
    ``jax.jit`` closure per request (so decode re-traces on EVERY call —
    count it via :func:`reference_trace_count`) and a Python loop with one
    host dispatch and a list append per token.  Sampling uses the same
    per-request fold-in scheme as the scan program, so the two paths are
    token-parity-comparable under a fixed key (tests/test_serving.py
    asserts bitwise equality).  Do not use in serving — this exists as the
    benchmark baseline and the parity oracle for :func:`generate`.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    prefix = internal_prefix(cfg)
    capacity = prefix + S + max_new_tokens
    greedy = temperature <= 0.0
    keys = _request_keys(key, B, temperature)
    temp = jnp.float32(max(temperature, 1e-6))

    logits, cache = M.prefill(params, cfg, batch, capacity=capacity)

    def _counted_decode(p, t, c, pos):
        _REFERENCE_TRACES[0] += 1
        return M.decode_step(p, cfg, t, c, pos)

    decode = jax.jit(_counted_decode)  # fresh closure: re-traced per request

    out = [tokens.astype(jnp.int32)]
    nxt = _sample(logits, keys, 0, temp, greedy)
    for i in range(max_new_tokens):
        out.append(nxt[:, None])
        if i == max_new_tokens - 1:
            break
        logits, cache = decode(params, nxt[:, None], cache, prefix + S + i)
        nxt = _sample(logits, keys, i + 1, temp, greedy)
    return jnp.concatenate(out, axis=1)
