"""Continuous-batching serving runtime over a paged KV cache.

The scan engine (``serving.engine``) compiles one decode program per
``(B, S, max_new)`` shape — ideal when requests arrive in shape-uniform
batches, hopeless for mixed-length traffic, which either pads every
request to the worst case or re-compiles per shape.  This runtime serves a
*stream* of heterogeneous requests with exactly ONE compiled decode step:

  * **Paged KV cache** — instead of a per-request contiguous
    ``(B, capacity)`` cache, KV lives in a shared pool of fixed-size pages
    (``models.layers.paged_pools_init``); each serving *slot* holds a page
    table of pool indices.  A slot's context can grow page-by-page, and
    slots of wildly different lengths share one allocation.
  * **Continuous scheduling** — a host-side scheduler admits queued
    requests into a fixed array of ``max_slots`` slots, runs one compiled
    decode step for the whole in-flight set per token, and retires
    finished slots via an in-program **done-mask**.  Admissions,
    retirements, and page-table edits change traced VALUES only (token
    ids, positions, table entries), never shapes — so the decode program
    traces exactly once per pool geometry, guarded by
    :func:`decode_trace_count` (same contract as ``serving.engine``).
  * **Prefix page reuse + suffix-only prefill** — full prompt pages are
    keyed by a chained content hash; a request whose prompt shares a
    page-aligned prefix with an in-flight request reuses those pages
    (refcount bump) and prefills ONLY the uncached suffix through
    ``models.transformer.prefill_paged`` (the cached prefix's FLOPs are
    skipped entirely — ``stats["prefill_tokens"]`` accounts for it).
  * **LRU page retention** (``retain_pages=True``) — hashed pages whose
    refcount drops to zero park on an LRU list instead of the free list
    and are evicted only under pool pressure, so a shared system prompt
    costs prefill compute once across the server's lifetime, not once
    per concurrent burst.
  * **Chunked prefill** — admission is split into ``begin_admit`` (page +
    slot reservation, no compute) and ``prefill_step`` (one fixed-size
    chunk of the prompt through the chunk program, compiled once per
    chunk length with a *traced* offset).  ``serving.driver`` interleaves
    chunks of a long prompt with decode steps of in-flight streams, which
    bounds their inter-token stalls and queued requests' TTFT.
  * **Paged attention** — the decode attend either gathers pages in jnp
    (``kernels.ref.paged_attention_ref``, the CPU default) or runs the
    fused Pallas kernel (``kernels.paged_attention``, the TPU default;
    ``use_pallas=None`` auto-detects like ``wash_shuffle``).

Per-request **parity contract** (``tests/test_batching.py``): a request
served through a busy continuous batch yields token-for-token the same
output as serving it alone through ``engine.generate_reference`` with the
same key — scheduling is a throughput optimization, not a semantics
change.

Prefill still compiles once per distinct prompt length (shape-dependent,
like the scan engine); decode — the steady-state hot path where a request
spends ``max_new - 1`` of its steps — never re-traces.

Serving modes mirror the engine: ``soup`` / ``member`` construct the
server with single-model params; ``ensemble`` holds the stacked
population, decodes every member per step against per-member pools, and
averages logits (``averaging.balanced_mean``) before sampling.

Example::

    server = ContinuousServer(params, cfg, page_size=16, max_slots=8)
    out = server.run([Request(0, prompt_a, max_new=32),
                      Request(1, prompt_b, max_new=7)])
    # out[0].tokens, out[1].tokens — each identical to serving alone
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.compat import donate_argnums
from repro.core import averaging
from repro.models import layers as L
from repro.models import transformer as M
from repro.serving.engine import MODES, averaged_params, serving_params

PyTree = Any

#: pool page 0 is never allocated: inactive slots' page tables point here,
#: so their (masked, garbage) writes can't corrupt live pages.
SCRATCH_PAGE = 0

#: bucket edges for the per-step speculative rollback histogram (tokens
#: drafted but rejected across the in-flight set; draft_k is small, so
#: small-integer buckets resolve the whole range)
SPEC_ROLLBACK_EDGES = (0.5, 1.5, 2.5, 4.5, 8.5, 16.5, 32.5)


# ---------------------------------------------------------------------------
# trace counters + executable cache (same contract as serving.engine)
# ---------------------------------------------------------------------------

_DECODE_TRACES = [0]
_PREFILL_TRACES = [0]
_EXEC_CACHE: Dict[Tuple, Callable] = {}


def reset_trace_counts() -> None:
    _DECODE_TRACES[0] = 0
    _PREFILL_TRACES[0] = 0


def decode_trace_count() -> int:
    """Traces of the continuous decode-step program (1 per pool geometry)."""
    return _DECODE_TRACES[0]


def prefill_trace_count() -> int:
    """Traces of the prefill programs: one per distinct chunk length (the
    chunk offset ``pos0`` is traced, so chunks of one length share a
    program across slots, offsets, and cached-prefix depths)."""
    return _PREFILL_TRACES[0]


def executable_cache_size() -> int:
    return len(_EXEC_CACHE)


def clear_executable_cache() -> None:
    _EXEC_CACHE.clear()


# ---------------------------------------------------------------------------
# requests / results / slots
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request in the stream.

    ``key`` is required when the server samples (temperature > 0) — the
    same discipline as ``engine.generate`` — and must be per-request, so
    identical prompts in one stream draw independent tokens."""

    uid: Any
    tokens: np.ndarray  # (S,) int32 prompt
    max_new: int
    key: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class Result:
    uid: Any
    tokens: np.ndarray  # (S + max_new,) int32: prompt + generated


@dataclasses.dataclass
class _Slot:
    uid: Any
    prompt: np.ndarray
    max_new: int
    key: jax.Array           # per-request sample key (split(req.key, 1)[0])
    pages: List[int]         # pool pages, prompt-order (shared and owned)
    total_pages: int         # worst-case pages this request can ever hold
    out: List[int]           # sampled tokens so far (out[-1] is pending)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def write_pos(self) -> int:
        # the pending token out[-1] has not been written yet; it lands at
        # absolute position prompt_len + (len(out) - 1) this step
        return self.prompt_len + len(self.out) - 1

    @property
    def future_pages(self) -> int:
        return self.total_pages - len(self.pages)


def _total_pages(prompt_len: int, max_new: int, page_size: int) -> int:
    # tokens ever written to the pool: S prompt + (max_new - 1) decode
    # inputs (the final sampled token is never fed back)
    stored = prompt_len + max_new - 1
    return max(-(-stored // page_size), 1)


@dataclasses.dataclass
class _Prefill:
    """An admission in progress: pages + a slot are reserved, but only
    ``pos`` of the prompt's tokens are in the pool so far.  Produced by
    ``ContinuousServer._begin_admit``; advanced (one chunk per call) by
    ``_prefill_step`` until the prompt is fully prefilled, at which point
    the first token is sampled and the slot goes live."""

    uid: Any
    prompt: np.ndarray
    max_new: int
    key: jax.Array
    pages: List[int]         # ALL prompt pages (shared prefix + owned)
    total_pages: int
    pos: int                 # tokens already in the pool
    cached_tokens: int       # prefix tokens reused (their FLOPs skipped)
    slot_index: int          # reserved decode slot
    digests: List[bytes]     # chain hashes of the prompt's full pages

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def remaining(self) -> int:
        return self.prompt_len - self.pos


# ---------------------------------------------------------------------------
# host-side page pool: free list, refcounts, prefix hash index
# ---------------------------------------------------------------------------


class _PagePool:
    """Host bookkeeping for the device page pool.

    Pages are refcounted: a page backing a shared prompt prefix is held by
    every slot that deduped onto it and freed when the last holder
    retires.  ``prefix`` maps the chained content hash of a page-aligned
    prompt chunk to the live page holding it.

    With ``retain=True``, a hashed page whose refcount drops to zero is
    *parked* on an LRU list (content + hash kept, sharable) instead of
    freed; ``alloc`` evicts the oldest parked page only once the free
    list is empty.  Every page is always in exactly one of three states —
    free, parked (LRU), or refcounted — so
    ``free_count + retained_count + len(refcount) == num_pages - 1``."""

    def __init__(self, num_pages: int, retain: bool = False):
        self.num_pages = num_pages
        self.retain = retain
        self.free: deque = deque(range(1, num_pages))  # page 0 = scratch
        self.refcount: Dict[int, int] = {}
        self.prefix: Dict[bytes, int] = {}
        self.hash_of: Dict[int, bytes] = {}
        self.lru: "OrderedDict[int, None]" = OrderedDict()  # oldest first
        self.lru_hits = 0
        self.lru_evictions = 0

    @property
    def free_count(self) -> int:
        return len(self.free)

    @property
    def retained_count(self) -> int:
        return len(self.lru)

    @property
    def available_count(self) -> int:
        """Pages an admission may claim: free + evictable (parked)."""
        return len(self.free) + len(self.lru)

    @property
    def used_count(self) -> int:
        """Pages held by live slots/prefills (parked pages are not used)."""
        return len(self.refcount)

    def alloc(self) -> int:
        if self.free:
            page = self.free.popleft()
        else:  # pool pressure: evict the least-recently-parked page
            page, _ = self.lru.popitem(last=False)
            del self.prefix[self.hash_of.pop(page)]
            self.lru_evictions += 1
        self.refcount[page] = 1
        return page

    def share(self, digest: bytes) -> Optional[int]:
        page = self.prefix.get(digest)
        if page is None:
            return None
        if page in self.lru:  # revive: parked content is still valid KV
            del self.lru[page]
            self.refcount[page] = 1
            self.lru_hits += 1
        else:
            self.refcount[page] += 1
        return page

    def register(self, page: int, digest: bytes) -> None:
        self.prefix[digest] = page
        self.hash_of[page] = digest

    def release(self, page: int) -> None:
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            del self.refcount[page]
            if self.retain and page in self.hash_of:
                self.lru[page] = None  # park, most-recently-used last
                return
            digest = self.hash_of.pop(page, None)
            if digest is not None:
                self.prefix.pop(digest, None)
            self.free.append(page)


def _chain_hashes(tokens: np.ndarray, page_size: int) -> List[bytes]:
    """Chained per-page digests of the prompt's full pages: page j's key
    covers tokens[0 : (j+1)*page_size], so equal keys mean equal *prefixes*
    (not just equal chunks) — the prefix property page sharing needs."""
    digests = []
    h = b""
    for j in range(tokens.shape[0] // page_size):
        chunk = np.ascontiguousarray(
            tokens[j * page_size:(j + 1) * page_size], dtype=np.int32
        )
        h = hashlib.sha1(h + chunk.tobytes()).digest()
        digests.append(h)
    return digests


# ---------------------------------------------------------------------------
# sampling (step index per SLOT, unlike the engine's shared scalar)
# ---------------------------------------------------------------------------


def _sample_steps(last, keys, steps, temperature, greedy: bool):
    """Next-token ids (B,) from last-position logits (B, V).

    Same fold-in scheme as ``engine._sample`` but with a per-slot step
    vector — slots in a continuous batch sit at different depths of their
    streams, yet each stream must equal the request served alone."""
    if greedy:
        return jnp.argmax(last, axis=-1).astype(jnp.int32)
    ks = jax.vmap(jax.random.fold_in)(keys, steps)
    return jax.vmap(
        lambda lg, k: jax.random.categorical(k, lg)
    )(last.astype(jnp.float32) / temperature, ks).astype(jnp.int32)


# ---------------------------------------------------------------------------
# compiled programs
# ---------------------------------------------------------------------------


def _build_admit(cfg: ModelConfig, ensemble: bool, S: int, n_pages: int,
                 page_size: int, greedy: bool):
    """Prefill + page-commit + first-token sample, one jit per prompt length.

    ``write_mask`` skips pages the scheduler deduped onto a shared prefix
    (their content is already in the pool — same tokens, same params, same
    prefill program ⇒ same KV)."""

    def program(params, k_pool, v_pool, tokens, page_ids, write_mask, key,
                temperature):
        _PREFILL_TRACES[0] += 1
        # trace-time host effect: the compile counters mirror the
        # one-executable-per-geometry contract _*_TRACES guard
        obs.get().record_compile("cont_prefill_admit", S=S)
        batch = {"tokens": tokens}
        if ensemble:
            logits, cache = jax.vmap(
                lambda p: M.prefill(p, cfg, batch, capacity=S)
            )(params)
            k_new = cache["kv"]["k"][:, :, 0]   # (N, L, S, KV, hd)
            v_new = cache["kv"]["v"][:, :, 0]
            last = averaging.balanced_mean(logits)[:, -1]
        else:
            logits, cache = M.prefill(params, cfg, batch, capacity=S)
            k_new = cache["kv"]["k"][:, 0]      # (L, S, KV, hd)
            v_new = cache["kv"]["v"][:, 0]
            last = logits[:, -1]

        pad = n_pages * page_size - S
        def paged(a):
            a = jnp.pad(a, [(0, 0)] * (a.ndim - 3) + [(0, pad), (0, 0), (0, 0)])
            return a.reshape(a.shape[:-3] + (n_pages, page_size) + a.shape[-2:])

        k_new, v_new = paged(k_new), paged(v_new)
        sel = write_mask[:, None, None, None]
        if ensemble:
            cur_k = k_pool[:, :, page_ids]
            cur_v = v_pool[:, :, page_ids]
            k_pool = k_pool.at[:, :, page_ids].set(jnp.where(sel, k_new, cur_k))
            v_pool = v_pool.at[:, :, page_ids].set(jnp.where(sel, v_new, cur_v))
        else:
            cur_k = k_pool[:, page_ids]
            cur_v = v_pool[:, page_ids]
            k_pool = k_pool.at[:, page_ids].set(jnp.where(sel, k_new, cur_k))
            v_pool = v_pool.at[:, page_ids].set(jnp.where(sel, v_new, cur_v))

        token0 = _sample_steps(last, key[None], jnp.zeros((1,), jnp.int32),
                               temperature, greedy)[0]
        return k_pool, v_pool, token0

    return jax.jit(program, donate_argnums=donate_argnums((1, 2)))


def _build_chunk(cfg: ModelConfig, ensemble: bool, greedy: bool,
                 spec: bool = False):
    """One prompt chunk through ``M.prefill_paged``: compiled once per
    chunk LENGTH — the offset ``pos0``, the page table, and the sampling
    key are all traced, so one program serves every slot, every chunk
    position, and every cached-prefix depth.

    The returned ``token0`` is the first sampled token; the host uses it
    only when the chunk completes the prompt (intermediate chunks' last
    rows are mid-prompt positions).

    ``spec`` servers run the chunk through the DRAFT model too (same
    tokens, same table) so the draft pools hold the prompt's soup-side
    K/V before the first speculative step; ``token0`` still comes from
    the verify side.  Prefix pages stay sharable: a page's content in
    BOTH pools is a pure function of (tokens, params), so a chain-hash
    hit is valid for the draft pool exactly when it is for the verify
    pool."""

    def program(params, draft_params, k_pool, v_pool, dk_pool, dv_pool,
                tokens, pos0, table, key, temperature):
        _PREFILL_TRACES[0] += 1
        obs.get().record_compile("cont_prefill_chunk",
                                 T=int(tokens.shape[-1]))
        if ensemble:
            def member(p, kp, vp):
                lg, pools = M.prefill_paged(
                    p, cfg, tokens, pos0, {"k": kp, "v": vp}, table)
                return lg, pools["k"], pools["v"]

            lgs, k_pool, v_pool = jax.vmap(member)(params, k_pool, v_pool)
            last = averaging.balanced_mean(lgs)[:, -1]
        else:
            lg, pools = M.prefill_paged(
                params, cfg, tokens, pos0, {"k": k_pool, "v": v_pool}, table)
            k_pool, v_pool = pools["k"], pools["v"]
            last = lg[:, -1]
        if spec:
            _, dpools = M.prefill_paged(
                draft_params, cfg, tokens, pos0,
                {"k": dk_pool, "v": dv_pool}, table)
            dk_pool, dv_pool = dpools["k"], dpools["v"]
        token0 = _sample_steps(last, key[None], jnp.zeros((1,), jnp.int32),
                               temperature, greedy)[0]
        return k_pool, v_pool, dk_pool, dv_pool, token0

    return jax.jit(program, donate_argnums=donate_argnums((2, 3, 4, 5)))


def _chunk_program(cfg: ModelConfig, ensemble: bool, T: int, max_pages: int,
                   page_size: int, num_pages: int, greedy: bool,
                   kv_dtype: Optional[str] = None, spec: bool = False):
    key = ("cont_chunk", cfg, ensemble, T, max_pages, page_size, num_pages,
           greedy, kv_dtype, spec)
    if key not in _EXEC_CACHE:
        _EXEC_CACHE[key] = _build_chunk(cfg, ensemble, greedy, spec)
    return _EXEC_CACHE[key]


def _build_decode(cfg: ModelConfig, ensemble: bool, greedy: bool,
                  use_pallas: bool):
    """THE continuous decode step: one token for the whole in-flight set.

    Every operand is traced — token ids, write positions, per-slot sample
    steps, budgets, the active mask, page tables, keys, temperature — so
    the program compiles once per pool geometry and is reused across every
    admission/retirement the stream ever makes."""

    def program(params, k_pool, v_pool, tokens, positions, steps, budgets,
                active, page_tables, keys, temperature):
        _DECODE_TRACES[0] += 1
        obs.get().record_compile("cont_decode",
                                 slots=int(tokens.shape[0]))
        if ensemble:
            def member(p, kp, vp):
                lg, pools = M.decode_step_paged(
                    p, cfg, tokens, positions, {"k": kp, "v": vp},
                    page_tables, use_pallas,
                )
                return lg, pools["k"], pools["v"]

            lgs, k_pool, v_pool = jax.vmap(member)(params, k_pool, v_pool)
            logits = averaging.balanced_mean(lgs)
        else:
            logits, pools = M.decode_step_paged(
                params, cfg, tokens, positions, {"k": k_pool, "v": v_pool},
                page_tables, use_pallas,
            )
            k_pool, v_pool = pools["k"], pools["v"]

        sampled = _sample_steps(logits[:, -1], keys, steps, temperature,
                                greedy)
        sampled = jnp.where(active, sampled, 0)
        done = active & (steps + 1 >= budgets)
        return sampled, done, k_pool, v_pool

    return program


def _build_spec_decode(cfg: ModelConfig, ensemble: bool, greedy: bool,
                       use_pallas: bool, draft_k: int):
    """The speculative decode step: draft ``k`` tokens with the soup, then
    verify all of them in ONE batched ensemble step — emitting up to
    ``k`` tokens per call, bitwise the plain path at fp32 KV.  Program
    logic lives in ``serving.speculative``; this wrapper owns the trace
    counter so the one-executable-per-(geometry, draft_k, kv_dtype)
    contract is guarded by the same ``decode_trace_count``."""
    from repro.serving import speculative

    inner = speculative.build_speculative_decode(
        cfg, ensemble, greedy, use_pallas, draft_k)

    def program(*args):
        _DECODE_TRACES[0] += 1
        obs.get().record_compile("cont_spec_decode", draft_k=draft_k)
        return inner(*args)

    return program


def _programs(cfg: ModelConfig, ensemble: bool, geometry: Tuple,
              greedy: bool, use_pallas: bool,
              kv_dtype: Optional[str] = None,
              draft_k: Optional[int] = None):
    """The decode program from the module executable cache — speculative
    when ``draft_k`` is set (``None`` = plain one-token decode)."""
    key = ("continuous", cfg, ensemble, geometry, greedy, use_pallas,
           kv_dtype, draft_k)
    if key not in _EXEC_CACHE:
        if draft_k is None:
            _EXEC_CACHE[key] = jax.jit(
                _build_decode(cfg, ensemble, greedy, use_pallas),
                donate_argnums=donate_argnums((1, 2)),
            )
        else:
            _EXEC_CACHE[key] = jax.jit(
                _build_spec_decode(cfg, ensemble, greedy, use_pallas,
                                   draft_k),
                donate_argnums=donate_argnums((2, 3, 4, 5)),
            )
    return _EXEC_CACHE[key]


def _admit_program(cfg: ModelConfig, ensemble: bool, S: int, n_pages: int,
                   page_size: int, num_pages: int, greedy: bool):
    key = ("cont_admit", cfg, ensemble, S, n_pages, page_size, num_pages,
           greedy)
    if key not in _EXEC_CACHE:
        _EXEC_CACHE[key] = _build_admit(cfg, ensemble, S, n_pages, page_size,
                                        greedy)
    return _EXEC_CACHE[key]


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------


class ContinuousServer:
    """Continuous-batching server: queue in, per-request token streams out.

    Parameters
    ----------
    params : single-model params (modes ``soup``/``member``) or the stacked
        ``(N, ...)`` population (mode ``ensemble``) — exactly the routing
        of ``engine.generate``; use :meth:`from_trained` to go straight
        from a training result.
    page_size : tokens per KV page.
    max_slots : in-flight request capacity (the decode step's batch).
    num_pages : pool size, shared by all slots (page 0 is scratch).
    max_pages_per_slot : page-table width = the longest context one slot
        can hold; defaults to the whole pool.
    temperature / use_pallas : stream-wide sampling temperature and
        attend-kernel routing (None = Pallas on TPU, jnp oracle elsewhere).
    prefill_chunk : split every prompt prefill into chunks of at most this
        many tokens (None = whole suffix in one program).  ``step()`` still
        finishes a request's prefill before decoding — chunk/decode
        INTERLEAVING is the driver's job (``serving.driver``), this knob
        only fixes the compiled chunk geometry.
    retain_pages : park refcount-0 hashed pages on an LRU list (evicted
        under pressure) instead of freeing them, so recurring prompts —
        a shared system prompt above all — skip their prefill compute on
        every later request.  Off by default: ``run()``-style one-shot
        streams expect a drained pool to be empty.
    speculative / draft_k : draft ``draft_k`` tokens per decode call with
        the population soup and verify them in one batched ensemble step
        (``serving.speculative``) — up to ``draft_k`` tokens emitted per
        call, bitwise the plain path at fp32 KV.  Requires the
        suffix-prefill path (the draft pools are prefilled by the same
        chunk programs) and a dense config.  In ``soup``/``member`` mode
        the model drafts for itself (accept rate 1.0 under greedy — the
        mechanics without the population speed-up).
    kv_dtype : ``None`` stores KV pages in the param dtype (the bitwise
        path); ``"int8"`` quantizes every pool page symmetrically with a
        per-(layer, page) float32 scale (``models.layers``), halving pool
        HBM; decode then matches fp32 to a pinned tolerance, not bitwise.
    """

    def __init__(self, params: PyTree, cfg: ModelConfig, *,
                 mode: str = "soup", temperature: float = 0.0,
                 page_size: int = 16, max_slots: int = 4,
                 num_pages: int = 64,
                 max_pages_per_slot: Optional[int] = None,
                 use_pallas: Optional[bool] = None,
                 prefill_chunk: Optional[int] = None,
                 retain_pages: bool = False,
                 speculative: bool = False, draft_k: int = 4,
                 kv_dtype: Optional[str] = None):
        if mode not in MODES:
            raise ValueError(
                f"unknown serving mode {mode!r}; expected one of {MODES}")
        reason = M.paged_decode_supported(cfg)
        if reason is not None:
            raise NotImplementedError(f"continuous batching: {reason}")
        if page_size < 1 or max_slots < 1 or num_pages < 2:
            raise ValueError("need page_size >= 1, max_slots >= 1, "
                             "num_pages >= 2 (page 0 is scratch)")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1 (or None)")
        if kv_dtype not in L.KV_DTYPES:
            raise ValueError(
                f"kv_dtype={kv_dtype!r}; expected one of {L.KV_DTYPES}")
        self.cfg = cfg
        self.params = params
        self.ensemble = mode == "ensemble"
        self.temperature = float(temperature)
        self.greedy = self.temperature <= 0.0
        self.page_size = page_size
        self.max_slots = max_slots
        self.num_pages = num_pages
        self.max_pages = (max_pages_per_slot if max_pages_per_slot is not None
                          else num_pages - 1)
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        self.prefill_chunk = prefill_chunk
        # suffix/chunk prefill needs bitwise-compatible paged numerics;
        # otherwise admissions fall back to the whole-prompt program with
        # write-mask dedup (no chunking, prefix pages shared but recomputed)
        self.suffix_prefill = M.paged_prefill_supported(cfg) is None
        self.kv_dtype = kv_dtype
        if kv_dtype is not None and not self.suffix_prefill:
            # the legacy whole-prompt admit writes raw rows straight into
            # the pool arrays — it has no quantization path
            raise NotImplementedError(
                f"kv_dtype={kv_dtype!r} needs the suffix-prefill path, "
                f"but {M.paged_prefill_supported(cfg)}")
        self.speculative = bool(speculative)
        self.draft_k = int(draft_k)
        if self.speculative:
            from repro.serving import speculative as spec_mod

            if self.draft_k < 1:
                raise ValueError(f"draft_k must be >= 1, got {draft_k}")
            reason = spec_mod.speculative_supported(cfg)
            if reason is not None:
                raise NotImplementedError(f"speculative decode: {reason}")

        n_members = None
        if self.ensemble:
            n_members = jax.tree_util.tree_leaves(params)[0].shape[0]
        pools = L.paged_pools_init(cfg, num_pages, page_size, cfg.num_layers,
                                   kv_dtype=kv_dtype)
        if self.ensemble:
            pools = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n_members,) + x.shape), pools)
        self._k_pool, self._v_pool = pools["k"], pools["v"]

        # the draft side: the population soup drafts for the ensemble; a
        # soup/member server drafts for itself.  Draft pools mirror the
        # verify pools' geometry under the SAME page tables.
        self._draft_params = None
        self._dk_pool = self._dv_pool = None
        if self.speculative:
            self._draft_params = (averaged_params(params) if self.ensemble
                                  else params)
            dpools = L.paged_pools_init(cfg, num_pages, page_size,
                                        cfg.num_layers, kv_dtype=kv_dtype)
            self._dk_pool, self._dv_pool = dpools["k"], dpools["v"]

        self._pool = _PagePool(num_pages, retain=retain_pages)
        self._slots: List[Optional[_Slot]] = [None] * max_slots
        self._prefills: List[_Prefill] = []   # admission order
        self._reserved_slots: set = set()
        self._queue: deque = deque()
        self._results: Dict[Any, Result] = {}
        self._dummy_key = jax.random.split(jax.random.key(0), 1)[0]
        geometry = (max_slots, self.max_pages, page_size, num_pages)
        self._decode = _programs(cfg, self.ensemble, geometry, self.greedy,
                                 self.use_pallas, kv_dtype,
                                 self.draft_k if self.speculative else None)
        self.stats = {"admitted": 0, "retired": 0, "cancelled": 0,
                      "decode_steps": 0, "pages_allocated": 0,
                      "pages_shared": 0, "peak_pages_in_use": 0,
                      "prefill_tokens": 0, "prefix_tokens_reused": 0,
                      "lru_hits": 0, "lru_evictions": 0,
                      "spec_drafted": 0, "spec_accepted": 0}

    # -- construction from a trained population -------------------------

    @classmethod
    def from_trained(cls, trained: Any, cfg: ModelConfig, *,
                     mode: str = "soup", member: int = 0, **kwargs):
        """Route a training result through ``engine.serving_params`` into a
        server: soup/member servers hold one model, ensemble the stack."""
        return cls(serving_params(trained, mode, member), cfg, mode=mode,
                   **kwargs)

    # -- queue API -------------------------------------------------------

    def validate(self, request: Request, pending=()) -> Request:
        """Check a request the way :meth:`submit` would — shared with the
        driver, which runs its own queue.  ``pending`` is any extra set of
        uids the caller already holds.  Returns the request with its
        prompt normalized to a flat int32 array."""
        tokens = np.asarray(request.tokens, np.int32).reshape(-1)
        if tokens.shape[0] < 1 or request.max_new < 1:
            raise ValueError("need a non-empty prompt and max_new >= 1")
        if not self.greedy and request.key is None:
            raise ValueError(
                "sampling (temperature>0) requires a per-request PRNG key, "
                "same discipline as engine.generate")
        # results are keyed by uid: two pending requests with one uid would
        # silently drop one stream's tokens.  (Reusing a uid AFTER its
        # request completed is fine — long-lived servers recycle ids, and
        # the overwrite is then a new result, not a lost one.)
        in_flight = {s.uid for s in self._slots if s is not None}
        in_flight |= {pf.uid for pf in self._prefills}
        if request.uid in in_flight or request.uid in pending or any(
                r.uid == request.uid for r in self._queue):
            raise ValueError(
                f"duplicate request uid {request.uid!r}: a request with "
                f"this uid is already queued or in flight")
        total = _total_pages(tokens.shape[0], request.max_new, self.page_size)
        if total > self.max_pages:
            raise ValueError(
                f"request {request.uid!r} needs {total} pages "
                f"(> max_pages_per_slot={self.max_pages})")
        if total > self.num_pages - 1:
            raise ValueError(
                f"request {request.uid!r} needs {total} pages "
                f"(> pool of {self.num_pages - 1} allocatable pages)")
        return dataclasses.replace(request, tokens=tokens)

    def submit(self, request: Request) -> None:
        self._queue.append(self.validate(request))

    @property
    def queue_len(self) -> int:
        return len(self._queue)

    @property
    def active_slots(self) -> int:
        return sum(s is not None for s in self._slots)

    # -- scheduling ------------------------------------------------------

    def _reserved_pages(self) -> int:
        """Pages the in-flight slots/prefills may still demand (lazy
        growth never fails because admission reserved for everyone's
        worst case)."""
        live = sum(s.future_pages for s in self._slots if s is not None)
        live += sum(pf.total_pages - len(pf.pages) for pf in self._prefills)
        return live

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None and i not in self._reserved_slots:
                return i
        return None

    def _sync_pool_stats(self) -> None:
        self.stats["lru_hits"] = self._pool.lru_hits
        self.stats["lru_evictions"] = self._pool.lru_evictions
        self.stats["peak_pages_in_use"] = max(
            self.stats["peak_pages_in_use"], self._pool.used_count)
        tel = obs.get()
        if tel.enabled:
            reg = tel.registry
            reg.gauge("serve.pages_free").set(self._pool.free_count)
            reg.gauge("serve.pages_retained").set(self._pool.retained_count)
            reg.gauge("serve.pages_refcounted").set(self._pool.used_count)
            reg.gauge("serve.pages_peak").set(
                self.stats["peak_pages_in_use"])
            # prefix-dedup hit rate: fraction of prompt tokens served from
            # cached prefix pages instead of a prefill program (this is
            # also the suffix-prefill token savings)
            seen = (self.stats["prefill_tokens"]
                    + self.stats["prefix_tokens_reused"])
            if seen:
                reg.gauge("serve.prefix_dedup_hit_rate").set(
                    self.stats["prefix_tokens_reused"] / seen)
                reg.gauge("serve.prefix_tokens_reused").set(
                    self.stats["prefix_tokens_reused"])

    # -- chunked/suffix admission (the driver's scheduler hooks) ---------

    def _begin_admit(self, req: Request) -> Optional[_Prefill]:
        """Reserve a slot + every prompt page for ``req`` — NO compute.

        Finds the longest chain-hash-cached prefix run in the pool, bumps
        (or LRU-revives) those pages, and allocates the rest, so the
        returned :class:`_Prefill` starts at ``pos = cached_tokens`` and
        only the uncached suffix ever runs through a prefill program.
        Returns None when no slot is free or the worst-case page
        reservation does not fit."""
        S = int(req.tokens.shape[0])
        n_prompt = max(-(-S // self.page_size), 1)
        total = _total_pages(S, req.max_new, self.page_size)
        slot_i = self._free_slot()
        if slot_i is None:
            return None

        digests = _chain_hashes(req.tokens, self.page_size)
        cached = 0
        while (cached < len(digests)
               and digests[cached] in self._pool.prefix):
            cached += 1
        # the suffix must keep >= 1 token: its last-position logits sample
        # the first output token (a fully cached prompt still runs a
        # 1-token chunk over the final position)
        cached = min(cached, (S - 1) // self.page_size)

        # reviving a parked prefix page consumes availability exactly like
        # an alloc (it leaves the LRU list), so it counts toward need
        revived = sum(1 for j in range(cached)
                      if self._pool.prefix[digests[j]] in self._pool.lru)
        need = (n_prompt - cached) + revived + (total - n_prompt)
        if self._pool.available_count - self._reserved_pages() < need:
            return None

        pages: List[int] = []
        for j in range(cached):
            pages.append(self._pool.share(digests[j]))
            self.stats["pages_shared"] += 1
        for j in range(cached, n_prompt):
            pages.append(self._pool.alloc())
            self.stats["pages_allocated"] += 1
        # NOTE: freshly allocated full pages are NOT registered as sharable
        # yet — their content does not exist until a prefill chunk writes
        # it.  ``_prefill_step`` registers each page as its chunk lands,
        # so a concurrent admission can only dedup onto written pages.
        self.stats["prefix_tokens_reused"] += cached * self.page_size
        self._sync_pool_stats()

        key = req.key if req.key is not None else jax.random.key(0)
        pf = _Prefill(uid=req.uid, prompt=req.tokens, max_new=req.max_new,
                      key=jax.random.split(key, 1)[0], pages=pages,
                      total_pages=total, pos=cached * self.page_size,
                      cached_tokens=cached * self.page_size,
                      slot_index=slot_i, digests=digests)
        self._reserved_slots.add(slot_i)
        self._prefills.append(pf)
        return pf

    def _prefill_step(self, pf: _Prefill, max_tokens: Optional[int] = None
                      ) -> bool:
        """Run ONE prompt chunk (at most ``max_tokens``; None = the whole
        remaining suffix) through the chunk program.  On the final chunk,
        samples the first token and installs the slot (or retires it for
        ``max_new == 1``).  Returns True when the prefill completed."""
        T = pf.remaining if max_tokens is None else min(max_tokens,
                                                        pf.remaining)
        chunk = pf.prompt[pf.pos:pf.pos + T]
        table = np.full((self.max_pages,), SCRATCH_PAGE, np.int32)
        table[:len(pf.pages)] = pf.pages
        program = _chunk_program(self.cfg, self.ensemble, T, self.max_pages,
                                 self.page_size, self.num_pages, self.greedy,
                                 self.kv_dtype, self.speculative)
        (self._k_pool, self._v_pool, self._dk_pool, self._dv_pool,
         token0) = program(
            self.params, self._draft_params, self._k_pool, self._v_pool,
            self._dk_pool, self._dv_pool, jnp.asarray(chunk),
            jnp.int32(pf.pos), jnp.asarray(table), pf.key,
            jnp.float32(max(self.temperature, 1e-6)),
        )
        written_before = pf.pos
        pf.pos += T
        self.stats["prefill_tokens"] += T
        # register the now-fully-written pages for prefix sharing — never
        # clobbering a digest already live on another page (possible when
        # the cached run was capped or LRU eviction broke an older chain:
        # the old page's release would tear down the new entry)
        for j in range(written_before // self.page_size,
                       pf.pos // self.page_size):
            if j < len(pf.digests) and pf.digests[j] not in self._pool.prefix:
                self._pool.register(pf.pages[j], pf.digests[j])
        if pf.remaining:
            return False

        self._prefills.remove(pf)
        self._reserved_slots.discard(pf.slot_index)
        slot = _Slot(uid=pf.uid, prompt=pf.prompt, max_new=pf.max_new,
                     key=pf.key, pages=pf.pages, total_pages=pf.total_pages,
                     out=[int(token0)])
        self.stats["admitted"] += 1
        if pf.max_new == 1:  # prefill-only request: retire immediately
            self._retire(slot)
        else:
            self._slots[pf.slot_index] = slot
        return True

    def _try_admit_legacy(self, req: Request) -> bool:
        """Whole-prompt admission through ``M.prefill`` + write-mask dedup
        — the fallback when ``M.paged_prefill_supported`` rejects the
        config (e.g. ``attn_impl="chunked"``, whose prefill numerics the
        paged attend cannot reproduce bitwise).  Shared prefix pages are
        skipped at WRITE time but their rows are still computed."""
        S = int(req.tokens.shape[0])
        n_prompt = max(-(-S // self.page_size), 1)
        total = _total_pages(S, req.max_new, self.page_size)
        slot_i = self._free_slot()
        if slot_i is None:
            return False

        digests = _chain_hashes(req.tokens, self.page_size)
        shared_pages = [self._pool.prefix.get(d) for d in digests]
        revived = sum(1 for p in shared_pages
                      if p is not None and p in self._pool.lru)
        new_now = n_prompt - sum(p is not None for p in shared_pages)
        need = new_now + revived + (total - n_prompt)
        if self._pool.available_count - self._reserved_pages() < need:
            return False

        pages: List[int] = []
        write_mask = np.ones((n_prompt,), bool)
        for j in range(n_prompt):
            page = self._pool.share(digests[j]) if j < len(digests) else None
            if page is not None:
                write_mask[j] = False
                self.stats["pages_shared"] += 1
            else:
                page = self._pool.alloc()
                self.stats["pages_allocated"] += 1
                if j < len(digests) and digests[j] not in self._pool.prefix:
                    self._pool.register(page, digests[j])
            pages.append(page)
        self._sync_pool_stats()
        self.stats["prefill_tokens"] += S

        key = req.key if req.key is not None else jax.random.key(0)
        slot_key = jax.random.split(key, 1)[0]
        admit = _admit_program(self.cfg, self.ensemble, S, n_prompt,
                               self.page_size, self.num_pages, self.greedy)
        self._k_pool, self._v_pool, token0 = admit(
            self.params, self._k_pool, self._v_pool,
            jnp.asarray(req.tokens)[None], jnp.asarray(pages, jnp.int32),
            jnp.asarray(write_mask), slot_key,
            jnp.float32(max(self.temperature, 1e-6)),
        )
        slot = _Slot(uid=req.uid, prompt=req.tokens, max_new=req.max_new,
                     key=slot_key, pages=pages, total_pages=total,
                     out=[int(token0)])
        self.stats["admitted"] += 1
        if req.max_new == 1:  # prefill-only request: retire immediately
            self._retire(slot)
            return True
        self._slots[slot_i] = slot
        return True

    def _try_admit(self, req: Request) -> bool:
        """Fully admit ``req`` (prefill runs to completion within this
        call — chunk-sized programs if ``prefill_chunk`` is set, but never
        interleaved with decode; the driver interleaves)."""
        if not self.suffix_prefill:
            return self._try_admit_legacy(req)
        pf = self._begin_admit(req)
        if pf is None:
            return False
        while not self._prefill_step(pf, self.prefill_chunk):
            pass
        return True

    def cancel(self, uid: Any) -> bool:
        """Drop a request wherever it is — queued, prefilling, or decoding
        — releasing its pages and slot.  Returns False for unknown uids
        (already finished or never submitted).  No Result is produced."""
        for r in self._queue:
            if r.uid == uid:
                self._queue.remove(r)
                self.stats["cancelled"] += 1
                return True
        for pf in self._prefills:
            if pf.uid == uid:
                for page in pf.pages:
                    self._pool.release(page)
                self._prefills.remove(pf)
                self._reserved_slots.discard(pf.slot_index)
                self.stats["cancelled"] += 1
                return True
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.uid == uid:
                for page in slot.pages:
                    self._pool.release(page)
                self._slots[i] = None
                self.stats["cancelled"] += 1
                return True
        return False

    def _admit(self) -> None:
        while self._queue and self._free_slot() is not None:
            if not self._try_admit(self._queue[0]):
                break  # head-of-line blocks until pages free up
            self._queue.popleft()

    def _grow(self, slot: _Slot, extra: int = 0) -> None:
        """Lazy page growth: allocate the write page(s) just before they
        are needed (``extra`` covers a speculative step's lookahead —
        bounded by the budget, so it never exceeds the admission-time
        worst case).  Cannot fail — admission reserved that worst case."""
        need_pages = (slot.write_pos + extra) // self.page_size + 1
        while len(slot.pages) < need_pages:
            slot.pages.append(self._pool.alloc())
            self.stats["pages_allocated"] += 1
        self._sync_pool_stats()

    def _shrink(self, slot: _Slot) -> None:
        """Roll back a speculative step's page-table cursor: release the
        trailing pages past the (possibly rolled-back) write position.
        Trailing decode pages are never chain-hash registered, so release
        really frees them — the pool's three-state partition (free /
        retained / refcounted) survives every rollback."""
        keep = slot.write_pos // self.page_size + 1
        while len(slot.pages) > keep:
            self._pool.release(slot.pages.pop())
        self._sync_pool_stats()

    def _retire(self, slot: _Slot) -> None:
        for page in slot.pages:
            self._pool.release(page)
        self.stats["retired"] += 1
        self._results[slot.uid] = Result(
            uid=slot.uid,
            tokens=np.concatenate([slot.prompt,
                                   np.asarray(slot.out, np.int32)]),
        )

    # -- the decode step -------------------------------------------------

    def step(self) -> List[Any]:
        """Admit what fits, dispatch ONE decode step for the in-flight set,
        retire whatever the done-mask finished.  Returns retired uids."""
        before = set(self._results)
        self._admit()
        if self.active_slots == 0:
            return [u for u in self._results if u not in before]

        B, Pmax = self.max_slots, self.max_pages
        tokens = np.zeros((B,), np.int32)
        positions = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        budgets = np.full((B,), np.iinfo(np.int32).max, np.int32)
        active = np.zeros((B,), bool)
        tables = np.full((B, Pmax), SCRATCH_PAGE, np.int32)
        n_spec = np.zeros((B,), np.int32)  # proposals per slot this call
        keys = []
        for i, slot in enumerate(self._slots):
            if slot is None:
                keys.append(self._dummy_key)
                continue
            if self.speculative:
                n_spec[i] = min(self.draft_k, slot.max_new - len(slot.out))
                n_spec[i] = max(n_spec[i], 1)
            self._grow(slot, extra=max(int(n_spec[i]) - 1, 0))
            tokens[i] = slot.out[-1]
            positions[i] = slot.write_pos
            steps[i] = len(slot.out)
            budgets[i] = slot.max_new
            active[i] = True
            tables[i, :len(slot.pages)] = slot.pages
            keys.append(slot.key)

        tel = obs.get()
        with tel.span("serve.decode_step", slots=self.active_slots):
            if self.speculative:
                (sampled, counts, done, self._k_pool, self._v_pool,
                 self._dk_pool, self._dv_pool) = self._decode(
                    self.params, self._draft_params,
                    self._k_pool, self._v_pool, self._dk_pool, self._dv_pool,
                    jnp.asarray(tokens), jnp.asarray(positions),
                    jnp.asarray(steps), jnp.asarray(budgets),
                    jnp.asarray(active), jnp.asarray(tables),
                    jnp.stack(keys),
                    jnp.float32(max(self.temperature, 1e-6)),
                )
                counts = np.asarray(counts)
            else:
                sampled, done, self._k_pool, self._v_pool = self._decode(
                    self.params, self._k_pool, self._v_pool,
                    jnp.asarray(tokens), jnp.asarray(positions),
                    jnp.asarray(steps), jnp.asarray(budgets),
                    jnp.asarray(active), jnp.asarray(tables),
                    jnp.stack(keys),
                    jnp.float32(max(self.temperature, 1e-6)),
                )
                counts = None
        sampled = np.asarray(sampled)
        done = np.asarray(done)
        self.stats["decode_steps"] += 1
        if tel.enabled:
            tel.registry.counter("serve.decode_steps").inc()
            tel.registry.histogram(
                "serve.slot_occupancy", obs.RATIO_EDGES
            ).observe(self.active_slots / self.max_slots)

        drafted = accepted = 0
        for i, slot in enumerate(self._slots):
            if slot is None:
                continue
            if self.speculative:
                m = int(counts[i])
                slot.out.extend(int(t) for t in sampled[i, :m])
                drafted += int(n_spec[i]) - 1
                accepted += m - 1
                if not done[i]:
                    # roll the page-table cursor back over rejected tokens
                    self._shrink(slot)
            else:
                slot.out.append(int(sampled[i]))
            if done[i]:
                self._retire(slot)
                self._slots[i] = None
        if self.speculative:
            self.stats["spec_drafted"] += drafted
            self.stats["spec_accepted"] += accepted
            if tel.enabled:
                reg = tel.registry
                reg.counter("serve.spec_drafted").inc(drafted)
                reg.counter("serve.spec_accepted").inc(accepted)
                if drafted:
                    reg.histogram(
                        "serve.spec_accept_ratio", obs.RATIO_EDGES
                    ).observe(accepted / drafted)
                reg.histogram(
                    "serve.spec_rollback", SPEC_ROLLBACK_EDGES
                ).observe(drafted - accepted)
        return [u for u in self._results if u not in before]

    def run(self, requests: Optional[List[Request]] = None
            ) -> Dict[Any, Result]:
        """Submit ``requests`` (if given) and drain queue + slots to
        completion.  Returns every result produced so far, keyed by uid."""
        for req in requests or []:
            self.submit(req)
        while self._queue or self.active_slots:
            n_results = len(self._results)
            self.step()
            if (self.active_slots == 0 and self._queue
                    and len(self._results) == n_results):
                # submit() validates every request fits an empty pool, so
                # an idle server that cannot admit is a bookkeeping bug
                raise RuntimeError(
                    f"scheduler stalled with {len(self._queue)} queued "
                    f"requests and {self._pool.available_count} "
                    f"available pages")
        return dict(self._results)
