"""Population-powered speculative decoding for the continuous runtime.

WASH maintains a *population* whose uniform soup and whose logit-averaged
ensemble are both strong predictors — and, because shuffling keeps the
members in one loss basin (the PAPA/WASH premise), the soup's next-token
argmax usually agrees with the ensemble's.  Ensemble-mode decode pays N
member forward passes per emitted token; this module turns the population
structure into latency instead:

  1. **Draft** — the soup (one model, the cheap predictor) runs ``k``
     ordinary paged decode steps over its OWN draft pools, proposing
     ``d_1 .. d_{k-1}`` continuation tokens per slot.
  2. **Verify** — the vmapped ensemble runs ONE teacher-forced paged
     decode step over ``B·k`` flattened rows: row ``(b, j)`` feeds input
     ``i_j`` (the pending token for ``j = 0``, draft ``d_j`` after) at
     position ``pos_b + j`` through slot ``b``'s page table.  Because
     the paged attend scatters every row's K/V **before** attending, row
     ``j`` sees its sibling rows' keys/values exactly as ``j`` sequential
     steps would have written them — per-row the batched verify is
     bitwise the sequential decode.
  3. **Accept** — the verified token ``v_j`` is what non-speculative
     decode would have emitted at output index ``steps + j`` GIVEN inputs
     ``i_0..i_j`` were the true context; the longest prefix where each
     draft matched the previous verified token (``d_j == v_{j-1}``) is
     emitted, ``m = 1 + |prefix|`` tokens per slot per call.

**Bitwise contract** (``tests/test_speculative_properties.py``): at fp32
KV, the emitted stream is bit-identical to non-speculative decode — for
greedy AND temperature sampling, since ``v_j`` is sampled with the same
deterministic ``fold_in(key_b, steps_b + j)`` the plain path uses.
Rejected rows leave *stale* K/V at positions ``>= pos + m`` in both
pools; they are invisible (every later attend masks by its own length)
and are overwritten with identical values before any row can read them.
The host rolls page tables back via ``ContinuousServer._shrink``.

Everything here is **traced**: draft length ``k`` is the only new
executable-cache key component (``("continuous", ..., kv_dtype,
draft_k)``), so warm speculative streams add zero traces — the
trace-count contract of ``serving.batching`` extends unchanged.

int8 pools compose (draft and verify pools both quantize); the bitwise
claim then relaxes to the pinned tolerance of the quantized oracle,
because a page's scale couples every row written to it.

MoE configs are rejected: capacity-factor dispatch makes a token's
routing depend on its *batchmates*, which breaks the per-row argument
above (and the continuous runtime's solo-parity contract with it).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import averaging
from repro.models import transformer as M

#: draft lengths the property suite exercises; larger k is legal but the
#: verify step's B*k rows grow the decode program linearly
MAX_DRAFT_K = 8


def speculative_supported(cfg: ModelConfig) -> Optional[str]:
    """None if speculative decode can serve ``cfg``, else the reason.

    Needs everything suffix/chunk prefill needs (the draft pools are
    populated by the same chunk programs), plus dense MLPs: MoE capacity
    dispatch is batch-shape-dependent, so a ``B·k``-row verify step would
    not be bitwise the ``k`` sequential ``B``-row steps it replaces."""
    reason = M.paged_prefill_supported(cfg)
    if reason is not None:
        return reason
    if cfg.moe:
        return ("MoE capacity-factor routing depends on batchmates; the "
                "batched verify step would break bitwise parity")
    return None


def build_speculative_decode(cfg: ModelConfig, ensemble: bool, greedy: bool,
                             use_pallas: bool, draft_k: int):
    """The speculative continuous decode step (untraced; the runtime wraps
    it with ``jax.jit`` + donation + trace counters).

    ``program(params, draft_params, k_pool, v_pool, dk_pool, dv_pool,
    tokens, positions, steps, budgets, active, page_tables, keys,
    temperature)`` returns ``(sampled (B, k), counts (B,), done (B,),
    k_pool, v_pool, dk_pool, dv_pool)`` — ``sampled[b, :counts[b]]`` are
    the emitted tokens; entries past ``counts`` are zero-masked.

    ``params``/pools are the verify side (stacked population when
    ``ensemble``); ``draft_params``/``dk/dv_pool`` the single-model draft
    side.  Page tables are SHARED: the draft pools mirror the verify
    pools' geometry and page allocation, so one host page is one logical
    context slice in both.
    """
    # late import: batching imports this module lazily from its program
    # builder, so a module-level import back would be circular
    from repro.serving.batching import _sample_steps

    if draft_k < 1:
        raise ValueError(f"draft_k must be >= 1, got {draft_k}")
    reason = speculative_supported(cfg)
    if reason is not None:
        raise NotImplementedError(f"speculative decode: {reason}")
    k = int(draft_k)

    def program(params, draft_params, k_pool, v_pool, dk_pool, dv_pool,
                tokens, positions, steps, budgets, active, page_tables,
                keys, temperature):
        B = tokens.shape[0]
        # proposals this call may emit per slot: never past the budget,
        # so speculative writes stay inside the page reservation
        # (max write pos == the plain path's prompt_len + max_new - 2)
        n_valid = jnp.where(active, jnp.clip(budgets - steps, 0, k), 0)

        def masked(valid, pos, tables):
            # invalid rows write to (scratch page, offset 0) and read a
            # 1-token scratch context — garbage in, masked garbage out
            return (jnp.where(valid, pos, 0),
                    jnp.where(valid[:, None], tables,
                              jnp.zeros_like(tables)))

        # -- draft: k sequential soup steps over the draft pools --------
        # step j feeds input i_j at pos+j (writing its draft K/V) and
        # samples d_{j+1} = the soup's guess for output index steps+j
        inputs = []
        cur = tokens
        for j in range(k):
            pos_j, tab_j = masked(j < n_valid, positions + j, page_tables)
            lg, dpools = M.decode_step_paged(
                draft_params, cfg, cur, pos_j,
                {"k": dk_pool, "v": dv_pool}, tab_j, use_pallas,
            )
            dk_pool, dv_pool = dpools["k"], dpools["v"]
            inputs.append(cur)
            cur = _sample_steps(lg[:, -1], keys, steps + j, temperature,
                                greedy)
        inputs = jnp.stack(inputs, axis=1)            # (B, k): i_0..i_{k-1}

        # -- verify: ONE ensemble step over B*k teacher-forced rows -----
        valid2d = jnp.arange(k)[None, :] < n_valid[:, None]   # (B, k)
        pos2d = positions[:, None] + jnp.arange(k)[None, :]
        vpos, vtab = masked(valid2d.reshape(-1), pos2d.reshape(-1),
                            jnp.repeat(page_tables, k, axis=0))
        vtok = inputs.reshape(B * k)
        if ensemble:
            def member(p, kp, vp):
                lg, pools = M.decode_step_paged(
                    p, cfg, vtok, vpos, {"k": kp, "v": vp}, vtab,
                    use_pallas,
                )
                return lg, pools["k"], pools["v"]

            lgs, k_pool, v_pool = jax.vmap(member)(params, k_pool, v_pool)
            logits = averaging.balanced_mean(lgs)     # (B*k, 1, V)
        else:
            logits, pools = M.decode_step_paged(
                params, cfg, vtok, vpos, {"k": k_pool, "v": v_pool}, vtab,
                use_pallas,
            )
            k_pool, v_pool = pools["k"], pools["v"]
        lg2d = logits[:, -1].reshape(B, k, -1)
        # v_j sampled exactly as the plain path samples output steps+j
        v = jnp.stack(
            [_sample_steps(lg2d[:, j], keys, steps + j, temperature, greedy)
             for j in range(k)], axis=1)              # (B, k)

        # -- accept the longest matching prefix -------------------------
        # i_{j+1} (= draft d_{j+1}) correct  <=>  it equals v_j
        match = (inputs[:, 1:] == v[:, :k - 1]).astype(jnp.int32)
        m = 1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1)
        m = jnp.minimum(m, jnp.maximum(n_valid, 1))
        counts = jnp.where(active, m, 0)
        sampled = jnp.where(valid2d & (jnp.arange(k)[None, :] < m[:, None]),
                            v, 0)
        done = active & (steps + counts >= budgets)
        return sampled, counts, done, k_pool, v_pool, dk_pool, dv_pool

    return program
