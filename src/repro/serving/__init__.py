"""Serving: batched generation engine over prefill/decode."""

from repro.serving.engine import (
    averaged_params,
    generate,
    generate_from_population,
    internal_prefix,
)

__all__ = [
    "averaged_params",
    "generate",
    "generate_from_population",
    "internal_prefix",
]
