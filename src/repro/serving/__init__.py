"""Serving: fused scan engine + continuous-batching runtime (see README.md)."""

from repro.serving.batching import ContinuousServer, Request, Result
from repro.serving.driver import (
    QueueFull,
    RequestDriver,
    RequestMetrics,
    poisson_arrivals,
    summarize,
)
from repro.serving.engine import (
    MODES,
    averaged_params,
    clear_executable_cache,
    decode_trace_count,
    executable_cache_size,
    generate,
    generate_from_population,
    generate_reference,
    internal_prefix,
    prefill_trace_count,
    reference_trace_count,
    reset_trace_counts,
    serving_params,
)

__all__ = [
    "ContinuousServer",
    "MODES",
    "QueueFull",
    "Request",
    "RequestDriver",
    "RequestMetrics",
    "Result",
    "poisson_arrivals",
    "summarize",
    "averaged_params",
    "clear_executable_cache",
    "decode_trace_count",
    "executable_cache_size",
    "generate",
    "generate_from_population",
    "generate_reference",
    "internal_prefix",
    "prefill_trace_count",
    "reference_trace_count",
    "reset_trace_counts",
    "serving_params",
]
