"""Serving: batched generation engine over prefill/decode."""

from repro.serving.engine import generate, internal_prefix

__all__ = ["generate", "internal_prefix"]
