"""Serving: fused scan-based batched generation engine (see README.md)."""

from repro.serving.engine import (
    MODES,
    averaged_params,
    clear_executable_cache,
    decode_trace_count,
    executable_cache_size,
    generate,
    generate_from_population,
    generate_reference,
    internal_prefix,
    prefill_trace_count,
    reference_trace_count,
    reset_trace_counts,
    serving_params,
)

__all__ = [
    "MODES",
    "averaged_params",
    "clear_executable_cache",
    "decode_trace_count",
    "executable_cache_size",
    "generate",
    "generate_from_population",
    "generate_reference",
    "internal_prefix",
    "prefill_trace_count",
    "reference_trace_count",
    "reset_trace_counts",
    "serving_params",
]
