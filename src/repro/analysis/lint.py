"""Repo lints: tracer hazards, f32 comm accumulators, thread discipline.

Three rule families over stdlib ``ast`` (this module imports no jax, so
the lint layer runs anywhere, CI included, without touching a backend):

``tracer-hazard``
    Host-only calls inside functions that jax traces (``jit`` /
    ``shard_map`` / ``scan`` / ``vmap`` / ... bodies): ``float()`` /
    ``int()`` on traced values, ``.item()``, ``np.*``, ``time.*`` and
    stdlib ``random.*``.  Each of these either silently bakes a
    trace-time constant into the executable or raises a
    ``TracerConversionError`` at the first real call.  ``int()`` /
    ``float()`` over static metadata (``x.shape[...]``, ``x.ndim``,
    ``x.size``, ``len(...)``) is exempt — shapes are Python ints under
    tracing — as are ``np.iinfo`` / ``np.finfo`` / dtype constructors,
    which are trace-time constants by construction.

``f32-accumulator``
    Assignments to comm/metrics accounting names (``*comm*``,
    ``*_total``, ``*_bytes``) from expressions that mention a narrow
    float dtype (``float32`` / ``float16`` / ``bfloat16``).  The paper's
    communication claim is reported from host-side accounting that must
    stay exact float64 (``docs/OBSERVABILITY.md``): a float32 running sum
    loses integer exactness past 2^24 bytes and breaks the bit-equal
    replay contract checked by ``tools/check_metrics_schema.py``.

``thread-discipline``
    For classes that spawn threads (``threading.Thread(target=...)`` or
    ``executor.submit(fn, ...)``): every attribute *written* by code the
    thread target can reach must be lock-guarded at EVERY access in the
    class — lexically inside ``with self.<lock>`` or in a method whose
    call sites are all lock-held (computed as a greatest fixpoint over
    the intra-class call graph, so private helpers called only under the
    lock count as guarded).  ``__init__`` is exempt (it happens-before
    the thread starts), as are synchronization primitives themselves
    (``Lock`` / ``Event`` / ``Queue`` / ...).  The analysis is
    class-scoped: module-level thread targets that touch no ``self``
    state (e.g. the train engine's staging closure) have nothing to
    check.

Violations carry a stable ``key`` (rule:path:function:detail — no line
numbers, so baselines survive unrelated edits).  ``load_baseline`` /
``apply_baseline`` implement the *checked* suppression workflow: a
baseline entry that no longer matches any violation is itself an error,
so waivers cannot outlive the code they excused.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Violation",
    "lint_source",
    "lint_file",
    "lint_tree",
    "load_baseline",
    "apply_baseline",
    "RULES",
]

RULES = ("tracer-hazard", "f32-accumulator", "thread-discipline")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str  # repo-relative (or as given)
    line: int
    func: str  # enclosing function qualname, or "<module>"
    detail: str  # stable discriminator (e.g. "float()", "attr:_pending")
    message: str

    @property
    def key(self) -> str:
        """Baseline key — deliberately line-number free."""
        return f"{self.rule}:{self.path}:{self.func}:{self.detail}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

# callables whose function-valued arguments jax traces.  Bare names AND
# attribute forms both count ("scan" / "lax.scan" / "jax.lax.scan");
# "map" only as an attribute (lax.map), never the builtin.
_TRACERS = {
    "jit", "shard_map", "pmap", "vmap", "grad", "value_and_grad",
    "scan", "fori_loop", "while_loop", "cond", "switch",
    "remat", "checkpoint", "eval_shape", "associative_scan", "custom_vjp",
}
_TRACERS_ATTR_ONLY = {"map"}


def _last_seg(func: ast.expr) -> Optional[str]:
    """Final name segment of a call target: jax.lax.scan -> "scan"."""
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _root_name(node: ast.expr) -> Optional[str]:
    """Root of an attribute/subscript/call chain: np.iinfo(x).max -> "np"."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def _is_tracer_call(call: ast.Call) -> bool:
    seg = _last_seg(call.func)
    if seg in _TRACERS:
        return True
    return seg in _TRACERS_ATTR_ONLY and isinstance(call.func, ast.Attribute)


def _is_tracer_ref(node: ast.expr) -> bool:
    """Is this expression a reference to a tracing transform (jax.jit,
    shard_map, ...)?  Used to resolve ``functools.partial(jax.jit, ...)``
    decorators."""
    return isinstance(node, (ast.Name, ast.Attribute)) and (
        _last_seg(node) in _TRACERS
    )


class _Parented(ast.NodeVisitor):
    def __init__(self) -> None:
        self.parents: Dict[ast.AST, ast.AST] = {}

    def generic_visit(self, node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            self.parents[child] = node
        super().generic_visit(node)


def _qualname(node: ast.AST, parents: Dict[ast.AST, ast.AST]) -> str:
    parts: List[str] = []
    cur: Optional[ast.AST] = node
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            parts.append(cur.name)
        elif isinstance(cur, ast.Lambda):
            parts.append("<lambda>")
        elif isinstance(cur, ast.ClassDef):
            parts.append(cur.name)
        cur = parents.get(cur)
    return ".".join(reversed(parts)) or "<module>"


# ---------------------------------------------------------------------------
# rule 1: tracer hazards
# ---------------------------------------------------------------------------

_NP_STATIC_OK = {
    "iinfo", "finfo", "dtype",
    "int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
    "uint64", "float16", "float32", "float64", "bool_",
}


def _is_static_metadata(expr: ast.expr) -> bool:
    """True when ``int()``/``float()`` over this expression is trace-safe:
    the value derives from shape/rank metadata, which jax exposes as
    Python ints even under tracing."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in (
            "shape", "ndim", "size",
        ):
            return True
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "len"):
            return True
    return False


def _collect_traced(tree: ast.Module) -> List[ast.AST]:
    """Function/lambda nodes whose bodies jax traces.

    A function is traced when (a) it is decorated with a tracing
    transform — directly (``@jax.jit``), via ``functools.partial``
    (``@partial(jax.jit, static_argnums=...)``) or a transform call
    (``@shard_map(...)``) — or (b) it is passed by name (or inline as a
    lambda) to a tracing call anywhere in the module.  Functions nested
    inside a traced function are traced with it.
    """
    by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)

    traced: Set[ast.AST] = set()

    def _mark_arg(arg: ast.expr) -> None:
        if isinstance(arg, ast.Lambda):
            traced.add(arg)
        elif isinstance(arg, ast.Name):
            for fn in by_name.get(arg.id, ()):
                traced.add(fn)
        elif isinstance(arg, ast.Call) and _last_seg(arg.func) == "partial":
            for sub in arg.args:
                _mark_arg(sub)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_tracer_ref(dec):
                    traced.add(node)
                elif isinstance(dec, ast.Call):
                    if _is_tracer_call(dec):
                        traced.add(node)
                    elif _last_seg(dec.func) == "partial" and any(
                        _is_tracer_ref(a) for a in dec.args
                    ):
                        traced.add(node)
        if isinstance(node, ast.Call) and _is_tracer_call(node):
            for arg in node.args:
                _mark_arg(arg)
            for kw in node.keywords:
                # e.g. Thread-style f=..., or scan(f=body)
                if kw.arg in ("f", "body", "body_fun", "cond_fun", "fun"):
                    _mark_arg(kw.value)

    # fold nested defs into their traced ancestors so each traced region
    # is walked exactly once
    roots: List[ast.AST] = []
    parents = _Parented()
    parents.visit(tree)
    for fn in traced:
        cur = parents.parents.get(fn)
        inherited = False
        while cur is not None:
            if cur in traced:
                inherited = True
                break
            cur = parents.parents.get(cur)
        if not inherited:
            roots.append(fn)
    return roots


def _tracer_hazards(tree: ast.Module, path: str,
                    parents: Dict[ast.AST, ast.AST]) -> List[Violation]:
    out: List[Violation] = []
    for root in _collect_traced(tree):
        fname = _qualname(root, parents)
        for node in ast.walk(root):
            if not isinstance(node, ast.Call):
                continue
            viol: Optional[str] = None
            msg = ""
            if isinstance(node.func, ast.Name) and node.func.id in (
                "float", "int",
            ):
                if not (node.args and _is_static_metadata(node.args[0])):
                    viol = f"{node.func.id}()"
                    msg = (f"{node.func.id}() on a traced value forces a "
                           "host transfer (exempt: shape/ndim/size/len "
                           "metadata)")
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "item"):
                viol = ".item()"
                msg = ".item() inside a traced function forces a host sync"
            else:
                root_id = _root_name(node.func)
                seg = _last_seg(node.func)
                if root_id in ("np", "numpy"):
                    chain = {n.attr for n in ast.walk(node.func)
                             if isinstance(n, ast.Attribute)}
                    if not chain & _NP_STATIC_OK:
                        viol = f"np.{seg}"
                        msg = (f"numpy call ({ast.unparse(node.func)}) in a "
                               "traced function is a trace-time constant — "
                               "use jnp, or hoist to the host")
                elif root_id == "time":
                    viol = f"time.{seg}"
                    msg = ("time.* in a traced function runs once at trace "
                           "time, not per step")
                elif root_id == "random":
                    viol = f"random.{seg}"
                    msg = ("stdlib random in a traced function bakes one "
                           "draw into the executable — use jax.random with "
                           "an explicit key")
            if viol is not None:
                out.append(Violation(
                    "tracer-hazard", path, node.lineno,
                    _qualname(node, parents) or fname, viol, msg,
                ))
    return out


# ---------------------------------------------------------------------------
# rule 2: f32 accumulators in comm/metrics accounting
# ---------------------------------------------------------------------------

# compound accounting names only: "comm_total", "bytes_total",
# "tokens_total", anything mentioning comm.  A bare local "total" (e.g.
# an on-device f32 metric reduction) is not accounting state.
_ACC_NAME_RE = re.compile(r"comm|\w_(?:total|bytes)$")
_NARROW = {"float32", "float16", "bfloat16", "f32", "f16", "bf16"}


def _target_names(target: ast.expr) -> Iterable[str]:
    for node in ast.walk(target):
        if isinstance(node, ast.Name):
            yield node.id
        elif isinstance(node, ast.Attribute):
            yield node.attr


def _mentions_narrow_float(expr: ast.expr) -> Optional[str]:
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in _NARROW:
            return node.attr
        if isinstance(node, ast.Name) and node.id in _NARROW:
            return node.id
        if isinstance(node, ast.Constant) and node.value in _NARROW:
            return str(node.value)
    return None


def _f32_accumulators(tree: ast.Module, path: str,
                      parents: Dict[ast.AST, ast.AST]) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            if node.value is None:
                continue
            targets, value = [node.target], node.value
        else:
            continue
        hit = None
        for t in targets:
            for name in _target_names(t):
                if _ACC_NAME_RE.search(name):
                    hit = name
                    break
            if hit:
                break
        if hit is None:
            continue
        narrow = _mentions_narrow_float(value)
        if narrow is not None:
            out.append(Violation(
                "f32-accumulator", path, node.lineno,
                _qualname(node, parents), f"{hit}:{narrow}",
                f"accounting name {hit!r} assigned via {narrow} — comm/"
                "metrics accumulators must stay exact float64 (Python "
                "float); see docs/OBSERVABILITY.md",
            ))
    return out


# ---------------------------------------------------------------------------
# rule 3: thread discipline
# ---------------------------------------------------------------------------

_SYNC_TYPES = {
    "Lock", "RLock", "Condition", "Event", "Semaphore", "BoundedSemaphore",
    "Barrier", "Queue", "SimpleQueue", "local", "Thread",
}
_LOCK_TYPES = {"Lock", "RLock", "Condition"}
_MUTATORS = {
    "append", "extend", "insert", "pop", "popleft", "appendleft", "remove",
    "clear", "update", "add", "discard", "setdefault",
}


def _self_attr(node: ast.expr) -> Optional[str]:
    """Innermost ``self.X`` attribute of a store/load chain:
    ``self.metrics[uid].admitted`` -> "metrics"."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return node.attr
        node = node.value
    return None


@dataclasses.dataclass
class _FnInfo:
    node: ast.AST
    qualname: str
    reads: List[Tuple[str, ast.AST, bool]]  # (attr, node, guarded)
    writes: List[Tuple[str, ast.AST, bool]]
    # (callee-name, guarded) for self.X() calls / property loads / bare
    # calls of sibling nested functions
    calls: List[Tuple[str, bool]]
    is_entry: bool = False  # a thread target


class _ClassScanner:
    """Per-class accounting for the thread-discipline rule."""

    def __init__(self, cls: ast.ClassDef,
                 parents: Dict[ast.AST, ast.AST]) -> None:
        self.cls = cls
        self.parents = parents
        self.fns: Dict[str, _FnInfo] = {}
        self.lock_attrs: Set[str] = set()
        self.sync_attrs: Set[str] = set()
        self.entries: Set[str] = set()
        self._scan()

    def _scan(self) -> None:
        # classify __init__-assigned sync primitives first
        for stmt in self.cls.body:
            if (isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and stmt.name == "__init__"):
                for node in ast.walk(stmt):
                    if not isinstance(node, ast.Assign):
                        continue
                    if not (isinstance(node.value, ast.Call)
                            and _last_seg(node.value.func) in _SYNC_TYPES):
                        continue
                    for t in node.targets:
                        attr = _self_attr(t)
                        if attr is None:
                            continue
                        self.sync_attrs.add(attr)
                        if _last_seg(node.value.func) in _LOCK_TYPES:
                            self.lock_attrs.add(attr)

        for stmt in self.cls.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_fn(stmt, stmt.name)

    # -- per-function walk, tracking lexical lock guards -----------------

    def _scan_fn(self, fn: ast.AST, name: str) -> None:
        info = _FnInfo(fn, name, [], [], [])
        self.fns[name] = info
        body = fn.body if isinstance(
            fn, (ast.FunctionDef, ast.AsyncFunctionDef)) else [fn.body]
        for stmt in body:
            self._walk(stmt, info, guarded=False)

    def _walk(self, node: ast.AST, info: _FnInfo, guarded: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested function: its own accounting unit (a thread target
            # candidate); lexical guards do not cross the boundary
            self._scan_fn(node, f"{info.qualname}.<locals>.{node.name}")
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            locks_here = any(
                _self_attr(item.context_expr) in self.lock_attrs
                for item in node.items
            )
            for item in node.items:
                self._walk(item.context_expr, info, guarded)
            for stmt in node.body:
                self._walk(stmt, info, guarded or locks_here)
            return

        self._record(node, info, guarded)
        for child in ast.iter_child_nodes(node):
            self._walk(child, info, guarded)

    def _record(self, node: ast.AST, info: _FnInfo, guarded: bool) -> None:
        # thread spawns: Thread(target=X) / executor.submit(X, ...)
        if isinstance(node, ast.Call):
            seg = _last_seg(node.func)
            target: Optional[ast.expr] = None
            if seg == "Thread":
                for kw in node.keywords:
                    if kw.arg == "target":
                        target = kw.value
            elif seg == "submit" and node.args:
                target = node.args[0]
            if target is not None:
                tname = self._callable_name(target, info)
                if tname is not None:
                    self.entries.add(tname)

            callee = self._self_call(node.func)
            if callee is not None:
                info.calls.append((callee, guarded))
                return  # the func expr is a call edge, not a data read
            if isinstance(node.func, ast.Name):
                info.calls.append(
                    (f"{info.qualname}.<locals>.{node.func.id}", guarded))

        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                attr = _self_attr(t)
                if attr is not None:
                    info.writes.append((attr, node, guarded))
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                attr = _self_attr(t)
                if attr is not None:
                    info.writes.append((attr, node, guarded))
        elif isinstance(node, ast.Call):
            # self.attr.append(...) and friends mutate the container
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _MUTATORS):
                attr = _self_attr(node.func.value)
                if attr is not None:
                    info.writes.append((attr, node, guarded))
        elif isinstance(node, ast.Attribute) and isinstance(
                node.ctx, ast.Load):
            attr = _self_attr(node)
            if attr is None:
                return
            if attr in self.fns or attr in {
                s.name for s in self.cls.body
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))
            }:
                # method or property reference -> call edge
                info.calls.append((attr, guarded))
            else:
                info.reads.append((attr, node, guarded))

    def _self_call(self, func: ast.expr) -> Optional[str]:
        if (isinstance(func, ast.Attribute)
                and isinstance(func.value, ast.Name)
                and func.value.id == "self"):
            return func.attr
        return None

    def _callable_name(self, target: ast.expr,
                       info: _FnInfo) -> Optional[str]:
        if isinstance(target, ast.Name):
            return f"{info.qualname}.<locals>.{target.id}"
        name = self._self_call(target)
        return name

    # -- analysis --------------------------------------------------------

    def violations(self, path: str) -> List[Violation]:
        # keep only spawn targets that resolve to a function of this
        # class: ``driver.submit(request)`` is a queue method taking a
        # Request, not an executor spawning ``request`` on a thread
        entries: Set[str] = set()
        for e in self.entries:
            r = e if e in self.fns else self._resolve(e)
            if r is not None and r in self.fns:
                entries.add(r)
        if not entries:
            return []

        # thread-reachable functions: closure over call edges from entries
        reachable: Set[str] = set()
        frontier = list(entries)
        while frontier:
            cur = frontier.pop()
            if cur in reachable:
                continue
            reachable.add(cur)
            for callee, _ in self.fns[cur].calls:
                resolved = self._resolve(callee)
                if resolved is not None and resolved not in reachable:
                    frontier.append(resolved)

        # greatest-fixpoint lock_held: f is lock-held when every one of
        # its call sites is lexically guarded or sits in a lock-held
        # caller.  Entries and call-site-free functions are never
        # lock-held (they can be entered from anywhere).
        sites: Dict[str, List[Tuple[str, bool]]] = {n: [] for n in self.fns}
        for caller, info in self.fns.items():
            for callee, guarded in info.calls:
                resolved = self._resolve(callee)
                if resolved is not None:
                    sites[resolved].append((caller, guarded))
        lock_held = {
            n: bool(sites[n]) and n not in entries and n != "__init__"
            for n in self.fns
        }
        changed = True
        while changed:
            changed = False
            for n, held in list(lock_held.items()):
                if not held:
                    continue
                ok = all(g or lock_held.get(c, False) for c, g in sites[n])
                if not ok:
                    lock_held[n] = False
                    changed = True

        thread_written: Set[str] = set()
        for n in reachable:
            if n == "__init__":
                continue
            for attr, _, _ in self.fns[n].writes:
                if attr not in self.sync_attrs:
                    thread_written.add(attr)

        out: List[Violation] = []
        for n, info in self.fns.items():
            if n == "__init__" or n.endswith(".<locals>.__init__"):
                continue
            held = lock_held.get(n, False)
            for attr, node, guarded in info.writes + info.reads:
                if attr not in thread_written or guarded or held:
                    continue
                kind = ("written" if any(
                    a == attr and nd is node for a, nd, _ in info.writes
                ) else "read")
                out.append(Violation(
                    "thread-discipline", path, node.lineno,
                    f"{self.cls.name}.{n}", f"attr:{attr}",
                    f"self.{attr} is written by the "
                    f"{'/'.join(sorted(entries))} thread but {kind} "
                    f"here without holding the lock "
                    f"({', '.join(sorted(self.lock_attrs)) or 'none found'})",
                ))
        return out

    def _resolve(self, callee: str) -> Optional[str]:
        if callee in self.fns:
            return callee
        # nested-name fallback: "<method>.<locals>.f" recorded from a
        # bare call may actually be a sibling method or a module function
        tail = callee.rsplit(".", 1)[-1]
        return tail if tail in self.fns else None


def _thread_discipline(tree: ast.Module, path: str,
                       parents: Dict[ast.AST, ast.AST]) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            out.extend(_ClassScanner(node, parents).violations(path))
    return out


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def lint_source(src: str, path: str = "<string>") -> List[Violation]:
    """All rules over one source string.  ``path`` labels the violations
    (use a repo-relative path so baseline keys are machine-independent)."""
    tree = ast.parse(src, filename=path)
    p = _Parented()
    p.visit(tree)
    out: List[Violation] = []
    out.extend(_tracer_hazards(tree, path, p.parents))
    out.extend(_f32_accumulators(tree, path, p.parents))
    out.extend(_thread_discipline(tree, path, p.parents))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def lint_file(path: Path, root: Optional[Path] = None) -> List[Violation]:
    path = Path(path)
    label = str(path.relative_to(root)) if root else str(path)
    return lint_source(path.read_text(), label.replace("\\", "/"))


def lint_tree(root: Path,
              subdirs: Sequence[str] = ("src/repro",)) -> List[Violation]:
    """Lint every ``*.py`` under ``root``'s ``subdirs`` (repo-relative
    violation paths)."""
    root = Path(root)
    out: List[Violation] = []
    for sub in subdirs:
        base = root / sub
        for path in sorted(base.rglob("*.py")):
            out.extend(lint_file(path, root=root))
    return out


# ---------------------------------------------------------------------------
# checked suppression baseline
# ---------------------------------------------------------------------------


def load_baseline(path: Path) -> Dict[str, str]:
    """``key  # justification`` lines -> {key: justification}.  Every
    entry MUST carry a justification comment — an unexplained waiver is a
    parse error, not a style nit."""
    out: Dict[str, str] = {}
    for lineno, raw in enumerate(Path(path).read_text().splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        key, sep, why = line.partition("#")
        key, why = key.strip(), why.strip()
        if not sep or not why:
            raise ValueError(
                f"{path}:{lineno}: baseline entry {key!r} has no "
                "justification comment (format: 'key  # why this is ok')")
        out[key] = why
    return out


def apply_baseline(
    violations: Sequence[Violation], baseline: Dict[str, str],
) -> Tuple[List[Violation], List[str]]:
    """(unwaived violations, stale baseline keys).  A stale key — one
    matching no current violation — is an error at the caller: the code
    it excused is gone, so the waiver must go too."""
    keys = {v.key for v in violations}
    remaining = [v for v in violations if v.key not in baseline]
    stale = sorted(k for k in baseline if k not in keys)
    return remaining, stale
