"""Compiled-program contracts over optimized HLO.

Promotes :mod:`repro.launch.hlo_stats`'s HLO-text parsing into a
programmatic checker: a :class:`Contract` states what a compiled program
is allowed to do on the wire and with its buffers, and
:func:`lower_and_check` / :func:`check_hlo` assert it against the
optimized module XLA actually scheduled — not against what the Python
source looks like it should lower to.

What a contract can pin down:

* **Collective footprint** — which collective kinds must appear
  (``require_collectives``), which must not (``forbid_collectives``),
  and exact/bounded op counts per kind (``counts``).  Async
  ``-start``/``-done`` pairs count once (``hlo_stats`` handles the
  pairing).
* **Permute topology** — every ``collective-permute``'s
  ``source_target_pairs`` must satisfy at least one :class:`PairRule`:
  :func:`stage_ring` is the WASH mixer's invariant on an (ens, pipe)
  mesh (``src ≡ tgt mod S`` — member exchange never crosses a stage
  boundary), :func:`forward_hop` is staged decode's (``tgt == src + 1``,
  never wrapping — activations only move one stage forward), and
  :func:`backward_hop` is the AD-transposed gradient hop a training
  pipeline's backward pass adds (``tgt == src - 1``).
* **Donation honored** — the ``input_output_alias`` block of the
  optimized module must alias *every* flat leaf of every donated
  argument.  jax silently drops donation it cannot use; this turns the
  silent drop into a failure.
* **Collective dtypes** — the element types collectives move
  (``collective_dtypes``), so a mixed-precision regression that starts
  shipping f32 where bf16 was promised (or vice versa) fails loudly.

Host-side companions (the accounting the paper's comm-volume claim rides
on is *host* float64, it never lowers): :func:`check_host_comm_f64`
asserts comm scalars are exact builtin floats (IEEE f64) and
:func:`replay_comm` re-runs the per-step accumulation bit-for-bit.
:func:`check_compile_count` wraps the engines' trace counters into the
same violation vocabulary.

The shipped contract matrix for the repo's four compiled programs lives
in :mod:`repro.analysis.matrix`; ``tools/run_analysis.py`` runs it in CI.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax

from repro.launch import hlo_stats

__all__ = [
    "Contract",
    "CheckReport",
    "ContractViolation",
    "PairRule",
    "stage_ring",
    "forward_hop",
    "backward_hop",
    "flat_donated_params",
    "check_hlo",
    "lower_and_check",
    "collective_footprint",
    "check_host_comm_f64",
    "replay_comm",
    "check_compile_count",
]


class ContractViolation(AssertionError):
    """A compiled program broke its contract.  ``problems`` lists every
    failed clause; ``report`` (when present) carries the parsed HLO
    evidence."""

    def __init__(self, name: str, problems: Sequence[str],
                 report: Optional["CheckReport"] = None) -> None:
        self.name = name
        self.problems = list(problems)
        self.report = report
        lines = "\n".join(f"  - {p}" for p in self.problems)
        super().__init__(f"contract {name!r} violated:\n{lines}")


@dataclasses.dataclass(frozen=True)
class PairRule:
    """A predicate over one collective-permute (src, tgt) pair."""

    kind: str  # "stage_ring" | "forward_hop" | "backward_hop"
    stages: int

    def __post_init__(self) -> None:
        if self.kind not in ("stage_ring", "forward_hop", "backward_hop"):
            raise ValueError(f"unknown pair rule {self.kind!r}")
        if self.stages < 1:
            raise ValueError("stages must be >= 1")

    def ok(self, src: int, tgt: int) -> bool:
        if self.kind == "stage_ring":
            return src % self.stages == tgt % self.stages
        # on a pipe-only mesh device id == stage id, on (ens, pipe)
        # id = e*S + p — either way stage = id % S and hops never wrap
        if self.kind == "forward_hop":
            return tgt == src + 1 and src % self.stages != self.stages - 1
        # backward_hop: reverse-mode AD transposes the forward ppermute
        # chain, shipping boundary gradients one stage back
        return tgt == src - 1 and src % self.stages != 0

    def describe(self) -> str:
        if self.kind == "stage_ring":
            return f"src ≡ tgt (mod {self.stages})"
        if self.kind == "forward_hop":
            return f"tgt == src + 1 (within a {self.stages}-stage pipe)"
        return f"tgt == src - 1 (within a {self.stages}-stage pipe)"


def stage_ring(stages: int) -> PairRule:
    """Permutes stay inside one stage's ens ring: ``src ≡ tgt mod S``."""
    return PairRule("stage_ring", stages)


def forward_hop(stages: int) -> PairRule:
    """Permutes move exactly one stage forward, never wrapping."""
    return PairRule("forward_hop", stages)


def backward_hop(stages: int) -> PairRule:
    """Permutes move exactly one stage backward, never wrapping — the
    AD-transposed image of :func:`forward_hop` in a training pipeline's
    backward pass."""
    return PairRule("backward_hop", stages)


@dataclasses.dataclass(frozen=True)
class Contract:
    """What one compiled program may do on the wire / with its buffers.

    ``counts`` maps a collective kind to an exact count (int) or an
    inclusive ``(lo, hi)`` range.  ``collective_dtypes`` maps a kind to
    the element dtypes it is allowed to move (HLO spellings: "f32",
    "bf16", ...).  ``donate_argnums`` are positional argnums of the
    *Python* callable; :func:`lower_and_check` expands them to flat HLO
    parameter numbers via the example arguments' pytree structure."""

    name: str
    require_collectives: Tuple[str, ...] = ()
    forbid_collectives: Tuple[str, ...] = ()
    counts: Optional[Mapping[str, Any]] = None
    permute_rules: Tuple[PairRule, ...] = ()
    donate_argnums: Tuple[int, ...] = ()
    collective_dtypes: Optional[Mapping[str, Sequence[str]]] = None


@dataclasses.dataclass
class CheckReport:
    """Parsed evidence + verdict for one contract check."""

    contract: Contract
    counts: Dict[str, int]
    bytes: Dict[str, int]
    permute_pairs: List[List[Tuple[int, int]]]
    dtypes: Dict[str, set]
    aliased_params: set
    expected_donated: Tuple[int, ...]
    problems: List[str]

    @property
    def ok(self) -> bool:
        return not self.problems


def flat_donated_params(args: Sequence[Any],
                        donate_argnums: Sequence[int]) -> Tuple[int, ...]:
    """Flat HLO parameter numbers covered by ``donate_argnums``.

    jit flattens its arguments' pytrees in positional order, one HLO
    parameter per leaf — so argnum ``i`` owns the contiguous run of
    parameter numbers at its flatten offset."""
    sizes = [len(jax.tree_util.tree_leaves(a)) for a in args]
    offsets = [0]
    for s in sizes:
        offsets.append(offsets[-1] + s)
    out: List[int] = []
    for i in donate_argnums:
        if not 0 <= i < len(args):
            raise ValueError(f"donate argnum {i} out of range for "
                             f"{len(args)} arguments")
        out.extend(range(offsets[i], offsets[i] + sizes[i]))
    return tuple(out)


def _hlo_text(obj: Any) -> str:
    """Accept raw HLO text, a ``.compile()``d executable, or anything
    with ``as_text``."""
    if isinstance(obj, str):
        return obj
    if hasattr(obj, "as_text"):
        return obj.as_text()
    raise TypeError(f"expected HLO text or a compiled executable, "
                    f"got {type(obj)!r}")


def check_hlo(hlo: Any, contract: Contract,
              donated_params: Optional[Sequence[int]] = None,
              raise_on_violation: bool = True) -> CheckReport:
    """Assert ``contract`` against an optimized-HLO module.

    ``donated_params`` are the flat parameter numbers that must appear in
    ``input_output_alias`` (from :func:`flat_donated_params`; pass
    explicitly when calling with raw text).  Returns the
    :class:`CheckReport`; raises :class:`ContractViolation` on failure
    unless ``raise_on_violation=False``."""
    text = _hlo_text(hlo)
    counts = hlo_stats.collective_counts(text)
    byts = hlo_stats.collective_bytes(text)
    pairs = hlo_stats.collective_permute_pairs(text)
    dtypes = hlo_stats.collective_result_dtypes(text)
    aliased = hlo_stats.input_output_aliased_params(text)
    expected = tuple(donated_params or ())

    problems: List[str] = []
    for kind in contract.require_collectives:
        if counts.get(kind, 0) == 0:
            problems.append(f"required collective {kind!r} absent")
    for kind in contract.forbid_collectives:
        if counts.get(kind, 0) != 0:
            problems.append(
                f"forbidden collective {kind!r} present "
                f"({counts[kind]} ops, {byts.get(kind, 0)} bytes)")
    if contract.counts:
        for kind, want in contract.counts.items():
            have = counts.get(kind, 0)
            if isinstance(want, tuple):
                lo, hi = want
                if not lo <= have <= hi:
                    problems.append(
                        f"{kind}: {have} ops outside [{lo}, {hi}]")
            elif have != want:
                problems.append(f"{kind}: {have} ops, expected {want}")
    if contract.permute_rules:
        if not pairs:
            problems.append(
                "permute rules given but no collective-permute lowered")
        for op in pairs:
            for src, tgt in op:
                if not any(r.ok(src, tgt) for r in contract.permute_rules):
                    rules = " or ".join(
                        r.describe() for r in contract.permute_rules)
                    problems.append(
                        f"permute pair ({src} -> {tgt}) violates {rules}")
    missing = sorted(set(expected) - aliased)
    if missing:
        problems.append(
            f"donated parameters {missing} not aliased in input_output_alias"
            f" (aliased: {sorted(aliased)}) — donation was dropped")
    if contract.collective_dtypes:
        for kind, allowed in contract.collective_dtypes.items():
            extra = dtypes.get(kind, set()) - set(allowed)
            if extra:
                problems.append(
                    f"{kind} moves dtypes {sorted(extra)} outside allowed "
                    f"{sorted(allowed)}")

    report = CheckReport(contract, counts, byts, pairs, dtypes, aliased,
                         expected, problems)
    if problems and raise_on_violation:
        raise ContractViolation(contract.name, problems, report)
    return report


def lower_and_check(fn: Callable, args: Sequence[Any], contract: Contract,
                    raise_on_violation: bool = True) -> CheckReport:
    """Lower ``fn(*args)`` to optimized HLO and assert ``contract``.

    ``fn`` may be a plain callable (jitted here, with the contract's
    ``donate_argnums`` attached so the donation clause tests the real
    thing) or an already-wrapped jit function (its own donation applies
    — pass the contract's ``donate_argnums`` to state what *should* be
    donated).  ``args`` may be arrays or ``jax.ShapeDtypeStruct``
    templates; nothing is executed, only lowered and compiled."""
    jitted = fn if hasattr(fn, "lower") else jax.jit(
        fn, donate_argnums=contract.donate_argnums)
    compiled = jitted.lower(*args).compile()
    donated = flat_donated_params(args, contract.donate_argnums)
    return check_hlo(compiled.as_text(), contract, donated_params=donated,
                     raise_on_violation=raise_on_violation)


def collective_footprint(hlo: Any) -> Dict[str, Any]:
    """One-call summary (counts / bytes / permute pairs) for footprint
    equality assertions — e.g. "the dryrun mixer lowers the identical
    collectives as the real one"."""
    text = _hlo_text(hlo)
    return {
        "counts": hlo_stats.collective_counts(text),
        "bytes": hlo_stats.collective_bytes(text),
        "permute_pairs": hlo_stats.collective_permute_pairs(text),
    }


# ---------------------------------------------------------------------------
# host-side contracts: f64 comm accounting + compile counts
# ---------------------------------------------------------------------------


def check_host_comm_f64(values: Mapping[str, Any],
                        name: str = "host-comm") -> None:
    """Comm accounting must be exact host float64: every value a builtin
    ``float`` (numpy float32/float64 scalars and jax arrays are rejected
    — a device round-trip is exactly the truncation hazard the host-side
    accounting exists to avoid) and finite."""
    problems = []
    for label, v in values.items():
        if type(v) is not float:
            problems.append(
                f"{label} is {type(v).__name__}, not builtin float "
                "(host f64)")
        elif not math.isfinite(v):
            problems.append(f"{label} is {v!r}, not finite")
    if problems:
        raise ContractViolation(name, problems)


def replay_comm(per_mix_step: float, gates: Sequence[bool]) -> float:
    """The engines' comm accumulation, replayed: one float64 add per
    mixing-due step, from 0.0, in step order.  Bit-equal comparison
    against an engine's ``comm_scalars`` IS the accounting contract —
    same adds, same order, same rounding."""
    total = 0.0
    for g in gates:
        if g:
            total += per_mix_step
    return total


def check_compile_count(name: str, count: int, expect: Any) -> None:
    """Trace-counter contract: ``expect`` is an exact int or an inclusive
    ``(lo, hi)`` range (the train engine's contract is ``(1, 2)``: at
    most one executable per gate variant)."""
    if isinstance(expect, tuple):
        lo, hi = expect
        ok = lo <= count <= hi
        want = f"[{lo}, {hi}]"
    else:
        ok = count == expect
        want = str(expect)
    if not ok:
        raise ContractViolation(
            name, [f"compiled {count} executables, contract allows {want}"])
