"""Static analysis over the repo's compiled programs and source.

Two halves, deliberately decoupled:

* :mod:`repro.analysis.contracts` / :mod:`repro.analysis.matrix` — HLO
  contract checks over the four compiled programs (needs jax and a
  multi-device host);
* :mod:`repro.analysis.lint` — stdlib-``ast`` repo lints (tracer
  hazards, f32 accumulators, thread discipline) that import and run
  without jax.

This package namespace stays import-light so ``lint`` users (and the CI
fast lane) never pay for jax init: import the submodules directly, or use
the lazy attribute access below.
"""

from __future__ import annotations

_SUBMODULES = ("contracts", "lint", "matrix")

__all__ = list(_SUBMODULES)


def __getattr__(name):
    if name in _SUBMODULES:
        import importlib

        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
