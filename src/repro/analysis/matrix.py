"""The shipped contract matrix: one :class:`~repro.analysis.contracts.Contract`
per compiled program the repo actually runs.

Five programs, five entries:

``train_chunk``
    The fused single-axis train chunk (``train.engine.make_fused_chunk_fn``
    on an ``("ens",)`` mesh): WASH mixing must lower collective-permutes
    plus the loss-``pmean`` all-reduce and nothing else, the
    ``donate_argnums=(0, 1)`` population/opt-state donation must survive
    to ``input_output_alias``, collectives move f32 only, the engine
    compiles at most two executables per run (mix / no-mix gate
    variants), and the host-side comm accounting is exact builtin-float64
    that replays bit-for-bit.

``pipelined_train``
    The pipelined chunk (``make_pipelined_chunk_fn`` on an (ens, pipe)
    mesh): same clauses, plus every collective-permute pair must be a
    stage-ring mixer hop (``src ≡ tgt mod S``), a one-stage-forward
    activation hop (``tgt == src + 1``), or the backward pass's
    AD-transposed gradient hop (``tgt == src - 1``).

``scan_decode``
    The serving engine's scan-decode body (``serving.engine``): a
    single-device program — no collectives at all — whose KV cache
    (argument 2) is donated and aliased, compiled once per prompt shape.

``continuous_decode``
    The continuous-batching decode step (``serving.batching``): no
    collectives, both paged KV pools (arguments 1 and 2) donated and
    aliased, compiled once per pool geometry across an entire mixed
    request stream — and reused by a second server on the same geometry.

``speculative_decode``
    The speculative decode step (``serving.speculative`` via
    ``serving.batching._build_spec_decode``): no collectives, all FOUR
    paged KV pools — verify k/v (arguments 2 and 3) AND draft k/v
    (arguments 4 and 5) — donated and aliased, compiled once per
    (geometry, ``draft_k``); a server with a different ``draft_k`` adds
    exactly one trace.

Each ``check_*`` raises :class:`~repro.analysis.contracts.ContractViolation`
on the first broken clause; :func:`run_matrix` runs every entry and
aggregates.  The matrix needs a forced multi-device CPU host
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — jax locks the
device count at first init, so ``tools/run_analysis.py`` sets the flag
before importing jax, and tests run it in a subprocess.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.analysis import contracts
from repro.analysis.contracts import (
    Contract, ContractViolation, backward_hop, check_compile_count,
    check_host_comm_f64, forward_hop, replay_comm, stage_ring,
)
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import population as pop
from repro.core import shardplan
from repro.core.compat import make_mesh
from repro.core.layer_index import infer_layer_ids, total_layers
from repro.core.mixing import MixingConfig, mixing_due, static_mix_comm
from repro.optim import make_optimizer
from repro.sharding import rules as sharding_rules

ENTRIES = ("train_chunk", "pipelined_train", "scan_decode",
           "continuous_decode", "speculative_decode")

# (ens=2, pipe=2) plus the 8-device CI lane test_pipeline already forces
REQUIRED_DEVICES = 4

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _require_devices() -> None:
    if jax.device_count() < REQUIRED_DEVICES:
        raise RuntimeError(
            f"the contract matrix needs >= {REQUIRED_DEVICES} devices "
            f"(got {jax.device_count()}); run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count=8 set BEFORE jax "
            f"first initializes (tools/run_analysis.py does this)")


# ---------------------------------------------------------------------------
# shared toy model (mirrors tests/test_pipeline.py's _TOY: stacked-blocks
# leaves so the same member splits over a pipe axis)
# ---------------------------------------------------------------------------

_L, _DIN, _D, _DOUT, _B, _N = 4, 16, 8, 4, 8, 2


def _toy_init(k):
    ks = jax.random.split(k, 3)
    return {"embed": {"w": jax.random.normal(ks[0], (_DIN, _D)) * 0.3},
            "blocks": {"w1": jax.random.normal(ks[1], (_L, _D, _D)) * 0.3},
            "head": {"w": jax.random.normal(ks[2], (_D, _DOUT)) * 0.3}}


def _toy_embed(p, b):
    return b["x"] @ p["embed"]["w"]


def _toy_blocks(p, x):
    def body(h, wl):
        return jnp.tanh(h @ wl) + h, None

    h, _ = lax.scan(body, x, p["blocks"]["w1"])
    return h


def _toy_head(p, x, b):
    return jnp.mean((x @ p["head"]["w"] - b["y"]) ** 2)


def _toy_loss(p, b):
    return _toy_head(p, _toy_blocks(p, _toy_embed(p, b)), b)


def _toy_data(m, step, k):
    kx, ky = jax.random.split(k)
    return {"x": jax.random.normal(kx, (_B, _DIN)),
            "y": jax.random.normal(ky, (_B, _DOUT))}


def _toy_tcfg(total_steps: int = 6) -> TrainConfig:
    return TrainConfig(population=_N, optimizer="sgd", lr=0.05,
                       total_steps=total_steps, batch_size=_B, seq_len=1,
                       seed=0)


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _chunk_args_sds(population, opt_state, pad_len: int = 3):
    """SDS templates in the fused/pipelined chunk signature
    ``(population, opt_state, batches, lrs, keydata, gates, n_valid)``
    — batch leaves carry the engine's (pad_len, n, B, ...) layout."""
    batches = {
        "x": jax.ShapeDtypeStruct((pad_len, _N, _B, _DIN), jnp.float32),
        "y": jax.ShapeDtypeStruct((pad_len, _N, _B, _DOUT), jnp.float32),
    }
    kd = jax.random.key_data(jax.random.key(0))
    return (
        _sds(population), _sds(opt_state), batches,
        jax.ShapeDtypeStruct((pad_len,), jnp.float32),
        jax.ShapeDtypeStruct((pad_len,) + kd.shape, kd.dtype),
        jax.ShapeDtypeStruct((pad_len,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )


def _tiny_model_cfg() -> ModelConfig:
    return ModelConfig(name="tiny", d_model=32, d_ff=64, num_layers=4,
                       num_heads=4, num_kv_heads=2, vocab_size=64,
                       max_position=128)


# ---------------------------------------------------------------------------
# entry 1: fused train chunk
# ---------------------------------------------------------------------------


def check_train_chunk() -> Dict[str, Any]:
    """Fused single-axis train chunk: permutes + loss all-reduce only, f32
    on the wire, population/opt-state donation aliased, <= 2 compiles per
    run, host comm accounting exact f64 and bit-replayable."""
    from repro.train import engine as T

    _require_devices()
    mesh = make_mesh((_N,), ("ens",))
    key = jax.random.key(0)
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    tcfg = _toy_tcfg()

    population = pop.init_population(_toy_init, key, _N,
                                     same_init=tcfg.same_init)
    lids = infer_layer_ids(pop.member(population, 0), _L)
    tl = total_layers(_L)
    opt_init, opt_update = make_optimizer(
        tcfg.optimizer, momentum=tcfg.momentum,
        weight_decay=tcfg.weight_decay)
    opt_state = jax.vmap(opt_init)(population)

    pspec = jax.tree_util.tree_map(lambda _: P("ens"), population)
    ospec = jax.tree_util.tree_map(lambda _: P("ens"), opt_state)
    bspecs = {"x": P(None, "ens"), "y": P(None, "ens")}
    chunk = T.make_fused_chunk_fn(mesh, mcfg, lids, tl, opt_update,
                                  _toy_loss, pspec, ospec, bspecs)

    contract = Contract(
        name="train_chunk",
        require_collectives=("collective-permute", "all-reduce"),
        forbid_collectives=("all-gather", "reduce-scatter", "all-to-all"),
        donate_argnums=(0, 1),
        collective_dtypes={k: ("f32",) for k in _COLLECTIVES},
    )
    report = contracts.lower_and_check(
        chunk, _chunk_args_sds(population, opt_state), contract)

    # compile count over a real (tiny) run: one executable per gate
    # variant, never re-traced per chunk
    T.reset_chunk_trace_count()
    result = T.train_population_sharded(
        key, _toy_init, _toy_loss, _toy_data, tcfg, mcfg, _L,
        record_every=3, mesh=mesh)
    check_compile_count("train_chunk-compiles", T.chunk_trace_count(), (1, 2))

    # host-side comm accounting: exact builtin f64, replayed bit-for-bit
    member_tpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), population)
    cps = static_mix_comm(member_tpl, mcfg, lids, tl, _N,
                          opt_state=opt_state)
    gates = [mixing_due(s, mcfg) for s in range(tcfg.total_steps)]
    replay = replay_comm(cps, gates)
    check_host_comm_f64(
        {"comm_per_mix_step": cps, "comm_scalars": result.comm_scalars,
         "replay": replay}, name="train_chunk-host-comm")
    if replay != result.comm_scalars:
        raise ContractViolation("train_chunk-host-comm", [
            f"replayed comm {replay!r} != engine comm "
            f"{result.comm_scalars!r} (accumulation order or per-step "
            f"value drifted)"])
    return {"hlo": report, "compiles": T.chunk_trace_count(),
            "comm_scalars": result.comm_scalars}


# ---------------------------------------------------------------------------
# entry 2: pipelined train chunk
# ---------------------------------------------------------------------------


def check_pipelined_train() -> Dict[str, Any]:
    """Pipelined chunk on an (ens=2, pipe=2) mesh: every permute is a
    stage-ring mixer hop, a one-stage-forward activation hop, or its
    AD-transposed backward gradient hop; donation and compile-count
    clauses as the fused chunk; shard-plan comm exact f64."""
    from repro.train import StageFns, train_population_pipelined
    from repro.train import engine as T

    _require_devices()
    S = 2
    mesh = make_mesh((_N, S), ("ens", "pipe"))
    key = jax.random.key(0)
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    tcfg = _toy_tcfg()
    sf = StageFns(_toy_embed, _toy_blocks, _toy_head)

    population = pop.init_population(_toy_init, key, _N,
                                     same_init=tcfg.same_init)
    lids = infer_layer_ids(pop.member(population, 0), _L)
    tl = total_layers(_L)
    opt_init, opt_update = make_optimizer(
        tcfg.optimizer, momentum=tcfg.momentum,
        weight_decay=tcfg.weight_decay)
    opt_state = jax.vmap(opt_init)(population)

    # mirror train_population_pipelined's spec/plan construction exactly
    member_tpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), population)
    member_specs = jax.tree_util.tree_map(lambda _: P(), member_tpl)
    stage_specs = sharding_rules.stage_member_specs(member_specs, lids,
                                                    "pipe")
    pplan = shardplan.plan_population_mixing(
        mesh, member_tpl, stage_specs, mcfg, lids, tl, _N)
    pspec = sharding_rules.population_pspecs(stage_specs, pplan.pop_axes)
    ospec = sharding_rules.opt_pspecs(opt_state, pspec, pplan.pop_axes)
    pop_entry = (pplan.pop_axes[0] if len(pplan.pop_axes) == 1
                 else tuple(pplan.pop_axes))
    bspecs = {"x": P(None, pop_entry), "y": P(None, pop_entry)}
    chunk = T.make_pipelined_chunk_fn(
        mesh, mcfg, lids, tl, opt_update, sf, pspec, ospec, bspecs,
        num_micro=2, pplan=pplan)

    contract = Contract(
        name="pipelined_train",
        require_collectives=("collective-permute", "all-reduce"),
        forbid_collectives=("all-gather", "reduce-scatter", "all-to-all"),
        permute_rules=(stage_ring(S), forward_hop(S), backward_hop(S)),
        donate_argnums=(0, 1),
        collective_dtypes={k: ("f32",) for k in _COLLECTIVES},
    )
    report = contracts.lower_and_check(
        chunk, _chunk_args_sds(population, opt_state), contract)

    T.reset_chunk_trace_count()
    result = train_population_pipelined(
        key, _toy_init, sf, _toy_data, tcfg, mcfg, _L, record_every=3,
        mesh=mesh, microbatches=2)
    check_compile_count("pipelined_train-compiles", T.chunk_trace_count(),
                        (1, 2))

    cps = shardplan.static_shard_mix_comm(pplan, opt_state=opt_state)
    gates = [mixing_due(s, mcfg) for s in range(tcfg.total_steps)]
    replay = replay_comm(cps, gates)
    check_host_comm_f64(
        {"comm_per_mix_step": cps, "comm_scalars": result.comm_scalars,
         "replay": replay}, name="pipelined_train-host-comm")
    if replay != result.comm_scalars:
        raise ContractViolation("pipelined_train-host-comm", [
            f"replayed comm {replay!r} != engine comm "
            f"{result.comm_scalars!r}"])
    return {"hlo": report, "compiles": T.chunk_trace_count(),
            "comm_scalars": result.comm_scalars}


# ---------------------------------------------------------------------------
# entry 3: scan decode (serving engine)
# ---------------------------------------------------------------------------


def check_scan_decode() -> Dict[str, Any]:
    """Serving scan decode: a collective-free single-device program whose
    KV cache (arg 2) is donated and aliased, compiled once per prompt
    shape (counter stays at 1 across repeat same-shape requests, +1 for a
    new shape)."""
    from repro.models import transformer as M
    from repro.serving import engine as E

    cfg = _tiny_model_cfg()
    B, S, max_new = 2, 4, 8
    capacity = E.internal_prefix(cfg) + S + max_new

    params_sds = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    cache_sds = jax.eval_shape(lambda: M.init_cache(cfg, B, capacity))
    key_dtype = jax.eval_shape(lambda: jax.random.key(0)).dtype
    args = (
        params_sds,
        jax.ShapeDtypeStruct((B, S), jnp.int32),
        cache_sds,
        jax.ShapeDtypeStruct((B, 1, cfg.vocab_size), jnp.float32),
        jax.ShapeDtypeStruct((B,), key_dtype),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    # probe the raw program body with explicit donation: the serving path
    # routes donation through compat.donate_argnums, a no-op on CPU, so
    # the alias contract must be asserted on the body itself
    program = E._decode_program(cfg, False, S, max_new, True)
    contract = Contract(
        name="scan_decode",
        forbid_collectives=_COLLECTIVES,
        donate_argnums=(2,),
    )
    report = contracts.lower_and_check(program, args, contract)

    # one executable per prompt shape
    E.reset_trace_counts()
    E.clear_executable_cache()
    params = M.init_params(jax.random.key(0), cfg)
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
    E.generate(params, cfg, batch, max_new)
    E.generate(params, cfg, batch, max_new)
    check_compile_count("scan_decode-compiles-per-shape",
                        E.decode_trace_count(), 1)
    E.generate(params, cfg, {"tokens": jnp.zeros((B, S + 1), jnp.int32)},
               max_new)
    check_compile_count("scan_decode-compiles-new-shape",
                        E.decode_trace_count(), 2)
    return {"hlo": report, "compiles": E.decode_trace_count()}


# ---------------------------------------------------------------------------
# entry 4: continuous decode step (paged serving)
# ---------------------------------------------------------------------------


def check_continuous_decode() -> Dict[str, Any]:
    """Continuous-batching decode step: collective-free, both paged KV
    pools (args 1 and 2) donated and aliased, compiled once per pool
    geometry across a whole mixed stream — and reused by a second server
    on the same geometry."""
    from repro.models import layers as L
    from repro.models import transformer as M
    from repro.serving import batching

    cfg = ModelConfig(name="tiny", d_model=32, d_ff=64, num_layers=2,
                      num_heads=4, num_kv_heads=2, vocab_size=50,
                      max_position=128)
    page_size, max_slots, num_pages = 4, 3, 32

    params_sds = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    pools_sds = jax.eval_shape(
        lambda: L.paged_pools_init(cfg, num_pages, page_size,
                                   cfg.num_layers))
    key_dtype = jax.eval_shape(lambda: jax.random.key(0)).dtype
    B = max_slots
    args = (
        params_sds, pools_sds["k"], pools_sds["v"],
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.bool_),
        jax.ShapeDtypeStruct((B, num_pages), jnp.int32),
        jax.ShapeDtypeStruct((B,), key_dtype),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    program = batching._build_decode(cfg, False, True, False)
    contract = Contract(
        name="continuous_decode",
        forbid_collectives=_COLLECTIVES,
        donate_argnums=(1, 2),
    )
    report = contracts.lower_and_check(program, args, contract)

    # one executable per pool geometry for a whole mixed stream, reused
    # by a second server on the same geometry
    batching.reset_trace_counts()
    batching.clear_executable_cache()
    params = M.init_params(jax.random.key(0), cfg)
    reqs = [batching.Request(uid=i, tokens=list(range(1, 1 + s)), max_new=m)
            for i, (s, m) in enumerate([(5, 6), (9, 3), (3, 8), (7, 5)])]
    server = batching.ContinuousServer(
        params, cfg, temperature=0.0, page_size=page_size,
        max_slots=max_slots, num_pages=num_pages)
    server.run(reqs)
    check_compile_count("continuous_decode-compiles-per-geometry",
                        batching.decode_trace_count(), 1)
    server2 = batching.ContinuousServer(
        params, cfg, temperature=0.0, page_size=page_size,
        max_slots=max_slots, num_pages=num_pages)
    server2.run([batching.Request(uid=90, tokens=[1, 2, 3], max_new=4)])
    check_compile_count("continuous_decode-compiles-reuse",
                        batching.decode_trace_count(), 1)
    return {"hlo": report, "compiles": batching.decode_trace_count()}


# ---------------------------------------------------------------------------
# entry 5: speculative decode step (draft + batched verify)
# ---------------------------------------------------------------------------


def check_speculative_decode() -> Dict[str, Any]:
    """Speculative decode step: collective-free, donation honored on all
    four paged KV pools (verify args 2–3, draft args 4–5), one executable
    per (pool geometry, ``draft_k``) across a whole speculative stream —
    and a server with a different ``draft_k`` adds exactly one trace."""
    from repro.models import layers as L
    from repro.models import transformer as M
    from repro.serving import batching

    cfg = ModelConfig(name="tiny", d_model=32, d_ff=64, num_layers=2,
                      num_heads=4, num_kv_heads=2, vocab_size=50,
                      max_position=128)
    page_size, max_slots, num_pages, draft_k = 4, 3, 32, 3

    params_sds = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    pools_sds = jax.eval_shape(
        lambda: L.paged_pools_init(cfg, num_pages, page_size,
                                   cfg.num_layers))
    key_dtype = jax.eval_shape(lambda: jax.random.key(0)).dtype
    B = max_slots
    args = (
        params_sds, params_sds,                        # verify + draft (soup)
        pools_sds["k"], pools_sds["v"],                # verify pools
        pools_sds["k"], pools_sds["v"],                # draft pools
        jax.ShapeDtypeStruct((B,), jnp.int32),         # tokens
        jax.ShapeDtypeStruct((B,), jnp.int32),         # positions
        jax.ShapeDtypeStruct((B,), jnp.int32),         # steps
        jax.ShapeDtypeStruct((B,), jnp.int32),         # budgets
        jax.ShapeDtypeStruct((B,), jnp.bool_),         # active
        jax.ShapeDtypeStruct((B, num_pages), jnp.int32),
        jax.ShapeDtypeStruct((B,), key_dtype),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    program = batching._build_spec_decode(cfg, False, True, False, draft_k)
    contract = Contract(
        name="speculative_decode",
        forbid_collectives=_COLLECTIVES,
        donate_argnums=(2, 3, 4, 5),
    )
    report = contracts.lower_and_check(program, args, contract)

    # one executable per (geometry, draft_k) for a whole speculative
    # stream; a different draft_k is a new program — exactly one more
    batching.reset_trace_counts()
    batching.clear_executable_cache()
    params = M.init_params(jax.random.key(0), cfg)
    reqs = [batching.Request(uid=i, tokens=list(range(1, 1 + s)), max_new=m)
            for i, (s, m) in enumerate([(5, 6), (9, 3), (3, 8), (7, 5)])]
    server = batching.ContinuousServer(
        params, cfg, temperature=0.0, page_size=page_size,
        max_slots=max_slots, num_pages=num_pages,
        speculative=True, draft_k=draft_k)
    server.run(reqs)
    check_compile_count("speculative_decode-compiles-per-geometry",
                        batching.decode_trace_count(), 1)
    server2 = batching.ContinuousServer(
        params, cfg, temperature=0.0, page_size=page_size,
        max_slots=max_slots, num_pages=num_pages,
        speculative=True, draft_k=draft_k)
    server2.run([batching.Request(uid=90, tokens=[1, 2, 3], max_new=4)])
    check_compile_count("speculative_decode-compiles-reuse",
                        batching.decode_trace_count(), 1)
    server3 = batching.ContinuousServer(
        params, cfg, temperature=0.0, page_size=page_size,
        max_slots=max_slots, num_pages=num_pages,
        speculative=True, draft_k=draft_k + 2)
    server3.run([batching.Request(uid=91, tokens=[1, 2, 3], max_new=4)])
    check_compile_count("speculative_decode-compiles-new-draft-k",
                        batching.decode_trace_count(), 2)
    return {"hlo": report, "compiles": batching.decode_trace_count()}


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

_CHECKS: Dict[str, Callable[[], Dict[str, Any]]] = {
    "train_chunk": check_train_chunk,
    "pipelined_train": check_pipelined_train,
    "scan_decode": check_scan_decode,
    "continuous_decode": check_continuous_decode,
    "speculative_decode": check_speculative_decode,
}


def run_matrix(entries: Optional[Tuple[str, ...]] = None,
               raise_on_violation: bool = True) -> Dict[str, Any]:
    """Run the contract matrix.  Returns ``{entry: result_dict}``; on any
    :class:`ContractViolation` raises one aggregate violation naming every
    failed entry (or records ``{"error": ...}`` per entry when
    ``raise_on_violation=False``)."""
    names = entries or ENTRIES
    unknown = set(names) - set(_CHECKS)
    if unknown:
        raise ValueError(f"unknown matrix entries {sorted(unknown)}; "
                         f"known: {list(ENTRIES)}")
    results: Dict[str, Any] = {}
    failures: List[str] = []
    for name in names:
        try:
            results[name] = _CHECKS[name]()
        except ContractViolation as e:
            results[name] = {"error": str(e)}
            failures.append(f"{name}: {e}")
    if failures and raise_on_violation:
        raise ContractViolation("matrix", failures)
    return results
