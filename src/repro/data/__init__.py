"""Synthetic data pipelines + per-member augmentation policies."""

from repro.data.synthetic import (
    ImageTask,
    LMTask,
    eval_images,
    make_image_task,
    make_lm_task,
    sample_images,
    sample_tokens,
)
from repro.data.augment import (
    AugmentPolicy,
    apply_policy,
    draw_policy,
    member_policies,
    soft_cross_entropy,
)

__all__ = [
    "ImageTask",
    "LMTask",
    "make_image_task",
    "make_lm_task",
    "sample_images",
    "eval_images",
    "sample_tokens",
    "AugmentPolicy",
    "draw_policy",
    "member_policies",
    "apply_policy",
    "soft_cross_entropy",
]
