"""Per-member data augmentations & regularizations (paper Appendix).

The heterogeneous setting draws, per member, a (mixup, label-smoothing,
cutmix, random-erasing) policy from the same menus as the paper
(CIFAR menus).  All augmentations produce *soft labels*, so the classifier
loss is a soft cross-entropy throughout.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

MIXUP_MENU = (0.0, 0.5, 1.0)
SMOOTH_MENU = (0.0, 0.05, 0.1)
CUTMIX_MENU = (0.0, 0.5, 1.0)
ERASE_MENU = (0.0, 0.15, 0.35)


@dataclasses.dataclass(frozen=True)
class AugmentPolicy:
    mixup: float = 0.0
    smooth: float = 0.0
    cutmix: float = 0.0
    erase: float = 0.0


def draw_policy(key: jax.Array) -> AugmentPolicy:
    ks = jax.random.split(key, 4)
    pick = lambda k, menu: menu[int(jax.random.randint(k, (), 0, len(menu)))]
    return AugmentPolicy(
        mixup=pick(ks[0], MIXUP_MENU),
        smooth=pick(ks[1], SMOOTH_MENU),
        cutmix=pick(ks[2], CUTMIX_MENU),
        erase=pick(ks[3], ERASE_MENU),
    )


def member_policies(key: jax.Array, n: int, heterogeneous: bool):
    if not heterogeneous:
        return [AugmentPolicy() for _ in range(n)]
    return [draw_policy(jax.random.fold_in(key, i)) for i in range(n)]


def _one_hot(labels, num_classes, smooth):
    oh = jax.nn.one_hot(labels, num_classes)
    return oh * (1.0 - smooth) + smooth / num_classes


def apply_policy(
    key: jax.Array,
    images: jax.Array,
    labels: jax.Array,
    num_classes: int,
    policy: AugmentPolicy,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (images, soft_labels)."""
    B, H, W, _ = images.shape
    y = _one_hot(labels, num_classes, policy.smooth)
    k_mix, k_cut, k_er, k_perm, k_lam = jax.random.split(key, 5)
    perm = jax.random.permutation(k_perm, B)

    if policy.mixup > 0.0:
        lam = jax.random.beta(k_lam, policy.mixup, policy.mixup, ())
        images = lam * images + (1 - lam) * images[perm]
        y = lam * y + (1 - lam) * y[perm]

    if policy.cutmix > 0.0:
        lam = jax.random.beta(k_cut, policy.cutmix, policy.cutmix, ())
        cut = jnp.sqrt(1.0 - lam)
        ch, cw = (cut * H).astype(jnp.int32), (cut * W).astype(jnp.int32)
        cy = jax.random.randint(k_cut, (), 0, H)
        cx = jax.random.randint(jax.random.fold_in(k_cut, 1), (), 0, W)
        yy = jnp.arange(H)[None, :, None, None]
        xx = jnp.arange(W)[None, None, :, None]
        inside = (
            (yy >= cy - ch // 2) & (yy < cy + ch // 2)
            & (xx >= cx - cw // 2) & (xx < cx + cw // 2)
        )
        images = jnp.where(inside, images[perm], images)
        area = jnp.clip(ch * cw / (H * W), 0.0, 1.0)
        y = (1 - area) * y + area * y[perm]

    if policy.erase > 0.0:
        eh = max(int(policy.erase * H), 1)
        ey = jax.random.randint(k_er, (B,), 0, H - eh + 1)
        ex = jax.random.randint(jax.random.fold_in(k_er, 1), (B,), 0, W - eh + 1)
        yy = jnp.arange(H)[None, :, None, None]
        xx = jnp.arange(W)[None, None, :, None]
        inside = (
            (yy >= ey[:, None, None, None]) & (yy < (ey + eh)[:, None, None, None])
            & (xx >= ex[:, None, None, None]) & (xx < (ex + eh)[:, None, None, None])
        )
        images = jnp.where(inside, 0.0, images)

    return images, y


def soft_cross_entropy(logits, soft_labels):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.sum(soft_labels * lp, axis=-1))
