"""Deterministic synthetic data pipelines.

No datasets ship in this container, so both tasks are generated from seeded
PRNG with enough *learnable structure* that optimization dynamics (loss
decrease, ensemble diversity, averaged-model behaviour) are meaningful:

  * image task — a Gaussian-mixture over class prototypes (CIFAR stand-in);
  * LM task    — an order-1 Markov chain with a random, Zipf-weighted
                 transition table (perplexity is learnable down to the chain
                 entropy).

Every member of a WASH population draws its *own data order* (different
keys), matching the paper's training setup.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# image classification task (CIFAR stand-in)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ImageTask:
    prototypes: jax.Array  # (C, H, W, 3)
    num_classes: int
    noise: float


def make_image_task(
    key: jax.Array, num_classes: int = 10, hw: int = 16, noise: float = 0.35
) -> ImageTask:
    protos = jax.random.normal(key, (num_classes, hw, hw, 3)) * 0.8
    # low-pass the prototypes so nearby pixels correlate (image-like)
    k = jnp.ones((3, 3, 1, 1)) / 9.0
    smooth = jax.lax.conv_general_dilated(
        protos.transpose(0, 3, 1, 2).reshape(-1, 1, hw, hw),
        k.transpose(3, 2, 0, 1),
        (1, 1),
        "SAME",
    )
    protos = smooth.reshape(num_classes, 3, hw, hw).transpose(0, 2, 3, 1)
    return ImageTask(protos, num_classes, noise)


def sample_images(task: ImageTask, key: jax.Array, batch: int):
    ky, kn = jax.random.split(key)
    labels = jax.random.randint(ky, (batch,), 0, task.num_classes)
    images = task.prototypes[labels] + task.noise * jax.random.normal(
        kn, (batch,) + task.prototypes.shape[1:]
    )
    return images, labels


def eval_images(task: ImageTask, key: jax.Array, batch: int = 512):
    """Fixed held-out batch (same key -> same eval set)."""
    return sample_images(task, key, batch)


# ---------------------------------------------------------------------------
# LM task (Markov chain)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMTask:
    table: jax.Array  # (V, V) transition logits
    vocab: int


def make_lm_task(key: jax.Array, vocab: int = 256, branching: float = 4.0) -> LMTask:
    # Zipf-ish sparse transitions: each state prefers a few successors.
    logits = jax.random.gumbel(key, (vocab, vocab)) * branching
    return LMTask(logits, vocab)


def sample_tokens(task: LMTask, key: jax.Array, batch: int, seq: int):
    k0, ks = jax.random.split(key)
    x0 = jax.random.randint(k0, (batch,), 0, task.vocab)

    def step(x, k):
        nxt = jax.random.categorical(k, task.table[x])
        return nxt, nxt

    keys = jax.random.split(ks, seq - 1)
    _, rest = jax.lax.scan(step, x0, keys)
    return jnp.concatenate([x0[None], rest], axis=0).T  # (batch, seq)
