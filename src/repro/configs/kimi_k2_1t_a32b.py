"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table scale) [arXiv:2501.kimi2].

384 routed experts, top-8, per-expert hidden 2048, 61 layers.  This config
exists for the dry-run/roofline table: its training state exceeds a single
256-chip v5e pod's HBM (recorded, not hidden, in EXPERIMENTS.md §Dry-run).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,  # GQA per the assignment table
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    moe=True,
    n_routed_experts=384,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    source="arXiv:2501.kimi2 (Kimi K2)",
)
