"""Model / run configuration dataclasses shared by the whole framework."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers every assigned architecture family.

    Families: dense | moe | ssm | hybrid | audio | vlm.
    ``block_kind``: attn | rwkv6 | hybrid (attn ∥ mamba).
    """

    name: str = "model"
    family: str = "dense"
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 2
    d_ff: int = 512
    vocab_size: int = 1000
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention variants
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None  # sliding-window attention size (tokens)

    # MLA (DeepSeek-V2 style multi-head latent attention)
    mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    moe: bool = False
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None  # per-expert hidden dim (defaults d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM / RWKV / hybrid
    block_kind: str = "attn"  # attn | rwkv6 | hybrid
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper-style)
    encoder_layers: int = 0
    num_frames: int = 1500  # encoder sequence length (stubbed frontend)

    # modality frontend stubs
    frontend: Optional[str] = None  # None | "audio" | "vision"
    num_patches: int = 0  # vision tokens prepended to the text sequence

    # positions
    pos_kind: str = "rope"  # rope | learned (whisper)
    max_position: int = 32768  # learned-pos table size

    # lowering: unroll factor for the block scan.  1 = rolled while-loop
    # (fast compile; XLA cost_analysis counts the body ONCE).  num_layers =
    # fully unrolled (dry-run default so roofline FLOPs/bytes are complete).
    scan_unroll: int = 1

    # performance knobs (§Perf hillclimbs; defaults = paper-faithful baseline)
    attn_impl: str = "naive"   # naive (materializes SxS) | chunked (online softmax)
    attn_chunk: int = 1024     # kv-chunk size for attn_impl=chunked
    remat_blocks: bool = False # activation-checkpoint each block in training
    moe_impl: str = "global"   # global (one dispatch over all tokens) |
                               # grouped (per-batch-row dispatch: buffers are
                               # data-local, exchange lowers to all-to-all)
    shard_hints: bool = False  # activate in-model GSPMD sharding constraints

    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""  # citation for the config

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """sub-quadratic decode: SSM state or sliding-window KV."""
        return self.block_kind in ("rwkv6", "hybrid") or self.window is not None

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.head_dim else None,
            kv_lora_rank=min(self.kv_lora_rank, 64),
            qk_nope_dim=32 if self.mla else self.qk_nope_dim,
            qk_rope_dim=16 if self.mla else self.qk_rope_dim,
            v_head_dim=32 if self.mla else self.v_head_dim,
            n_routed_experts=min(self.n_routed_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            moe_d_ff=min(self.resolved_moe_d_ff, 128) if self.moe else None,
            encoder_layers=2 if self.is_encdec else 0,
            num_frames=32 if self.is_encdec else self.num_frames,
            max_position=min(self.max_position, 512),
            num_patches=8 if self.frontend == "vision" else 0,
            window=min(self.window, 64) if self.window else None,
            rwkv_head_dim=32,
            name=self.name + "-reduced",
            dtype="float32",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4096, 256, "train"),
    InputShape("prefill_32k", 32768, 32, "prefill"),
    InputShape("decode_32k", 32768, 128, "decode"),
    InputShape("long_500k", 524288, 1, "decode"),
)

INPUT_SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Training/population hyper-parameters (paper §4 defaults)."""

    population: int = 5
    same_init: bool = True
    optimizer: str = "sgd"  # sgd | adamw
    lr: float = 0.1
    min_lr: float = 1e-4
    momentum: float = 0.9
    weight_decay: float = 1e-4
    warmup_steps: int = 0
    total_steps: int = 1000
    batch_size: int = 64
    seq_len: int = 128
    seed: int = 0
    heterogeneous: bool = True  # per-member augmentations/regularization
