"""Architecture registry: the 10 assigned architectures, keyed by public id.

``get_arch("minitron-8b")`` returns the exact assigned ModelConfig;
``get_arch(id).reduced()`` is the CPU smoke-test variant (2 layers,
d_model<=256, <=4 experts).
"""

from repro.configs.base import (
    INPUT_SHAPES,
    INPUT_SHAPES_BY_NAME,
    InputShape,
    ModelConfig,
    TrainConfig,
)
from repro.configs.minitron_8b import CONFIG as _minitron
from repro.configs.llama3_2_3b import CONFIG as _llama32
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.qwen3_4b import CONFIG as _qwen3
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.internvl2_76b import CONFIG as _internvl
from repro.configs.qwen1_5_4b import CONFIG as _qwen15

ARCHS = {
    c.name: c
    for c in (
        _minitron,
        _llama32,
        _dsv2,
        _whisper,
        _qwen3,
        _hymba,
        _rwkv6,
        _kimi,
        _internvl,
        _qwen15,
    )
}

ARCH_IDS = tuple(ARCHS.keys())


def get_arch(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


__all__ = [
    "ARCHS",
    "ARCH_IDS",
    "get_arch",
    "ModelConfig",
    "TrainConfig",
    "InputShape",
    "INPUT_SHAPES",
    "INPUT_SHAPES_BY_NAME",
]
