"""rwkv6-3b — Finch: attention-free, data-dependent decay [arXiv:2404.05892].

O(1)-state decode: runs the long_500k shape natively.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # D / rwkv_head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    block_kind="rwkv6",
    rwkv_head_dim=64,
    source="arXiv:2404.05892 (RWKV-6 Finch 3B)",
)
