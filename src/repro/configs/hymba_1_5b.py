"""hymba-1.5b — hybrid parallel attention + Mamba heads [arXiv:2411.13676].

Every block runs a GQA sliding-window attention head-group in parallel
with a selective-SSM (Mamba) path; outputs are fused with a learned
softmax gate.  Hymba's meta-tokens and the few global-attention layers are
simplified to uniform SWA (noted in DESIGN.md).  SWA + SSM state make this
arch sub-quadratic, so it runs the long_500k shape.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,  # GQA
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    block_kind="hybrid",
    ssm_state=16,
    window=1024,
    source="arXiv:2411.13676 (Hymba-1.5B)",
)
