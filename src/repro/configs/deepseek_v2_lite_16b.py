"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

MLA latent cache: kv_lora_rank=512, decoupled rope dim 64.  MoE: 2 shared
+ 64 routed experts, top-6, per-expert hidden 1408.  (The assignment
header says 64e; its bracket note says 160 routed — we follow the header
and the model card; the expert count is one config field either way.)
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MLA: per-head latents, kv head count unused
    d_ff=1408,
    vocab_size=102400,
    mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    moe=True,
    n_routed_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    source="arXiv:2405.04434 (DeepSeek-V2-Lite)",
)
