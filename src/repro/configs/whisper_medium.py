"""whisper-medium — encoder–decoder audio transformer [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the assignment:
``input_specs`` feeds precomputed 1500-frame embeddings to the encoder.
Learned absolute positions (no rope), per the Whisper architecture.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,  # decoder
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    pos_kind="learned",
    max_position=32768,  # decode_32k requires a 32k position table
    num_frames=1500,
    frontend="audio",
    source="arXiv:2212.04356 (Whisper medium)",
)
