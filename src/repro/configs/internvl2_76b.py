"""internvl2-76b — VLM: InternViT (stub) + InternLM2-like LM [arXiv:2404.16821].

The vision encoder + projector are a STUB per the assignment:
``input_specs`` feeds 256 precomputed patch embeddings that are prepended
to the text sequence; the 80-layer LM backbone is fully implemented.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,  # GQA
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision",
    num_patches=256,
    source="arXiv:2404.16821 (InternVL2-76B, LM backbone)",
)
