"""Parameter / activation sharding rules.

A small table of name-based rules (the cases where the *direction* of the
matmul matters for collective placement: column-parallel in, row-parallel
out, expert-parallel MoE) backed by a divisibility heuristic for everything
else.  Scanned-block leading axes are never sharded (scan iterates them).

The rules produce PartitionSpecs; GSPMD propagates to activations, with
batch sharding pinned by the input specs.
"""

from __future__ import annotations

import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

PyTree = Any

# leaf-name patterns -> which *logical* dim gets the model axis
# (negative indices from the end; None = replicate)
_COL_PAR = re.compile(r"(wq|wk|wv|w1|w3|in_proj|dt_proj|w_uk|w_uv|wr|wg|frame_proj|patch_proj)$")
_ROW_PAR = re.compile(r"(wo|w2|out_proj|x_proj)$")
_REPLICATE = re.compile(
    r"(scale|bias|^b$|bq|bk|bv|b1|b2|mu|w0|u$|beta|router|conv_w|conv_b|A_log|^D$"
    r"|dt_bias|w_lora_a|w_lora_b|w_dkv|w_krope|pos|enc_pos|ln)"
)


def _leaf_name(path) -> str:
    parts = [str(p.key) if hasattr(p, "key") else str(p.idx) for p in path]
    return parts[-1] if parts else ""


def _is_blocks_leaf(path) -> bool:
    return any(
        hasattr(p, "key") and str(p.key) in ("blocks", "enc_blocks") for p in path
    )


def _heuristic(shape: Tuple[int, ...], model: int, skip_first: bool):
    """Shard the right-most dim divisible by the model axis (>= 2x)."""
    spec = [None] * len(shape)
    lo = 1 if skip_first else 0
    for i in range(len(shape) - 1, lo - 1, -1):
        if shape[i] % model == 0 and shape[i] // model >= 2:
            spec[i] = "model"
            break
    return P(*spec)


def param_pspec(path, leaf, cfg: ModelConfig, model_size: int) -> P:
    name = _leaf_name(path)
    shape = tuple(leaf.shape)
    nb = _is_blocks_leaf(path)
    off = 1 if nb else 0  # scanned layer axis leads blocks leaves

    if _REPLICATE.search(name):
        return P()
    if len(shape) - off < 2:
        return P()

    def with_model_at(dim_from_end: int) -> P:
        idx = len(shape) - 1 - dim_from_end
        if shape[idx] % model_size == 0 and shape[idx] // model_size >= 2:
            spec = [None] * len(shape)
            spec[idx] = "model"
            return P(*spec)
        return _heuristic(shape, model_size, nb)

    # MoE experts: expert-parallel over the model axis
    if any(hasattr(p, "key") and str(p.key) == "experts" for p in path):
        e_idx = off  # (L, E, D, F) or (E, D, F)
        if shape[e_idx] % model_size == 0:
            spec = [None] * len(shape)
            spec[e_idx] = "model"
            return P(*spec)
        return _heuristic(shape, model_size, nb)

    if name == "tok":  # (V, D): shard vocab (row-parallel embed + rsc logits)
        return with_model_at(1)
    if name == "w" and any(hasattr(p, "key") and str(p.key) == "lm_head" for p in path):
        return with_model_at(0)  # (D, V): column-parallel head
    if _COL_PAR.search(name):
        return with_model_at(0)  # output features sharded
    if _ROW_PAR.search(name):
        return with_model_at(1)  # input features sharded
    return _heuristic(shape, model_size, nb)


def param_pspecs(params: PyTree, cfg: ModelConfig, mesh) -> PyTree:
    model = mesh.shape["model"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [param_pspec(path, leaf, cfg, model) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(cfg: ModelConfig, mesh, batch_size: int) -> PyTree:
    """Token/frame/patch inputs: batch over (pod-and-)data axes."""
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nd = int(np.prod([mesh.shape[a] for a in dax]))
    bspec = dax if (dax and batch_size % nd == 0) else None
    out = {"tokens": P(bspec, None)}
    if cfg.frontend == "audio":
        out["frames"] = P(bspec, None, None)
    if cfg.frontend == "vision":
        out["patches"] = P(bspec, None, None)
    return out


def cache_pspecs(cache_shapes: PyTree, cfg: ModelConfig, mesh, batch: int) -> PyTree:
    """Decode cache sharding.

    KV ring (L, B, cap, kv, hd): batch over data when divisible; otherwise
    (long_500k, B=1) shard the *context* axis over every available chip —
    context parallelism.  SSM states shard batch or the inner-dim.
    """
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nd = int(np.prod([mesh.shape[a] for a in dax])) if dax else 1
    model = mesh.shape["model"]
    batch_ok = dax and batch % nd == 0

    def spec_for(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        if name in ("k", "v"):  # (L,B,cap,kv,hd)
            if batch_ok:
                cap_ax = "model" if shape[2] % model == 0 else None
                return P(None, dax, cap_ax, None, None)
            ctx = dax + ("model",)
            n = nd * model
            return P(None, None, ctx if shape[2] % n == 0 else None, None, None)
        if name in ("ckv", "krope"):  # (L,B,cap,r)
            if batch_ok:
                return P(None, dax, "model" if shape[2] % model == 0 else None, None)
            ctx = dax + ("model",)
            n = nd * model
            return P(None, None, ctx if shape[2] % n == 0 else None, None)
        if name in ("xk", "xv"):  # (L,B,frames,kv,hd)
            return P(None, dax if batch_ok else None, None, None, None)
        if name == "h":  # mamba (L,B,DI,S)
            di_ax = "model" if shape[2] % model == 0 else None
            return P(None, dax if batch_ok else None, di_ax, None)
        if name == "conv":  # (L,B,k-1,DI)
            return P(None, dax if batch_ok else None, None,
                     "model" if shape[3] % model == 0 else None)
        if name == "S":  # rwkv (L,B,H,hd,hd)
            return P(None, dax if batch_ok else None, None, None, None)
        if name in ("x_tm", "x_cm"):  # (L,B,D)
            return P(None, dax if batch_ok else None,
                     "model" if shape[2] % model == 0 else None)
        if name == "pos_ids":
            return P()
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat]
    )


def named(tree_specs: PyTree, mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# stacked populations (WASH): specs for the leading ens axis + opt moments
# ---------------------------------------------------------------------------


def stage_member_specs(
    member_specs: PyTree, layer_ids: PyTree, pipe_axis: str = "pipe"
) -> PyTree:
    """Stage-shard the member specs for a pipeline mesh.

    Inserts ``pipe_axis`` on the scanned layer axis (dim 0) of every
    stacked-blocks leaf — identified by an array-valued ``layer_ids`` leaf
    (:func:`repro.core.layer_index.infer_layer_ids`), *not* by path, so
    list-of-dicts block models (whose per-block leaves have no layer axis)
    are left replicated rather than corrupted.  Everything else (embed,
    head, norms, per-block leaves of unscanned models) stays
    pipe-replicated; :mod:`repro.core.shardplan` attributes those leaves
    to an owner stage for accounting.
    """

    def _one(spec, lid):
        if isinstance(lid, int):
            return spec
        entries = tuple(spec) if spec is not None else ()
        if entries and entries[0] is not None:
            raise ValueError(
                f"scanned layer axis already sharded by {entries[0]!r}; "
                "cannot also stage-split it"
            )
        return P(pipe_axis, *entries[1:])

    return jax.tree_util.tree_map(
        _one, member_specs, layer_ids,
        is_leaf=lambda x: x is None or isinstance(x, P),
    )


def population_pspecs(
    member_specs: PyTree,
    pop_axes=("ens",),
    *,
    layer_ids: PyTree = None,
    pipe_axis: str = None,
) -> PyTree:
    """Specs for a stacked population: the leading axis is sharded over the
    population mesh axes, every member dim keeps its member-level spec.

    ``member_specs`` leaves are member-level ``PartitionSpec``s (``P()``
    replicates a member within its population shard); ``pop_axes`` is the
    tuple of mesh axes carrying the population (``("ens",)``, or
    ``("ens", "data")`` when the population divides over data too — see
    :func:`repro.core.shardplan.classify_roles`).  Passing ``pipe_axis``
    (with the matching ``layer_ids``) first routes the member specs
    through :func:`stage_member_specs`, emitting stage-sharded specs for
    pipeline meshes.
    """
    if pipe_axis is not None:
        if layer_ids is None:
            raise ValueError("pipe_axis requires layer_ids")
        member_specs = stage_member_specs(member_specs, layer_ids, pipe_axis)
    lead = pop_axes[0] if len(pop_axes) == 1 else tuple(pop_axes)

    def _one(s):
        entries = tuple(s) if s is not None else ()
        return P(lead, *entries)

    return jax.tree_util.tree_map(
        _one, member_specs, is_leaf=lambda x: x is None or isinstance(x, P)
    )


def opt_pspecs(opt_state: PyTree, pop_specs: PyTree, pop_axes=("ens",)) -> PyTree:
    """Specs for a vmapped optimizer state over a stacked population.

    Moment slots (``mu``/``nu`` — the leaves WASH+Opt shuffles) mirror the
    population's specs exactly, so moment shards line up with their
    parameter shards and the replayed shuffle plan indexes both with the
    same local coordinates.  Everything else (step counters) is sharded
    over the population axes only.
    """
    lead = pop_axes[0] if len(pop_axes) == 1 else tuple(pop_axes)
    return {
        k: pop_specs if k in ("mu", "nu")
        else jax.tree_util.tree_map(lambda _: P(lead), opt_state[k])
        for k in opt_state
    }
