"""In-model sharding hints (GSPMD constraints).

Model code is mesh-agnostic; the launcher activates hints for the current
mesh via :func:`use_hints`.  When inactive (unit tests, single device),
``constrain`` is the identity, so the model stays runnable anywhere.

This is the §Perf lever for the MoE dispatch: without an explicit
constraint GSPMD keeps the (groups, experts, capacity, d_model) buffer
replicated over the model axis and only 1/16th of the chips do expert
math; pinning it to P(data, model, None, None) makes the expert einsum
fully expert-parallel.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _axes():
    return getattr(_state, "axes", None)


@contextlib.contextmanager
def use_hints(data_axes: Sequence[str], model_axis: Optional[str] = "model"):
    """Activate sharding hints for code traced inside this context."""
    prev = _axes()
    _state.axes = (tuple(data_axes), model_axis)
    try:
        yield
    finally:
        _state.axes = prev


def constrain(x, kind: str):
    """Attach a sharding constraint if hints are active.

    kinds:
      moe_buffer   (groups, E, C, D)   -> P(data, model, None, None)
      moe_buffer_global (E, C, D)      -> P(model, None, None)
      activations  (B, S, D)           -> P(data, None, None)
    """
    axes = _axes()
    if axes is None:
        return x
    data_axes, model_axis = axes
    da = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    try:
        if kind == "moe_buffer":
            spec = P(da, model_axis, None, None)
        elif kind == "moe_buffer_local":
            # groups data-sharded, experts replicated: the dispatch scatter
            # stays device-local (each model-axis replica redundantly builds
            # its copy); the subsequent moe_buffer reshard is a local slice.
            spec = P(da, None, None, None)
        elif kind == "moe_buffer_global":
            spec = P(model_axis, None, None)
        elif kind == "moe_group_dm":
            # one token-group per chip: dispatch scatters stay fully local
            # and the expert exchange is a true all-to-all (G over BOTH axes)
            spec = P(tuple(data_axes) + (model_axis,), None, None, None)
        elif kind == "tokens_dm":
            spec = P(tuple(data_axes) + (model_axis,), None, None)
        elif kind == "activations":
            spec = P(da, None, None)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
