"""Pallas TPU flash attention (blockwise streaming softmax), GQA + windows.

Targets the prefill/training hot-spot of the dense/hybrid architectures.
Layout per grid step: one (batch, q-head) pair and one query block reside
in VMEM; K/V for the matching kv-head stream through an inner fori_loop in
``block_k``-sized slices.  Running max/sum rescaling is the standard
numerically-stable streaming softmax.  Causal and sliding-window masks are
applied with position arithmetic, and fully-masked K blocks are skipped by
clamping the loop's upper bound (the TPU win: no wasted MXU work past the
diagonal).

VMEM budget per step (bf16): q block (block_q × hd) + K/V (S × hd each).
For the 32k prefill at hd=128 that is ~8 MB per tensor — within v5e's
16 MB when block_q ≤ 512; longer sequences must shard S over the mesh
first (which the launcher's sequence sharding does).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compat import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, out_ref, *,
    block_k: int, scale: float, causal: bool, window,
):
    bq, hd = q_ref.shape
    s = k_ref.shape[0]
    qi = pl.program_id(1)
    q0 = qi * bq  # absolute position of the first query in this block

    q = q_ref[...].astype(jnp.float32) * scale

    nkv = s // block_k
    if causal:
        # highest kv block any query in this block can see (skip the rest)
        hi = (q0 + bq + block_k - 1) // block_k
        nkv_eff = jnp.minimum(nkv, hi)
    else:
        nkv_eff = nkv

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        scores = q @ k.T  # (bq, block_k)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
        kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
        mask = jnp.ones((bq, block_k), bool)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nkv_eff, body, (acc0, m0, l0))
    out_ref[...] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(out_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    block_q: int = 256,
    block_k: int = 256,
    interpret=None,
) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,S,KV,hd) -> (B,S,H,hd).  GQA: H % KV == 0."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, "pad S to block multiples"
    scale = hd ** -0.5

    # fold (B, H) into the grid's leading axis; map q-head -> kv-head
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    kernel = functools.partial(
        _flash_kernel, block_k=block_k, scale=scale, causal=causal, window=window
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, S, hd), lambda h, i: (h // g, 0, 0)),
            pl.BlockSpec((None, S, hd), lambda h, i: (h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(qh, kh, vh)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
