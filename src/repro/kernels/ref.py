"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def wash_shuffle_ref(x: jax.Array, perm: jax.Array, mask: jax.Array) -> jax.Array:
    """x: (N, D); perm: (N, D); mask: (D,)."""
    shuffled = jnp.take_along_axis(x, perm, axis=0)
    return jnp.where(mask[None, :], shuffled, x)


def flash_attention_ref(q, k, v, *, causal=True, window=None) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,S,KV,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qf = q.reshape(B, S, KV, g, hd).astype(jnp.float32)
    scores = jnp.einsum("btkgh,bskh->bkgts", qf, k.astype(jnp.float32)) / (hd ** 0.5)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (j <= i)
    if window is not None:
        mask = mask & (j > i - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, page_table, lengths,
                        k_scale=None, v_scale=None) -> jax.Array:
    """Gather-then-attend oracle for the paged decode kernel.

    q: (B,H,hd); k_pool/v_pool: (P,page_size,KV,hd);
    page_table: (B,max_pages) int32; lengths: (B,) int32 -> (B,H,hd).

    Materializes each slot's context contiguously (the two-pass form the
    kernel fuses away) and applies a plain masked softmax — same grouping
    and float32 reductions as ``models.layers.sdpa``.

    ``k_scale``/``v_scale`` (``(P,)`` float32, optional) dequantize int8
    pools: page ``p``'s rows are read as ``pool[p] * scale[p]`` — the
    per-page symmetric scheme of ``models.layers.paged_pools_init``.
    """
    B, H, hd = q.shape
    _, page_size, KV, _ = k_pool.shape
    g = H // KV
    k = k_pool[page_table].reshape(B, -1, KV, hd)  # (B, max_pages*ps, KV, hd)
    v = v_pool[page_table].reshape(B, -1, KV, hd)
    if k_scale is not None:
        ps = jnp.repeat(k_scale[page_table], page_size, axis=1)  # (B, ctx)
        k = k.astype(jnp.float32) * ps[:, :, None, None]
    if v_scale is not None:
        ps = jnp.repeat(v_scale[page_table], page_size, axis=1)
        v = v.astype(jnp.float32) * ps[:, :, None, None]
    qf = q.reshape(B, KV, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, k.astype(jnp.float32)) / (hd ** 0.5)
    valid = jnp.arange(k.shape[1]) < lengths[:, None]  # (B, ctx)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u) -> jax.Array:
    """r/k/v/w: (B,T,H,hd); u: (H,hd) -> y (B,T,H,hd)."""
    B, T, H, hd = r.shape

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32), v_t.astype(jnp.float32))
        y = jnp.einsum(
            "bhk,bhkv->bhv", r_t.astype(jnp.float32), S + u[None, :, :, None] * kv
        )
        S = w_t.astype(jnp.float32)[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)
