"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to None → auto-detect: the kernels are compiled on
TPU and interpreted elsewhere (this container is CPU-only).  Pass an
explicit bool to force either path.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas
from repro.kernels.wash_shuffle import (
    bucketed_shuffle_pallas,
    wash_shuffle_pallas,
)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def wash_shuffle(x, perm, mask, block_d: int = 2048, interpret=None):
    return wash_shuffle_pallas(x, perm, mask, block_d=block_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def bucketed_shuffle(x, idx, block_d: int = 2048, interpret=None):
    return bucketed_shuffle_pallas(x, idx, block_d=block_d, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q, k, v, causal: bool = True, window=None,
    block_q: int = 256, block_k: int = 256, interpret=None,
):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, chunk: int = 16, interpret=None):
    return rwkv6_scan_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, page_table, lengths,
                    k_scale=None, v_scale=None, interpret=None):
    return paged_attention_pallas(
        q, k_pool, v_pool, page_table, lengths,
        k_scale=k_scale, v_scale=v_scale, interpret=interpret,
    )
