"""Pallas TPU kernel for the RWKV-6 WKV recurrence (Finch, arXiv:2404.05892).

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u) · k_tᵀ v_t)

The recurrence is O(T · hd²) with a (hd × hd) running state — the decode /
long-context hot-spot of the rwkv6-3b architecture.  Grid step = one
(batch·head) pair; r/k/v/w for that head stream through VMEM in one block
(T × hd each) and the state lives in an f32 VMEM scratch across the
``chunk``-strided fori_loop.  Within a chunk the T-loop is unrolled so the
VPU sees straight-line (hd × hd) FMAs instead of per-step control flow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compat import resolve_interpret


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, out_ref, *, chunk: int):
    t, hd = r_ref.shape
    u = u_ref[...].astype(jnp.float32)  # (1, hd)

    def chunk_body(c, state):
        base = c * chunk

        def step(i, st):
            idx = base + i
            r = r_ref[pl.ds(idx, 1), :].astype(jnp.float32)
            k = k_ref[pl.ds(idx, 1), :].astype(jnp.float32)
            v = v_ref[pl.ds(idx, 1), :].astype(jnp.float32)
            w = w_ref[pl.ds(idx, 1), :].astype(jnp.float32)
            kv = k.T @ v  # (hd, hd)
            y = r @ (st + u.T * kv)  # (1, hd)
            out_ref[pl.ds(idx, 1), :] = y.astype(out_ref.dtype)
            return w.T * st + kv

        return jax.lax.fori_loop(0, chunk, step, state, unroll=True)

    jax.lax.fori_loop(0, t // chunk, chunk_body, jnp.zeros((hd, hd), jnp.float32))


def rwkv6_scan_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    chunk: int = 16,
    interpret=None,
) -> jax.Array:
    """r/k/v/w: (B,T,H,hd); u: (H,hd) -> y (B,T,H,hd).

    w must already be the per-step decay in (0,1) (i.e. exp(-exp(...))).
    """
    B, T, H, hd = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, "pad T to a chunk multiple"

    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    rr, kk, vv, ww = fold(r), fold(k), fold(v), fold(w)
    uu = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)

    out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(B * H,),
        in_specs=[
            pl.BlockSpec((None, T, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, T, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, T, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, T, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, 1, hd), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, T, hd), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, hd), r.dtype),
        interpret=resolve_interpret(interpret),
    )(rr, kk, vv, ww, uu)
    return out.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
