"""Pallas TPU kernel: paged-attention decode over a block-pool KV cache.

The continuous-batching runtime (``repro.serving.batching``) stores KV in a
shared pool of fixed-size pages, ``(num_pages, page_size, KV, hd)``, with a
per-slot **page table** mapping logical context positions to pool pages.
Decode-time attention then needs a gather of each slot's pages followed by
a masked attend — two HBM passes when written naively in jnp (materialize
``(B, max_pages·page_size, KV, hd)``, then attend).

This kernel fuses the gather INTO the attend: the page table rides in as a
**scalar-prefetch** operand (``pltpu.PrefetchScalarGridSpec``), so the
BlockSpec index map dereferences ``page_table[slot, j]`` and the DMA engine
streams exactly the pages each slot owns from HBM into VMEM — no
contiguous copy of the context ever exists.  Accumulation across a slot's
pages is the standard streaming softmax (running max / sum / accumulator
in VMEM scratch, carried across the sequential page axis of the grid).

Layout per grid step ``(b·KV + k, j)``: one (slot, kv-head) pair holds its
``g = H // KV`` query rows in VMEM and visits page ``page_table[b, j]``.
Pages past a slot's length are skipped with ``pl.when`` (no DMA'd page is
wasted on fully-masked work beyond the first); intra-page tail positions
are masked with position arithmetic.

``interpret=None`` auto-detects like ``wash_shuffle``: compiled on TPU,
interpret mode elsewhere (CPU timings are correctness-only).  The pure-jnp
oracle is :func:`repro.kernels.ref.paged_attention_ref`, parity-asserted
in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import resolve_interpret

NEG_INF = -1e30


def _paged_kernel(
    *refs,
    kv: int, page_size: int, scale: float, quantized: bool,
):
    # scalar-prefetch refs lead: pt (B, max_pages) i32, lengths (B,) i32,
    # then — quantized pools only — per-slot-per-page dequant scales
    # ks/vs (B, max_pages) f32 (pre-gathered through the page table, so
    # the kernel never indexes the (P,) scale vectors itself)
    if quantized:
        pt_ref, len_ref, ks_ref, vs_ref = refs[:4]
        refs = refs[4:]
    else:
        pt_ref, len_ref = refs[:2]
        ks_ref = vs_ref = None
        refs = refs[2:]
    q_ref, k_ref, v_ref = refs[:3]   # (g, hd), (page_size, hd), (page_size, hd)
    o_ref = refs[3]                  # (g, hd)
    acc_ref, m_ref, l_ref = refs[4:]  # VMEM scratch: (g, hd), (g, 1), (g, 1)

    j = pl.program_id(1)
    b = pl.program_id(0) // kv
    length = len_ref[b]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip pages wholly past this slot's context (their DMA already
    # happened, but no VPU/MXU work is spent on fully-masked scores)
    @pl.when(j * page_size < length)
    def _page():
        g = q_ref.shape[0]
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[b, j]
        scores = q @ k.T  # (g, page_size)
        tpos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (g, page_size), 1
        )
        scores = jnp.where(tpos < length, scores, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        vpage = v_ref[...].astype(jnp.float32)
        if quantized:
            vpage = vpage * vs_ref[b, j]
        acc_ref[...] = acc_ref[...] * alpha + p @ vpage
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
        ).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    *,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One-token paged attention for a batch of serving slots.

      q          : (B, H, hd)   — the current token's query per slot
      k_pool     : (P, page_size, KV, hd) — shared K page pool
      v_pool     : (P, page_size, KV, hd) — shared V page pool
      page_table : (B, max_pages) int32 — pool page id per logical page
                   (unused tail entries may point anywhere; they are masked)
      lengths    : (B,) int32 — valid context tokens per slot (>= 1)
      k_scale /
      v_scale    : (P,) float32, optional — per-page dequant scales for
                   int8 pools (``models.layers.paged_pools_init`` with
                   ``kv_dtype="int8"``); pages are read as
                   ``pool[p] * scale[p]``.  Both or neither.

    Returns (B, H, hd).  GQA: ``H % KV == 0``; queries are grouped by kv
    head exactly as :func:`repro.models.layers.sdpa` groups them.
    """
    B, H, hd = q.shape
    P, page_size, KV, _ = k_pool.shape
    max_pages = page_table.shape[1]
    g = H // KV
    scale = hd ** -0.5
    quantized = k_scale is not None
    if quantized != (v_scale is not None):
        raise ValueError("pass both k_scale and v_scale, or neither")

    qh = q.reshape(B * KV, g, hd)
    pt = page_table.astype(jnp.int32)
    kernel = functools.partial(
        _paged_kernel, kv=KV, page_size=page_size, scale=scale,
        quantized=quantized,
    )
    # quantized pools prepend two more scalar-prefetch operands (dequant
    # scales pre-gathered to (B, max_pages)); index-map lambdas take one
    # trailing arg per prefetch operand
    n_pref = 4 if quantized else 2
    def _q_map(h, j, *pref):
        return (h, 0, 0)

    def _page_map(h, j, *pref):
        return (pref[0][h // KV, j], 0, h % KV, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_pref,
        grid=(B * KV, max_pages),
        in_specs=[
            pl.BlockSpec((None, g, hd), _q_map),
            pl.BlockSpec((None, page_size, None, hd), _page_map),
            pl.BlockSpec((None, page_size, None, hd), _page_map),
        ],
        out_specs=pl.BlockSpec((None, g, hd), _q_map),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    prefetch = (pt, lengths.astype(jnp.int32))
    if quantized:
        prefetch += (k_scale[pt].astype(jnp.float32),
                     v_scale[pt].astype(jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, g, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(*prefetch, qh, k_pool, v_pool)
    return out.reshape(B, H, hd)
