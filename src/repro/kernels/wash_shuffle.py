"""Pallas TPU kernel for the dense WASH shuffle (paper Eq. 3).

The dense shuffle is three HBM passes when written naively in jnp
(uniforms → argsort-take → where).  This kernel fuses the *apply* phase —
masked cross-member permute-gather — into a single pass over VMEM tiles of
the stacked (N, D) leaf:

    out[n, i] = x[perm[n, i], i]   if mask[i]
              = x[n, i]            otherwise

TPU adaptation: the ensemble axis N is tiny (3–16), so the per-coordinate
gather along axis 0 is realized as an N-way select (VPU-friendly
compare+select, no hardware gather), while the coordinate axis is tiled to
``block_d`` lanes in VMEM (128-aligned).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compat import resolve_interpret


def _shuffle_kernel(x_ref, perm_ref, mask_ref, out_ref, *, n: int):
    x = x_ref[...]          # (N, block_d)
    perm = perm_ref[...]    # (N, block_d) int32
    mask = mask_ref[...]    # (1, block_d) bool
    # gather along the tiny ens axis as an N-way select
    gathered = jnp.zeros_like(x)
    for m in range(n):
        gathered = jnp.where(perm == m, x[m][None, :], gathered)
    out_ref[...] = jnp.where(mask, gathered, x)


def wash_shuffle_pallas(
    x: jax.Array,
    perm: jax.Array,
    mask: jax.Array,
    *,
    block_d: int = 2048,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """x: (N, D); perm: (N, D) int32; mask: (D,) bool -> shuffled (N, D)."""
    interpret = resolve_interpret(interpret)
    n, d = x.shape
    block_d = min(block_d, d)
    # pad D to a multiple of block_d
    pad = (-d) % block_d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        perm = jnp.pad(perm, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, (0, pad))
    dp = x.shape[1]
    grid = (dp // block_d,)
    out = pl.pallas_call(
        functools.partial(_shuffle_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, dp), x.dtype),
        interpret=interpret,
    )(x, perm, mask[None, :])
    return out[:, :d]


def bucketed_shuffle_pallas(
    x: jax.Array,
    idx: jax.Array,
    *,
    block_d: int = 2048,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Bucketed WASH apply (core.shuffle's TPU-native mode) as one fused
    VMEM pass over the stacked (N, D) leaf.

    ``idx``: (N, k_per) int32 plan with pairwise-disjoint rows; bucket s
    applies the global cyclic shift θ̂_n = θ_{(n+s) mod N} on its
    coordinates, bucket 0 is the identity.  The bucket structure is first
    scattered into a per-coordinate shift map (a cheap (D,) int32 op
    outside the kernel), which turns the apply into exactly the masked
    permute-gather the dense kernel already fuses:

        perm[n, i] = (n + shift[i]) mod N,   mask[i] = shift[i] > 0

    so both modes share one Pallas kernel, one HBM pass, and one tiling
    scheme (coordinate axis tiled to ``block_d`` lanes; N-way VPU select
    along the tiny ens axis instead of a hardware gather).
    """
    n, d = x.shape
    shift = jnp.zeros((d,), jnp.int32)
    if n > 1:  # bucket 0 is the identity; rows are disjoint → one scatter
        shift = shift.at[idx[1:]].set(
            jnp.arange(1, n, dtype=jnp.int32)[:, None]
        )
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    perm = (rows + shift[None, :]) % n
    mask = shift > 0
    return wash_shuffle_pallas(
        x, perm, mask, block_d=block_d, interpret=interpret
    )
