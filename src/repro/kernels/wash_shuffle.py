"""Pallas TPU kernel for the dense WASH shuffle (paper Eq. 3).

The dense shuffle is three HBM passes when written naively in jnp
(uniforms → argsort-take → where).  This kernel fuses the *apply* phase —
masked cross-member permute-gather — into a single pass over VMEM tiles of
the stacked (N, D) leaf:

    out[n, i] = x[perm[n, i], i]   if mask[i]
              = x[n, i]            otherwise

TPU adaptation: the ensemble axis N is tiny (3–16), so the per-coordinate
gather along axis 0 is realized as an N-way select (VPU-friendly
compare+select, no hardware gather), while the coordinate axis is tiled to
``block_d`` lanes in VMEM (128-aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shuffle_kernel(x_ref, perm_ref, mask_ref, out_ref, *, n: int):
    x = x_ref[...]          # (N, block_d)
    perm = perm_ref[...]    # (N, block_d) int32
    mask = mask_ref[...]    # (1, block_d) bool
    # gather along the tiny ens axis as an N-way select
    gathered = jnp.zeros_like(x)
    for m in range(n):
        gathered = jnp.where(perm == m, x[m][None, :], gathered)
    out_ref[...] = jnp.where(mask, gathered, x)


def wash_shuffle_pallas(
    x: jax.Array,
    perm: jax.Array,
    mask: jax.Array,
    *,
    block_d: int = 2048,
    interpret: bool = True,
) -> jax.Array:
    """x: (N, D); perm: (N, D) int32; mask: (D,) bool -> shuffled (N, D)."""
    n, d = x.shape
    block_d = min(block_d, d)
    # pad D to a multiple of block_d
    pad = (-d) % block_d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        perm = jnp.pad(perm, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, (0, pad))
    dp = x.shape[1]
    grid = (dp // block_d,)
    out = pl.pallas_call(
        functools.partial(_shuffle_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, dp), x.dtype),
        interpret=interpret,
    )(x, perm, mask[None, :])
    return out[:, :d]
