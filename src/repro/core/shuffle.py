"""Parameter shuffling — the core mechanism of WASH (paper Eq. 3).

Two implementations, equal in expectation (Eq. 4) and both exactly
distance-preserving (Eq. 5):

``dense``    Faithful to the paper's math: every scalar coordinate draws an
             independent uniform permutation of {1..N} (argsort of per-scalar
             uniforms over the ens axis) gated by an independent
             Bernoulli(p_l).  Used for validation and CPU-scale repro.

``bucketed`` TPU-native: exactly k_l = round(p_l * d_l) coordinates are
             selected per leaf via stratified sampling (unique, shared
             randomness), split into N equal buckets; bucket s applies the
             cyclic shift π(n) = (n+s) mod N.  Bucket 0 is the identity, so
             each member *sends* exactly k_l*(N-1)/N scalars per leaf per
             step — the paper's p·d communication volume — and the exchange
             lowers to static-shape ``collective-permute`` ops on the ICI
             when executed under ``shard_map`` (see :func:`bucketed_apply_collective`).

Shuffles are expressed as *plans* (index pytrees) built once per step from
shared randomness, so WASH+Opt can replay the identical plan on the
optimizer state (paper §4 "Training methods").
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import axis_size
from repro.core.schedules import layer_probability, layer_probability_array

PyTree = Any


# ---------------------------------------------------------------------------
# dense (faithful) mode
# ---------------------------------------------------------------------------


def dense_plan(key: jax.Array, shape, n: int, p_l: float):
    """Per-coordinate uniform permutation + Bernoulli gate for one leaf.

    ``shape`` is the *member* shape (without the ens axis).  Returns
    ``(perm, mask)`` with ``perm: (n, *shape) int32`` columns being
    independent uniform permutations of range(n) and ``mask: shape bool``.
    """
    kp, km = jax.random.split(key)
    u = jax.random.uniform(kp, (n,) + tuple(shape))
    perm = jnp.argsort(u, axis=0).astype(jnp.int32)
    mask = jax.random.bernoulli(km, p=jnp.float32(p_l), shape=tuple(shape))
    return perm, mask


def dense_apply(leaf: jax.Array, perm: jax.Array, mask: jax.Array) -> jax.Array:
    """θ̂_n^i = θ_{π_i(n)}^i where masked, else θ_n^i (leaf: (n, *shape))."""
    shuffled = jnp.take_along_axis(leaf, perm, axis=0)
    return jnp.where(mask[None], shuffled, leaf)


def dense_plan_layered(key: jax.Array, shape, n: int, p_vec):
    """Dense plan for a stacked-blocks leaf: shape = (L, *rest).

    ``p_vec`` gives the Eq. 6 probability per scanned layer, so the
    layer-wise schedule stays exact even when all blocks live in one leaf.
    """
    kp, km = jax.random.split(key)
    u = jax.random.uniform(kp, (n,) + tuple(shape))
    perm = jnp.argsort(u, axis=0).astype(jnp.int32)
    p = jnp.asarray(p_vec, jnp.float32).reshape((shape[0],) + (1,) * (len(shape) - 1))
    mask = jax.random.uniform(km, tuple(shape)) < p
    return perm, mask


# ---------------------------------------------------------------------------
# bucketed (TPU-native) mode
# ---------------------------------------------------------------------------


def stratified_unique_indices(key: jax.Array, d: int, k: int) -> jax.Array:
    """k unique indices in [0, d), one uniform draw per equal stratum.

    Deterministically unique (strata are disjoint) with uniform marginal
    coverage — a static-shape, sort-free surrogate for sampling without
    replacement, chosen for TPU friendliness.  The returned order is
    randomly permuted so position within the plan carries no information.
    """
    if k <= 0:
        return jnp.zeros((0,), jnp.int32)
    ko, ks = jax.random.split(key)
    i = jnp.arange(k)
    starts = (i * d) // k
    ends = ((i + 1) * d) // k
    widths = jnp.maximum(ends - starts, 1)
    offs = jax.random.randint(ko, (k,), 0, jnp.iinfo(jnp.int32).max) % widths
    idx = (starts + offs).astype(jnp.int32)
    return jax.random.permutation(ks, idx)


def bucket_count(d: int, n: int, p_l: float) -> int:
    """Per-bucket coordinate count k_per; total selected = k_per * n."""
    k = int(round(p_l * d))
    return max(k // n, 0)


def bucketed_plan(
    key: jax.Array, d: int, n: int, p_l: float, k_per: Optional[int] = None
) -> Optional[jax.Array]:
    """Index plan ``(n, k_per) int32``; row s holds coordinates shifted by s.

    Returns None when the leaf is too small / probability too low for even
    one coordinate per bucket (no communication for this leaf this step).
    ``k_per`` overrides the count derived from ``p_l`` — the shard-local
    planner (:mod:`repro.core.shardplan`) passes each shard's slice of the
    *global* budget so per-shard volumes never exceed the global plan's.
    """
    if k_per is None:
        k_per = bucket_count(d, n, p_l)
    if k_per == 0:
        return None
    idx = stratified_unique_indices(key, d, k_per * n)
    return idx.reshape(n, k_per)


def bucketed_plan_layered(
    key: jax.Array, num_layers: int, d_rest: int, n: int, p_vec,
    counts=None,
) -> Optional[jax.Array]:
    """Bucketed plan for a stacked-blocks leaf of member shape (L, d_rest).

    Layer l contributes round(p_l * d_rest) coordinates inside its own flat
    range [l*d_rest, (l+1)*d_rest); counts are static (p_vec is static), so
    the concatenated index set keeps Eq. 6's depth profile exactly while
    remaining a single static-shape plan.  The pooled set is randomly
    permuted, trimmed to a multiple of N and reshaped to (N, k_per).

    ``counts`` overrides the per-layer coordinate counts (pre-clip); the
    shard-local planner passes each shard's slice of the global per-layer
    budget, with ``d_rest`` then being the *local* per-layer flat size.
    """
    if counts is None:
        counts = [int(round(float(p_vec[l]) * d_rest)) for l in range(num_layers)]
    pieces = []
    for l in range(num_layers):
        k_l = int(counts[l])
        if k_l <= 0:
            continue
        kl_key = jax.random.fold_in(key, l)
        idx_l = stratified_unique_indices(kl_key, d_rest, min(k_l, d_rest))
        pieces.append(idx_l + l * d_rest)
    if not pieces:
        return None
    idx = jnp.concatenate(pieces)
    k_per = idx.shape[0] // n
    if k_per == 0:
        return None
    idx = jax.random.permutation(jax.random.fold_in(key, num_layers + 1), idx)
    return idx[: k_per * n].reshape(n, k_per)


def bucketed_apply_stacked(leaf: jax.Array, idx: jax.Array) -> jax.Array:
    """Apply a bucketed plan to a stacked leaf (n, *shape) — no collectives."""
    n = leaf.shape[0]
    flat = leaf.reshape(n, -1)
    for s in range(1, n):
        vals = flat[:, idx[s]]
        # θ̂_n = θ_{(n+s) mod N}: member n takes member (n+s)'s value.
        flat = flat.at[:, idx[s]].set(jnp.roll(vals, -s, axis=0))
    return flat.reshape(leaf.shape)


def bucketed_apply_collective(
    x_flat: jax.Array, idx: jax.Array, axis_name: str
) -> jax.Array:
    """Apply a bucketed plan to one member's flat params under shard_map.

    Each bucket is a single ``ppermute``: member j sends its k_per selected
    scalars to member (j-s) mod N (equivalently: everyone receives from its
    (n+s)-th neighbour).  Total send volume per member per step:
    k_per * (N-1) scalars = p·d·(N-1)/N — the paper's Table 1 accounting.
    """
    n = axis_size(axis_name)
    out = x_flat
    for s in range(1, n):
        vals = x_flat[idx[s]]
        recv = lax.ppermute(
            vals, axis_name, perm=[(j, (j - s) % n) for j in range(n)]
        )
        out = out.at[idx[s]].set(recv)
    return out


def _block_from(vals: jax.Array, axis_name, q: int, m: int) -> jax.Array:
    """This shard's copy of the block held q shards ahead on the ring."""
    if q % m == 0:
        return vals
    return lax.ppermute(
        vals, axis_name, perm=[(j, (j - q) % m) for j in range(m)]
    )


def bucketed_apply_collective_blocked(
    x_flat: jax.Array, idx: jax.Array, axis_name
) -> jax.Array:
    """Bucketed apply for a shard holding ``n_local`` contiguous members.

    ``x_flat``: (n_local, D); the global population is n = n_local * m
    (m = mesh axis size).  Bucket s applies the global cyclic shift
    θ̂_g = θ_{(g+s) mod n}.  For member i of shard j (global g = j*n_local+i)
    the source rows [g+s, g+s+n_local) span at most two neighbouring
    shards, so each bucket costs ≤ 2 static ``ppermute`` ops regardless of
    n_local.  Degenerate cases recover the existing paths exactly:
    m == 1 → jnp.roll (the stacked reference), n_local == 1 → the
    per-member :func:`bucketed_apply_collective`.
    """
    m = axis_size(axis_name)
    n_local = x_flat.shape[0]
    n = n_local * m
    out = x_flat
    for s in range(1, n):
        vals = out[:, idx[s]]                       # (n_local, k_per)
        q, r = divmod(s, n_local)
        recv1 = _block_from(vals, axis_name, q, m)
        if r == 0:
            shifted = recv1
        else:
            recv2 = _block_from(vals, axis_name, q + 1, m)
            shifted = jnp.concatenate([recv1, recv2], axis=0)[r : r + n_local]
        out = out.at[:, idx[s]].set(shifted)
    return out


# ---------------------------------------------------------------------------
# tree-level plans
# ---------------------------------------------------------------------------


def make_plan(
    key: jax.Array,
    params: PyTree,
    layer_ids: PyTree,
    total_layers: int,
    base_p: float,
    schedule: str = "decreasing",
    mode: str = "dense",
    n: Optional[int] = None,
) -> PyTree:
    """Build a shuffle plan for a whole (stacked) population pytree.

    ``params`` may be the stacked population (leading ens axis) or a single
    member template together with explicit ``n``.
    """
    import numpy as np

    # ints and np.ndarrays are both ordinary pytree leaves, so layer_ids
    # flattens in lockstep with params.
    leaves, treedef = jax.tree_util.tree_flatten(params)
    lid_leaves = jax.tree_util.tree_flatten(layer_ids)[0]
    plans = []
    for i, (leaf, lid) in enumerate(zip(leaves, lid_leaves)):
        k = jax.random.fold_in(key, i)
        if n is None:
            nn, member_shape = int(leaf.shape[0]), leaf.shape[1:]
        else:
            nn, member_shape = n, leaf.shape
        layered = not isinstance(lid, int)
        if layered:
            p_vec = np.clip(
                layer_probability_array(base_p, lid, total_layers, schedule), 0.0, 1.0
            )
            if p_vec.max() <= 0.0:
                plans.append(None)
                continue
            assert member_shape and len(p_vec) == member_shape[0], (
                f"layered lid len {len(p_vec)} vs leaf {member_shape}"
            )
            if mode == "dense":
                plans.append(dense_plan_layered(k, member_shape, nn, p_vec))
            elif mode == "bucketed":
                d_rest = int(np.prod(member_shape[1:], dtype=np.int64)) if len(member_shape) > 1 else 1
                plans.append(
                    bucketed_plan_layered(k, int(member_shape[0]), d_rest, nn, p_vec)
                )
            else:
                raise ValueError(f"unknown shuffle mode {mode!r}")
            continue
        p_l = layer_probability(base_p, int(lid), total_layers, schedule)
        if p_l <= 0.0:
            plans.append(None)
        elif mode == "dense":
            plans.append(dense_plan(k, member_shape, nn, min(p_l, 1.0)))
        elif mode == "bucketed":
            d = 1
            for s in member_shape:
                d *= int(s)
            plans.append(bucketed_plan(k, d, nn, min(p_l, 1.0)))
        else:
            raise ValueError(f"unknown shuffle mode {mode!r}")
    return jax.tree_util.tree_unflatten(treedef, plans)


def _bucketed_apply_pallas(leaf: jax.Array, idx: jax.Array) -> jax.Array:
    """Stacked bucketed apply through the fused Pallas kernel (one VMEM
    pass instead of N-1 roll/scatter rounds).  Pure data movement, so the
    result is bitwise-identical to :func:`bucketed_apply_stacked`;
    ``interpret=None`` auto-detects TPU vs interpret mode."""
    from repro.kernels.wash_shuffle import bucketed_shuffle_pallas

    n = leaf.shape[0]
    flat = leaf.reshape(n, -1)
    return bucketed_shuffle_pallas(flat, idx).reshape(leaf.shape)


def apply_plan_stacked(
    plan: PyTree, tree: PyTree, mode: str = "dense", use_pallas: bool = False
) -> PyTree:
    """Apply a plan to a stacked pytree (params, or optimizer moments).

    ``use_pallas`` routes bucketed applies through the fused Pallas kernel
    (:func:`repro.kernels.wash_shuffle.bucketed_shuffle_pallas`)."""

    def _one(p, leaf):
        if p is None:
            return leaf
        if mode == "dense":
            perm, mask = p
            return dense_apply(leaf, perm, mask)
        if use_pallas:
            return _bucketed_apply_pallas(leaf, p)
        return bucketed_apply_stacked(leaf, p)

    return jax.tree_util.tree_map(
        _one, plan, tree, is_leaf=lambda x: x is None or isinstance(x, tuple)
    )


def apply_plan_collective(plan: PyTree, tree: PyTree, axis_name: str) -> PyTree:
    """Apply a bucketed plan to one member's pytree under shard_map."""

    def _one(p, leaf):
        if p is None:
            return leaf
        flat = leaf.reshape(-1)
        return bucketed_apply_collective(flat, p, axis_name).reshape(leaf.shape)

    return jax.tree_util.tree_map(_one, plan, tree, is_leaf=lambda x: x is None)


def apply_plan_collective_blocked(
    plan: PyTree, tree: PyTree, axis_name, use_pallas: bool = False
) -> PyTree:
    """Apply a bucketed plan to a block of members under shard_map.

    ``tree`` leaves carry a leading local-ens axis (n_local, *member_shape);
    the plan was built for the population held along ``axis_name`` (a mesh
    axis name or tuple of names), so every shard applies the same indices
    and the cross-shard rows travel by ``ppermute``.

    ``use_pallas`` routes the apply through the fused Pallas kernel when
    the population axis is a single shard (the 1-device degenerate case,
    where the blocked apply is exactly the stacked roll); multi-shard
    exchanges always take the ``ppermute`` path — the kernel is a local
    HBM-pass optimization, not a collective.
    """
    pallas_ok = use_pallas and axis_size(axis_name) == 1

    def _one(p, leaf):
        if p is None:
            return leaf
        if pallas_ok:
            return _bucketed_apply_pallas(leaf, p)
        n_local = leaf.shape[0]
        flat = leaf.reshape(n_local, -1)
        return bucketed_apply_collective_blocked(flat, p, axis_name).reshape(
            leaf.shape
        )

    return jax.tree_util.tree_map(_one, plan, tree, is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# communication accounting (paper Table 1)
# ---------------------------------------------------------------------------


def plan_selected_scalars(plan: PyTree, mode: str = "dense"):
    """Scalars *selected* for shuffling this step (paper's p·d accounting)."""
    total = 0
    for p in jax.tree_util.tree_leaves(
        plan, is_leaf=lambda x: x is None or isinstance(x, tuple)
    ):
        if p is None:
            continue
        if mode == "dense":
            _, mask = p
            total = total + jnp.sum(mask)
        else:
            total = total + p.size
    return total


def plan_sent_scalars(plan: PyTree, n: int, mode: str = "dense"):
    """Scalars actually *sent* per member (identity assignments excluded)."""
    sel = plan_selected_scalars(plan, mode)
    return sel * (n - 1) / n
