"""End-of-training evaluation strategies (paper §4 'Evaluation strategy').

  Ensemble   : average the *predictions* (softmax probs) of all members.
  Averaged   : uniform weight soup  θ̄ = (1/N) Σ θ_n  (UniformSoup / AvgSoup).
  GreedySoup : add members in decreasing val-accuracy order, keep a member
               only if it improves val accuracy of the running soup.
"""

from __future__ import annotations

from typing import Any, Callable, List

import jax
import jax.numpy as jnp

from repro.core import population as pop

PyTree = Any


def balanced_mean(x: jax.Array) -> jax.Array:
    """Mean over axis 0 as a fixed balanced pairwise-sum tree.

    The explicit pairwise tree (instead of ``jnp.mean``'s backend-chosen
    reduction order) makes the result *layout-independent bitwise*: the
    same arithmetic DAG runs whether the leading axis lives on one device
    or is sharded one-row-per-device.  Used for both weight soups
    (:func:`uniform_soup`) and the serving engine's ensemble-mode logit
    averaging, so the two averaging paths cannot drift apart numerically.
    """
    rows = [x[i] for i in range(x.shape[0])]
    n = len(rows)
    while len(rows) > 1:
        nxt = [rows[i] + rows[i + 1] for i in range(0, len(rows) - 1, 2)]
        if len(rows) % 2:
            nxt.append(rows[-1])
        rows = nxt
    return rows[0] / n


def uniform_soup(stacked: PyTree) -> PyTree:
    """Uniform weight soup θ̄ = (1/N) Σ θ_n, as a fixed balanced-tree sum.

    Layout-independent bitwise (see :func:`balanced_mean`): serving soups
    from the vmap and fused shard_map engines compare equal — asserted in
    tests/test_shardplan.py on a real multi-device population."""
    return jax.tree_util.tree_map(balanced_mean, stacked)


def soup_of(stacked: PyTree, indices: List[int]) -> PyTree:
    idx = jnp.asarray(indices)
    return jax.tree_util.tree_map(lambda x: jnp.mean(x[idx], axis=0), stacked)


def ensemble_logprobs(
    apply_fn: Callable[[PyTree, Any], jax.Array], stacked: PyTree, batch
) -> jax.Array:
    """log of the member-averaged softmax (the paper's Ensemble)."""
    logits = jax.vmap(lambda p: apply_fn(p, batch))(stacked)  # (N, B, C)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.log(jnp.mean(probs, axis=0) + 1e-9)


def ensemble_accuracy(apply_fn, stacked, batch, labels) -> jax.Array:
    lp = ensemble_logprobs(apply_fn, stacked, batch)
    return jnp.mean(jnp.argmax(lp, axis=-1) == labels)


def member_accuracies(apply_fn, stacked, batch, labels) -> jax.Array:
    logits = jax.vmap(lambda p: apply_fn(p, batch))(stacked)
    return jnp.mean(jnp.argmax(logits, axis=-1) == labels[None], axis=-1)


def model_accuracy(apply_fn, params, batch, labels) -> jax.Array:
    logits = apply_fn(params, batch)
    return jnp.mean(jnp.argmax(logits, axis=-1) == labels)


def greedy_soup(
    apply_fn: Callable, stacked: PyTree, val_batch, val_labels
) -> PyTree:
    """GreedySoup of Wortsman et al. (51), as evaluated in the paper."""
    accs = member_accuracies(apply_fn, stacked, val_batch, val_labels)
    order = list(jnp.argsort(-accs))
    chosen: List[int] = [int(order[0])]
    best = float(model_accuracy(apply_fn, soup_of(stacked, chosen), val_batch, val_labels))
    for i in order[1:]:
        trial = chosen + [int(i)]
        acc = float(model_accuracy(apply_fn, soup_of(stacked, trial), val_batch, val_labels))
        if acc >= best:
            chosen, best = trial, acc
    return soup_of(stacked, chosen)


def interpolate(stacked: PyTree, weights) -> PyTree:
    """Arbitrary convex combination (Fig. 6 interpolation heatmaps)."""
    w = jnp.asarray(weights)
    w = w / jnp.sum(w)
    n = pop.population_size(stacked)
    assert w.shape == (n,)
    return jax.tree_util.tree_map(
        lambda x: jnp.tensordot(w, x, axes=(0, 0)), stacked
    )
