"""Layer-wise shuffle-probability schedules (paper Eq. 6 + Tab. 4 ablations)."""

from __future__ import annotations


def layer_probability(
    base_p: float, depth: int, total_layers: int, schedule: str = "decreasing"
) -> float:
    """Shuffle probability for a parameter at ``depth`` in [0, L-1].

    decreasing : p_l = p * (1 - l/(L-1))   (paper default; last layer frozen)
    constant   : p_l = p
    increasing : p_l = p * l/(L-1)         (first layer frozen)
    """
    if total_layers <= 1:
        return base_p
    frac = depth / (total_layers - 1)
    if schedule == "decreasing":
        return base_p * (1.0 - frac)
    if schedule == "constant":
        return base_p
    if schedule == "increasing":
        return base_p * frac
    raise ValueError(f"unknown schedule {schedule!r}")


def layer_probability_array(base_p, depths, total_layers: int, schedule: str = "decreasing"):
    """Vectorized :func:`layer_probability` for stacked-block leaves.

    ``depths`` is an integer array (one depth per scanned layer); returns a
    float array of per-layer probabilities.
    """
    import numpy as np

    depths = np.asarray(depths, dtype=np.float64)
    if total_layers <= 1:
        return np.full_like(depths, base_p)
    frac = depths / (total_layers - 1)
    if schedule == "decreasing":
        return base_p * (1.0 - frac)
    if schedule == "constant":
        return np.full_like(depths, base_p)
    if schedule == "increasing":
        return base_p * frac
    raise ValueError(f"unknown schedule {schedule!r}")


def active_window(step: int, start_step: int, stop_step) -> bool:
    """Fig. 5b ablation: shuffle only inside [start_step, stop_step)."""
    if step < start_step:
        return False
    if stop_step is not None and step >= stop_step:
        return False
    return True
