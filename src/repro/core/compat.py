"""Version compatibility for the distributed jax APIs.

The distributed path (fused engine, dry-run, mesh constructors) is written
against the modern surface — ``jax.shard_map``, ``jax.make_mesh(...,
axis_types=...)``, ``jax.set_mesh`` — but must also run on jax 0.4.x where
those live under ``jax.experimental.shard_map`` / don't exist yet.  Every
call site imports the shims from here instead of feature-testing inline.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax import lax

Specs = Any


def axis_size(axis_name) -> int:
    """Static mesh-axis size inside shard_map (``lax.axis_size`` is newer
    than 0.4.x; ``psum`` of a literal takes the static fast path)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``.

    ``check_vma`` maps onto the old API's ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported; falls back
    to ``mesh_utils`` + ``Mesh`` on jax versions without ``make_mesh``."""
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError, AttributeError):
        pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh

    return Mesh(mesh_utils.create_device_mesh(shape), axes)


def donate_argnums(argnums):
    """Buffer-donation argnums, or () on CPU where donation is an ignored
    no-op that only triggers a jax warning.  Shared by both serving
    runtimes (scan engine and continuous batching)."""
    return argnums if jax.default_backend() in ("tpu", "gpu") else ()


def resolve_interpret(interpret) -> bool:
    """Pallas ``interpret=None`` → auto-detect: compile the kernel on TPU,
    interpret everywhere else (CPU containers).  Explicit bools pass
    through untouched."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def use_mesh(mesh):
    """Ambient-mesh context: ``jax.set_mesh`` or the legacy ``with mesh:``."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext()
