"""Shard-local WASH mixing plans for ens×data×model meshes.

The stacked/bucketed mixing paths (:mod:`repro.core.mixing`) build one
*global* plan per parameter leaf and assume the leaf is replicated within
a member.  On a production ``(ens, data, model)`` mesh that is exactly
wrong: gathering globally-indexed coordinates breaks the parameter
sharding, and XLA replicates the selected payload over each member's
chips before the ens-axis permute.  This module is the planner that makes
WASH mesh-native:

  * **Axis roles** (:func:`classify_roles`): every mesh axis gets an
    explicit :class:`AxisRole` — ``ENS`` axes carry the population (the
    ``ens`` axis, plus data axes when the population divides over them —
    then every chip holds whole members and per-member compute stays
    bitwise-identical to the ens-only engine); leftover ``DATA`` axes
    split each member's batch (gradients ``pmean`` over them); ``MODEL``
    axes shard members and are visible to the planner only through the
    PartitionSpecs; a ``PIPE`` axis partitions each member's blocks into
    contiguous pipeline stages (:func:`repro.core.layer_index.
    stage_layer_bounds`).  A size-1 ``pipe`` axis is dropped entirely, so
    degenerate pipeline meshes take the single-stage (bitwise-identical)
    paths.
  * **Per-stage plans**: a pipe-sharded blocks leaf draws one sub-plan per
    stage from that stage's own budget, in stage-*local* coordinates.
    Every chip builds all stages' sub-plans from the same key
    (``fold_in(leaf_key, stage)``), concatenates them, and masks foreign
    stages' columns to the out-of-range sentinel ``d_local`` — JAX clamps
    OOB gathers and *drops* OOB scatters, so the masked columns move no
    data, the plan array stays SPMD-uniform (one trace), and the
    ``ppermute`` rings run purely within each stage's ens slice.
    :func:`static_stage_mix_comm` accounts each stage exactly;
    :func:`static_shard_mix_comm` is their literal sum.
  * **Local shard shapes** are derived once, host-side, from a member
    template + per-leaf ``PartitionSpec`` via ``jax.eval_shape``-style
    shape math and spec slicing (:func:`plan_population_mixing`); no
    device math is scattered at call sites.
  * **Per-shard budgets**: each shard draws its slice of the *global*
    bucketed budget — ``k_per_local = k_per_global // num_shards``
    (per-layer for scanned-blocks leaves) — so the summed per-shard
    communication volume never exceeds the global plan's (asserted in
    ``tests/test_shardplan.py``).  An unsharded leaf keeps the exact
    global budget, which makes the single-``ens``-axis path bitwise
    identical to :func:`repro.core.mixing.mix_collective_blocked`.
  * **Plan keys** fold the chip's shard position *per leaf*: the leaf key
    (``fold_in(step_key, leaf_index)``, matching
    :func:`repro.core.shuffle.make_plan`) is folded with the linearized
    coordinate over the mesh axes that actually shard that leaf.  Shards
    therefore draw independent permutations, while chips that hold
    replicas of the same shard (e.g. data-replicated leaves) fold the
    same position and stay consistent — and the ``ens``-axis ``ppermute``
    neighbours agree on every bucket.  Eq. (4)/(5) hold per shard, hence
    globally, and the permute payload is the paper's p·d/chips.

Public entry points: :func:`plan_population_mixing` (the static planner),
:func:`mix_collective_sharded` (the in-``shard_map`` mixing step the fused
engine calls), :func:`static_shard_mix_comm` (exact host-side float64
accounting), and :func:`make_shardlocal_mixer` (a standalone
``shard_map``-wrapped mixer; ``repro.launch.dryrun`` delegates here).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import shuffle as shf
from repro.core.layer_index import (
    infer_layer_ids,
    stage_layer_bounds,
    stage_of_depth,
    total_layers,
)
from repro.core.mixing import MixingConfig, momentum_like_leaves
from repro.core.schedules import layer_probability, layer_probability_array

PyTree = Any

PIPE_AXIS = "pipe"


# ---------------------------------------------------------------------------
# axis classification
# ---------------------------------------------------------------------------


def data_like_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry batch/data parallelism (mirrors launch.mesh.data_axes
    without importing launch from core)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


class AxisRole(enum.Enum):
    """What a mesh axis *means* to the population planner."""

    ENS = "ens"      # carries the population (ppermute rings run here)
    DATA = "data"    # splits each member's batch (gradients pmean here)
    MODEL = "model"  # shards member parameters (visible via PartitionSpecs)
    PIPE = "pipe"    # partitions each member's blocks into pipeline stages


@dataclasses.dataclass(frozen=True)
class AxisRoles:
    """Explicit per-axis role assignment for one mesh.

    The single source of truth the planner, the fused engines, and the
    accounting all read; replaces the old ``(pop_axes, dp_axes)`` tuple
    plumbing (anything not in either tuple used to be implicitly
    model-ish).  Size-1 ``pipe`` axes never appear here — they are dropped
    at classification time so degenerate pipeline meshes take the
    single-stage code paths bitwise.
    """

    roles: Tuple[Tuple[str, AxisRole], ...]

    def axes(self, role: AxisRole) -> Tuple[str, ...]:
        return tuple(a for a, r in self.roles if r == role)

    @property
    def pop_axes(self) -> Tuple[str, ...]:
        return self.axes(AxisRole.ENS)

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return self.axes(AxisRole.DATA)

    @property
    def model_axes(self) -> Tuple[str, ...]:
        return self.axes(AxisRole.MODEL)

    @property
    def pipe_axis(self) -> Optional[str]:
        p = self.axes(AxisRole.PIPE)
        return p[0] if p else None

    def role_of(self, axis: str) -> Optional[AxisRole]:
        return dict(self.roles).get(axis)


def classify_roles(
    mesh,
    n: int,
    *,
    pop_axes: Optional[Tuple[str, ...]] = None,
    dp_axes: Optional[Tuple[str, ...]] = None,
) -> AxisRoles:
    """Assign an :class:`AxisRole` to every mesh axis for a population of n.

    Population axes always start with ``ens``.  Data axes are *absorbed*
    into the population when the population divides over ens×data — each
    chip then holds whole members and the per-member update needs no
    gradient collective, which keeps multi-axis runs bitwise-identical to
    the ens-only engine.  Otherwise data axes split each member's batch
    (``DATA``) and gradients are ``pmean``-ed over them.  An axis named
    ``pipe`` (of size > 1) becomes the pipeline-stage axis; every other
    axis is ``MODEL``.  Callers may pin ``pop_axes``/``dp_axes`` explicitly
    (the standalone mixer derives them from its population specs); the
    pipe axis is still recognized by name.
    """
    names = mesh.axis_names
    if pop_axes is None or dp_axes is None:
        if "ens" not in names:
            raise ValueError(f"population mesh needs an 'ens' axis; got {names}")
        e = int(mesh.shape["ens"])
        if n % e:
            raise ValueError(
                f"population {n} must divide over ens axis of size {e}"
            )
        # size-1 data axes carry nothing: keep them out of both groups so
        # degenerate meshes take the trivial (bitwise-identical) body
        data = tuple(
            a for a in data_like_axes(mesh) if int(mesh.shape[a]) > 1
        )
        dsz = int(np.prod([mesh.shape[a] for a in data])) if data else 1
        if data and (n // e) % dsz == 0:
            auto_pop, auto_dp = ("ens",) + data, ()
        else:
            auto_pop, auto_dp = ("ens",), data
        pop_axes = auto_pop if pop_axes is None else tuple(pop_axes)
        dp_axes = auto_dp if dp_axes is None else tuple(dp_axes)
    else:
        pop_axes, dp_axes = tuple(pop_axes), tuple(dp_axes)

    roles = []
    for a in names:
        if a in pop_axes:
            roles.append((a, AxisRole.ENS))
        elif a in dp_axes:
            roles.append((a, AxisRole.DATA))
        elif a == PIPE_AXIS and int(mesh.shape[a]) > 1:
            roles.append((a, AxisRole.PIPE))
        else:
            roles.append((a, AxisRole.MODEL))
    return AxisRoles(roles=tuple(roles))


def classify_axes(mesh, n: int) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """Back-compat view of :func:`classify_roles`: ``(pop_axes, dp_axes)``."""
    r = classify_roles(mesh, n)
    return r.pop_axes, r.dp_axes


# ---------------------------------------------------------------------------
# the static planner
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafShardInfo:
    """Static per-leaf shard geometry + bucketed budget (host-side only)."""

    index: int                      # plan-key fold index (flatten order)
    member_shape: Tuple[int, ...]   # global member shape
    local_shape: Tuple[int, ...]    # this chip's member-shard shape
    sharded_dims: Tuple[Tuple[int, str, int], ...]  # (dim, axis, local_size)
    num_shards: int                 # model shards only (pipe excluded)
    layered: bool
    counts_local: Optional[Tuple[int, ...]]  # layered per-layer budget (all L)
    k_per_local: int                # non-layered per-bucket count (0: no plan)
    sel_local: int                  # scalars selected per shard per step
    d_local: int                    # flat size of the local member shard
    d_rest_local: int               # layered: per-layer local flat size
    # pipeline fields (single-stage plans: stage=0, bounds/k_per None)
    stage: int = 0                  # owner stage of a non-stage-split leaf
    stage_bounds: Optional[Tuple[Tuple[int, int], ...]] = None
    stage_k_per: Optional[Tuple[int, ...]] = None  # per-stage bucket budget

    @property
    def shard_axes(self) -> Tuple[str, ...]:
        return tuple(a for _, a, _ in self.sharded_dims)

    @property
    def stage_split(self) -> bool:
        return self.stage_k_per is not None


@dataclasses.dataclass(frozen=True)
class PopulationPlan:
    """Everything the fused engine needs to mix a sharded population.

    Built once, host-side, by :func:`plan_population_mixing`; consumed at
    trace time inside ``shard_map`` (never itself traced).
    """

    roles: AxisRoles
    axis_sizes: Tuple[Tuple[str, int], ...]
    num_stages: int                 # pipe-axis size (1: no pipeline)
    n: int                          # global population
    n_local: int                    # members per pop-shard
    infos: Tuple[Optional[LeafShardInfo], ...]  # flatten order
    treedef: Any
    mcfg: MixingConfig

    @property
    def pop_axes(self) -> Tuple[str, ...]:
        return self.roles.pop_axes

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return self.roles.dp_axes

    @property
    def pipe_axis(self) -> Optional[str]:
        return self.roles.pipe_axis

    @property
    def any_sharded(self) -> bool:
        return any(i is not None and i.sharded_dims for i in self.infos)

    def size(self, axis: str) -> int:
        return dict(self.axis_sizes)[axis]


def _local_leaf_geometry(shape, spec, mesh, roles: AxisRoles, layered=False):
    """Spec slicing: the chip-local shard shape of one *member* leaf.

    Returns ``(local_shape, sharded_dims, num_shards, pipe_stages)``.
    The pipe axis is handled specially: it may only appear alone on the
    scanned layer axis (dim 0) of a stacked-blocks leaf, never enters
    ``sharded_dims``/``num_shards`` (plan keys must NOT fold the stage —
    every chip builds all stages' sub-plans), and tolerates uneven layer
    counts (``local[0]`` is the floor; the planner's per-stage accounting
    uses :func:`repro.core.layer_index.stage_layer_bounds`, the engines
    require exact divisibility).
    """
    entries = tuple(spec) if spec is not None else ()
    local = list(shape)
    sharded_dims = []
    num_shards = 1
    pipe_stages = 1
    pipe = roles.pipe_axis
    for dim, e in enumerate(entries):
        if e is None:
            continue
        axes = (e,) if isinstance(e, str) else tuple(e)
        for a in axes:
            if roles.role_of(a) in (AxisRole.ENS, AxisRole.DATA):
                raise ValueError(
                    f"param spec uses axis {a!r}, which carries the "
                    f"population/batch — member specs may only use model/"
                    f"pipe-type axes (mesh axes {mesh.axis_names}, "
                    f"roles {roles.roles})"
                )
        if pipe is not None and pipe in axes:
            if axes != (pipe,):
                raise ValueError(
                    f"the pipe axis cannot share a dim with {axes}"
                )
            if not (layered and dim == 0):
                raise ValueError(
                    f"the pipe axis may only shard the scanned layer axis "
                    f"(dim 0) of stacked-blocks leaves; got dim {dim} of "
                    f"shape {shape} (layered={layered})"
                )
            pipe_stages = int(mesh.shape[pipe])
            local[dim] = shape[0] // pipe_stages
            continue
        sz = int(np.prod([mesh.shape[a] for a in axes]))
        if sz == 1:
            continue
        if local[dim] % sz:
            raise ValueError(
                f"leaf dim {dim} of shape {shape} not divisible by mesh "
                f"axes {axes} (size {sz})"
            )
        local[dim] //= sz
        if len(axes) != 1:
            raise ValueError(
                f"multi-axis sharding of one dim ({axes}) is not supported "
                "by the shard-local planner yet"
            )
        sharded_dims.append((dim, axes[0], local[dim]))
        num_shards *= sz
    return tuple(local), tuple(sharded_dims), num_shards, pipe_stages


def plan_population_mixing(
    mesh,
    member_tpl: PyTree,
    member_specs: PyTree,
    mcfg: MixingConfig,
    layer_ids: PyTree,
    tl: int,
    n: int,
    *,
    pop_axes: Optional[Tuple[str, ...]] = None,
    dp_axes: Optional[Tuple[str, ...]] = None,
) -> PopulationPlan:
    """Build the static shard-local mixing plan for a population.

    ``member_tpl`` is a single-member pytree (arrays or
    ``ShapeDtypeStruct``); ``member_specs`` its per-leaf ``PartitionSpec``s
    (``None``/``P()`` = replicated).  ``layer_ids``/``tl`` follow
    :func:`repro.core.shuffle.make_plan`; per-leaf key folding matches it
    exactly, so an entirely-unsharded plan reproduces the global plan
    bitwise.  A ``pipe`` mesh axis (size > 1) splits stage-sharded blocks
    leaves into per-stage budgets and assigns every other leaf an owner
    stage by depth.
    """
    roles = classify_roles(mesh, n, pop_axes=pop_axes, dp_axes=dp_axes)
    pop_axes, dp_axes = roles.pop_axes, roles.dp_axes
    pipe = roles.pipe_axis
    num_stages = int(mesh.shape[pipe]) if pipe is not None else 1
    member_sds = jax.eval_shape(lambda: member_tpl)
    leaves, treedef = jax.tree_util.tree_flatten(member_sds)
    spec_leaves = jax.tree_util.tree_flatten(
        member_specs, is_leaf=lambda x: x is None or isinstance(x, P)
    )[0]
    lid_leaves = jax.tree_util.tree_flatten(layer_ids)[0]
    if not (len(leaves) == len(spec_leaves) == len(lid_leaves)):
        raise ValueError(
            f"member/specs/layer_ids trees disagree: {len(leaves)} vs "
            f"{len(spec_leaves)} vs {len(lid_leaves)} leaves"
        )

    infos = []
    for i, (leaf, spec, lid) in enumerate(zip(leaves, spec_leaves, lid_leaves)):
        shape = tuple(int(s) for s in leaf.shape)
        layered = not isinstance(lid, int)
        local, sharded_dims, num_shards, pipe_stages = _local_leaf_geometry(
            shape, spec, mesh, roles, layered=layered
        )
        d_local = int(np.prod(local, dtype=np.int64)) if local else 1
        if layered:
            if not shape:
                raise ValueError(f"layered leaf {i} must have a layer axis")
            if sharded_dims and any(d == 0 for d, _, _ in sharded_dims):
                raise ValueError(
                    f"leaf {i}: the scanned layer axis cannot be sharded"
                )
            L = shape[0]
            p_vec = np.clip(
                layer_probability_array(mcfg.base_p, lid, tl, mcfg.schedule),
                0.0, 1.0,
            )
            d_rest = int(np.prod(shape[1:], dtype=np.int64)) if len(shape) > 1 else 1
            d_rest_local = (
                int(np.prod(local[1:], dtype=np.int64)) if len(local) > 1 else 1
            )
            counts_global = [int(round(float(p_vec[l]) * d_rest)) for l in range(L)]
            counts_local = tuple(c // num_shards for c in counts_global)
            if pipe_stages > 1:
                # per-stage budgets: each stage pools only its own layers'
                # counts and takes an independent floor — the paper's Eq. 6
                # schedule applied stage-locally, so the shuffle ring never
                # crosses a stage boundary
                bounds = stage_layer_bounds(L, pipe_stages)
                stage_k_per = tuple(
                    sum(
                        min(c, d_rest_local)
                        for c in counts_local[lo:hi] if c > 0
                    ) // n
                    for lo, hi in bounds
                )
                k_per = sum(stage_k_per)
                infos.append(LeafShardInfo(
                    index=i, member_shape=shape, local_shape=local,
                    sharded_dims=sharded_dims, num_shards=num_shards,
                    layered=True, counts_local=counts_local,
                    k_per_local=k_per, sel_local=k_per * n,
                    d_local=d_local, d_rest_local=d_rest_local,
                    stage_bounds=bounds, stage_k_per=stage_k_per,
                ))
                continue
            pooled = sum(
                min(c, d_rest_local) for c in counts_local if c > 0
            )
            k_per = pooled // n
            sel = k_per * n
            infos.append(LeafShardInfo(
                index=i, member_shape=shape, local_shape=local,
                sharded_dims=sharded_dims, num_shards=num_shards,
                layered=True, counts_local=counts_local, k_per_local=k_per,
                sel_local=sel, d_local=d_local, d_rest_local=d_rest_local,
            ))
            continue
        p_l = layer_probability(mcfg.base_p, int(lid), tl, mcfg.schedule)
        d_global = int(np.prod(shape, dtype=np.int64)) if shape else 1
        k_per_global = (
            shf.bucket_count(d_global, n, min(p_l, 1.0)) if p_l > 0.0 else 0
        )
        k_per_local = k_per_global // num_shards
        infos.append(LeafShardInfo(
            index=i, member_shape=shape, local_shape=local,
            sharded_dims=sharded_dims, num_shards=num_shards,
            layered=False, counts_local=None, k_per_local=k_per_local,
            sel_local=k_per_local * n, d_local=d_local, d_rest_local=0,
            stage=(
                stage_of_depth(int(lid), tl - 2, num_stages)
                if num_stages > 1 else 0
            ),
        ))

    sizes = {a: int(mesh.shape[a]) for a in mesh.axis_names}
    m = int(np.prod([sizes[a] for a in pop_axes]))
    if n % m:
        raise ValueError(
            f"population {n} must divide over pop axes {pop_axes} (size {m})"
        )
    return PopulationPlan(
        roles=roles,
        axis_sizes=tuple(sizes.items()),
        num_stages=num_stages,
        n=n, n_local=n // m,
        infos=tuple(infos), treedef=treedef, mcfg=mcfg,
    )


# ---------------------------------------------------------------------------
# traced pieces (run inside shard_map)
# ---------------------------------------------------------------------------


def _shard_position(info: LeafShardInfo, pplan: PopulationPlan):
    """Linearized coordinate of this chip over the axes sharding ``info``.

    Chips holding replicas of the same shard (axes absent from the leaf's
    spec) compute the same position, so replicated copies draw identical
    plans and stay consistent."""
    pos = jnp.zeros((), jnp.int32)
    for _, a, _ in info.sharded_dims:
        pos = pos * pplan.size(a) + lax.axis_index(a)
    return pos


def _stage_split_plan(k: jax.Array, info: LeafShardInfo, pplan: PopulationPlan):
    """One SPMD-uniform plan for a pipe-sharded blocks leaf.

    Every chip builds *all* stages' sub-plans (stage ``s`` from
    ``fold_in(k, s)``, indices in stage-local coordinates over that
    stage's layer slice of the counts) and concatenates them along the
    bucket dim, so the traced shapes agree across the mesh.  Columns
    owned by other stages are then masked to the sentinel ``d_local``
    (one past the local flat shard): JAX *clamps* out-of-range gathers
    (the read value is discarded by the matching dropped scatter) and
    *drops* out-of-range scatters, so masked columns move no data and
    :mod:`repro.core.shuffle` needs no pipe-awareness at all.
    """
    if info.member_shape[0] % len(info.stage_k_per):
        raise ValueError(
            f"stage-split plans need num_layers divisible by the stage "
            f"count; got {info.member_shape[0]} layers over "
            f"{len(info.stage_k_per)} stages (the planner's accounting "
            f"allows uneven stages, executing them does not)"
        )
    subs, stage_ids = [], []
    for s, (lo, hi) in enumerate(info.stage_bounds):
        if info.stage_k_per[s] == 0:
            continue
        sub = shf.bucketed_plan_layered(
            jax.random.fold_in(k, s), hi - lo, info.d_rest_local,
            pplan.n, None, counts=info.counts_local[lo:hi],
        )
        subs.append(sub)
        stage_ids.append(np.full((info.stage_k_per[s],), s, np.int32))
    if not subs:
        return None
    idx = jnp.concatenate(subs, axis=1)
    sid = jnp.asarray(np.concatenate(stage_ids))
    mine = sid[None, :] == lax.axis_index(pplan.pipe_axis)
    return jnp.where(mine, idx, jnp.int32(info.d_local))


def build_local_plans(key: jax.Array, pplan: PopulationPlan) -> PyTree:
    """Build this chip's bucketed plans (one per leaf, indices into the
    *local flat member shard*).  Must run inside ``shard_map`` when any
    leaf is sharded (the key fold reads ``axis_index``).  Stage-split
    leaves get the sentinel-masked concatenation of per-stage sub-plans
    (:func:`_stage_split_plan`); the stage is *not* folded into the plan
    key — all chips must agree on every stage's sub-plan so the masked
    columns line up."""
    plans = []
    for info in pplan.infos:
        if info is None or info.sel_local == 0:
            plans.append(None)
            continue
        k = jax.random.fold_in(key, info.index)
        if info.sharded_dims:
            k = jax.random.fold_in(k, _shard_position(info, pplan))
        if info.stage_split:
            plans.append(_stage_split_plan(k, info, pplan))
        elif info.layered:
            plans.append(shf.bucketed_plan_layered(
                k, len(info.counts_local), info.d_rest_local, pplan.n,
                None, counts=info.counts_local,
            ))
        else:
            plans.append(shf.bucketed_plan(
                k, info.d_local, pplan.n, 0.0, k_per=info.k_per_local
            ))
    return jax.tree_util.tree_unflatten(pplan.treedef, plans)


def all_gather_population(params: PyTree, pplan: PopulationPlan) -> PyTree:
    """Reconstruct full member leaves from model shards (tiled all-gather
    per sharded dim; bitwise — gathering moves values, it never computes).
    Leaves carry a leading local-population axis, so dim k of the member
    is axis k+1 of the leaf."""
    flat = jax.tree_util.tree_flatten(params)[0]
    out = []
    for info, leaf in zip(pplan.infos, flat):
        for dim, a, _ in info.sharded_dims:
            leaf = lax.all_gather(leaf, a, axis=dim + 1, tiled=True)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(pplan.treedef, out)


def shard_population(tree: PyTree, pplan: PopulationPlan) -> PyTree:
    """This chip's model-shard of full member leaves (inverse of
    :func:`all_gather_population`; an exact slice)."""
    flat = jax.tree_util.tree_flatten(tree)[0]
    out = []
    for info, leaf in zip(pplan.infos, flat):
        for dim, a, lsz in info.sharded_dims:
            leaf = lax.dynamic_slice_in_dim(
                leaf, lax.axis_index(a) * lsz, lsz, axis=dim + 1
            )
        out.append(leaf)
    return jax.tree_util.tree_unflatten(pplan.treedef, out)


def mix_collective_sharded(
    key: jax.Array,
    params: PyTree,
    opt_state: Optional[PyTree],
    cfg: MixingConfig,
    pplan: PopulationPlan,
    gate: Optional[jax.Array],
    use_pallas: bool = False,
) -> Tuple[PyTree, Optional[PyTree]]:
    """Shard-local mixing on a block of members under ``shard_map``.

    The multi-axis generalization of
    :func:`repro.core.mixing.mix_collective_blocked`: ``params`` leaves
    carry a leading local-population axis and hold each member's
    *model-shard*; WASH plans come from :func:`build_local_plans` and the
    bucket exchanges ``ppermute`` over ``pplan.pop_axes``; PAPA pulls
    ``pmean`` over the same axes (elementwise, so shard-local application
    is exact).  ``gate`` masks the result as in the blocked path (pass
    ``None`` for an ungated mixer).  Communication is accounted host-side
    via :func:`static_shard_mix_comm`, never here.
    """
    if cfg.kind == "none":
        return params, opt_state

    ax = pplan.pop_axes
    # the Pallas bucketed-shuffle kernel indexes without OOB masking, so
    # stage-split plans (sentinel columns) must take the lax path
    use_pallas = use_pallas and pplan.num_stages == 1

    def _gated(new_tree, old_tree):
        if gate is None:
            return new_tree
        return jax.tree_util.tree_map(
            lambda a, b: jnp.where(gate > 0, a, b), new_tree, old_tree
        )

    if cfg.kind in ("wash", "wash_opt"):
        plan = build_local_plans(key, pplan)
        new_params = shf.apply_plan_collective_blocked(
            plan, params, ax, use_pallas=use_pallas
        )
        new_opt = opt_state
        if cfg.shuffles_optimizer() and opt_state is not None:
            new_opt = dict(opt_state)
            for mk, mv in momentum_like_leaves(opt_state, params).items():
                new_opt[mk] = _gated(
                    shf.apply_plan_collective_blocked(
                        plan, mv, ax, use_pallas=use_pallas
                    ),
                    mv,
                )
        return _gated(new_params, params), new_opt

    if cfg.kind == "papa":
        pulled = jax.tree_util.tree_map(
            lambda x: cfg.papa_alpha * x
            + (1.0 - cfg.papa_alpha)
            * lax.pmean(jnp.mean(x, axis=0, keepdims=True), ax),
            params,
        )
        return _gated(pulled, params), opt_state

    if cfg.kind == "papa_all":
        avg = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                lax.pmean(jnp.mean(x, axis=0, keepdims=True), ax), x.shape
            ),
            params,
        )
        return _gated(avg, params), opt_state

    raise ValueError(f"unknown mixing kind {cfg.kind!r}")


# ---------------------------------------------------------------------------
# exact host-side communication accounting (paper Table 1, per shard)
# ---------------------------------------------------------------------------


def shard_leaf_volumes(pplan: PopulationPlan) -> Dict[int, Tuple[float, int]]:
    """Per-leaf ``{leaf_index: (scalars sent per member per shard, num_shards)}``
    for a WASH mixing step (bucket 0 is the identity: ``sel·(N-1)/N``)."""
    out = {}
    for info in pplan.infos:
        if info is None:
            continue
        sent = info.sel_local * (pplan.n - 1) / pplan.n
        out[info.index] = (float(sent), info.num_shards)
    return out


def _opt_replay_factor(pplan: PopulationPlan, opt_state) -> int:
    """1 + number of optimizer moment trees the WASH plan is replayed on."""
    if not (pplan.mcfg.shuffles_optimizer() and opt_state is not None):
        return 1
    member = jax.tree_util.tree_unflatten(
        pplan.treedef,
        [jax.ShapeDtypeStruct(i.member_shape, jnp.float32)
         for i in pplan.infos],
    )
    return 1 + len(momentum_like_leaves(opt_state, member))


def static_stage_mix_comm(
    pplan: PopulationPlan,
    stage: int,
    opt_state: Optional[PyTree] = None,
) -> float:
    """Exact scalars sent per member by pipeline stage ``stage`` on a
    mixing-due step, in host float64.

    Stage-split leaves contribute their own stage budget
    (``stage_k_per[stage]·n·(N-1)/N`` per model shard); every other leaf
    is attributed to its owner stage by depth
    (:func:`repro.core.layer_index.stage_of_depth`), so each scalar is
    counted exactly once and
    :func:`static_shard_mix_comm` can report the global volume as the
    literal sum over stages.
    """
    cfg = pplan.mcfg
    if cfg.kind == "none":
        return 0.0
    if stage < 0 or stage >= pplan.num_stages:
        raise ValueError(
            f"stage {stage} out of range for {pplan.num_stages} stages"
        )
    if cfg.kind in ("papa", "papa_all"):
        total = 0
        for info in pplan.infos:
            size = int(np.prod(info.member_shape, dtype=np.int64))
            if info.stage_split:
                lo, hi = info.stage_bounds[stage]
                total += (hi - lo) * (size // info.member_shape[0])
            elif info.stage == stage:
                total += size
        return float(total)
    comm = 0.0
    for info in pplan.infos:
        if info.stage_split:
            sel_s = info.stage_k_per[stage] * pplan.n
            comm += sel_s * (pplan.n - 1) / pplan.n * info.num_shards
        elif info.stage == stage:
            comm += info.sel_local * (pplan.n - 1) / pplan.n * info.num_shards
    return float(comm * _opt_replay_factor(pplan, opt_state))


def static_shard_mix_comm(
    pplan: PopulationPlan,
    opt_state: Optional[PyTree] = None,
) -> float:
    """Exact scalars sent per member on a mixing-due step, summed over the
    member's shards, in host float64 (the multi-axis counterpart of
    :func:`repro.core.mixing.static_mix_comm`; equal to it when no leaf is
    sharded).  Each chip sends ``sel_local·(N-1)/N`` per leaf; a member
    spans ``num_shards`` chips per leaf.  On a pipeline mesh the total is
    the *literal* sum of :func:`static_stage_mix_comm` over the stages, so
    the sum-equals-global contract holds to the last ulp."""
    cfg = pplan.mcfg
    if cfg.kind == "none":
        return 0.0
    if pplan.num_stages > 1:
        return float(sum(
            static_stage_mix_comm(pplan, s, opt_state=opt_state)
            for s in range(pplan.num_stages)
        ))
    if cfg.kind in ("papa", "papa_all"):
        return float(sum(
            int(np.prod(i.member_shape, dtype=np.int64)) for i in pplan.infos
        ))
    comm = sum(
        sent * num for sent, num in shard_leaf_volumes(pplan).values()
    )
    return float(comm * _opt_replay_factor(pplan, opt_state))


# ---------------------------------------------------------------------------
# standalone mixer (public API; repro.launch.dryrun delegates here)
# ---------------------------------------------------------------------------


def make_shardlocal_mixer(
    mesh,
    mcfg: MixingConfig,
    num_blocks: int,
    pop_specs: PyTree,
    opt_specs: PyTree,
):
    """§Perf: a ``shard_map``-wrapped shard-local WASH/PAPA mixing step.

    ``pop_specs`` are the stacked-population specs (leading entry = the
    population axes, remaining entries = the member sharding); member
    specs and the population axes are derived from them, so the caller
    keeps a single source of truth.  Member shapes and the population
    size are read off the population actually passed in (at trace time —
    the planner itself is pure host-side shape math), so one mixer
    factory serves any parameter tree matching ``pop_specs``.

    Returns ``mixer(pop, opt, key) -> (pop, opt, comm_total)``.
    ``comm_total`` is the static scalars-sent count summed over the whole
    population, host-computed (the old dry-run prototype double-counted
    data replicas by psumming a per-chip device scalar over every mesh
    axis — and folded the chip position into every leaf's plan key,
    silently desynchronizing replicas of unsharded leaves).  Because it
    rides the compiled graph it is returned as a float32 device scalar,
    which rounds past 2^24 scalars — callers that need the count exact
    should use :func:`static_shard_mix_comm` host-side, as the fused
    engine does.
    """
    from repro.core.compat import shard_map

    def _strip(spec):
        return P(*tuple(spec)[1:])

    member_specs = jax.tree_util.tree_map(
        _strip, pop_specs, is_leaf=lambda x: isinstance(x, P)
    )
    first = jax.tree_util.tree_flatten(
        pop_specs, is_leaf=lambda x: isinstance(x, P)
    )[0][0]
    lead = tuple(first)[0]
    pop_axes = (lead,) if isinstance(lead, str) else tuple(lead)
    m_pop = 1
    for a in pop_axes:
        m_pop *= int(mesh.shape[a])

    def _global_member_sds(pop_local):
        """Undo the spec slicing: global member shapes from local shards."""
        def one(leaf, spec):
            shape = list(leaf.shape[1:])
            for dim, e in enumerate(tuple(spec) if spec is not None else ()):
                if e is None:
                    continue
                for a in (e,) if isinstance(e, str) else tuple(e):
                    shape[dim] *= int(mesh.shape[a])
            return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)

        return jax.tree_util.tree_map(
            one, pop_local, member_specs,
            is_leaf=lambda x: hasattr(x, "shape"),
        )

    def mixer(pop_local, opt_local, key):
        member_tpl = _global_member_sds(pop_local)
        n = jax.tree_util.tree_leaves(pop_local)[0].shape[0] * m_pop
        lids = infer_layer_ids(member_tpl, num_blocks)
        pplan = plan_population_mixing(
            mesh, member_tpl, member_specs, mcfg, lids,
            total_layers(num_blocks), n, pop_axes=pop_axes, dp_axes=(),
        )
        comm = static_shard_mix_comm(pplan)
        if mcfg.shuffles_optimizer() and isinstance(opt_specs, dict):
            comm *= 1 + sum(1 for k in ("mu", "nu") if k in opt_specs)
        new_pop, new_opt = mix_collective_sharded(
            key, pop_local, opt_local, mcfg, pplan, gate=None
        )
        return new_pop, new_opt, jnp.asarray(n * comm, jnp.float32)

    return shard_map(
        mixer,
        mesh,
        in_specs=(pop_specs, opt_specs, P()),
        out_specs=(pop_specs, opt_specs, P()),
        check_vma=False,
    )
