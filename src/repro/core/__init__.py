"""Core WASH library: population shuffling, mixing strategies, soups."""

from repro.core.mixing import MixingConfig, mix_collective, mix_stacked
from repro.core.shuffle import (
    apply_plan_collective,
    apply_plan_stacked,
    make_plan,
)
from repro.core.averaging import (
    ensemble_accuracy,
    greedy_soup,
    uniform_soup,
)
from repro.core.consensus import (
    avg_distance_to_consensus,
    consensus,
    sq_distance_to_consensus,
)
from repro.core import population
from repro.core.shardplan import (
    make_shardlocal_mixer,
    mix_collective_sharded,
    plan_population_mixing,
    static_shard_mix_comm,
)

__all__ = [
    "MixingConfig",
    "mix_collective",
    "mix_stacked",
    "make_plan",
    "apply_plan_stacked",
    "apply_plan_collective",
    "uniform_soup",
    "greedy_soup",
    "ensemble_accuracy",
    "consensus",
    "sq_distance_to_consensus",
    "avg_distance_to_consensus",
    "population",
    "plan_population_mixing",
    "mix_collective_sharded",
    "make_shardlocal_mixer",
    "static_shard_mix_comm",
]
