"""Population mixing strategies.

After every optimizer step the training loop calls ``mix`` on the stacked
population (or, in the distributed path, each member calls the collective
variant under ``shard_map``).  Implemented strategies:

  none      independent training (paper's Baseline)
  wash      parameter shuffling (paper Alg. 1)
  wash_opt  WASH + the same shuffle replayed on the optimizer moments
  papa      EMA pull toward consensus every T steps (PAPA, Eq. 1)
  papa_all  hard averaging every T_all steps (PAPA-all == DART)

Communication volume (scalars sent per member per mixing step) feeds the
paper's Table 1.  The stacked entry points report it per call; the fused
collective path (:func:`mix_collective_blocked`) does not — bucketed plan
sizes are static, so both training engines account communication
host-side in exact float64 via :func:`static_mix_comm` instead of
carrying a float32 scalar through the jitted step (which truncates past
2^24 scalars).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import shuffle as shf
from repro.core.compat import axis_size
from repro.core.schedules import active_window

PyTree = Any


@dataclasses.dataclass(frozen=True)
class MixingConfig:
    kind: str = "wash"           # none | wash | wash_opt | papa | papa_all
    base_p: float = 0.001        # WASH base probability (first layer)
    schedule: str = "decreasing" # decreasing | constant | increasing (Eq. 6 / Tab. 4)
    mode: str = "dense"          # dense | bucketed (see core.shuffle)
    papa_alpha: float = 0.99     # PAPA EMA coefficient (Eq. 1)
    papa_every: int = 10         # PAPA all-reduce period T
    papa_all_every: int = 1000   # PAPA-all / DART averaging period
    start_step: int = 0          # Fig. 5b ablation window
    stop_step: Optional[int] = None
    pallas_shuffle: bool = False # bucketed applies via the fused Pallas kernel

    def shuffles_optimizer(self) -> bool:
        return self.kind == "wash_opt"


def momentum_like_leaves(opt_state: PyTree, params: PyTree) -> PyTree:
    """The slice of the optimizer state that WASH+Opt shuffles.

    Our optimizers (repro.optim) store moments in a dict with the same
    sub-structure as params under keys 'mu' (SGD/Adam first moment) and
    optionally 'nu'.  Anything else (step counters) is left alone.
    """
    return {k: opt_state[k] for k in ("mu", "nu") if k in opt_state}


def _wash_step_stacked(
    key, params, opt_state, cfg: MixingConfig, layer_ids, total_layers
) -> Tuple[PyTree, PyTree, jax.Array]:
    plan = shf.make_plan(
        key, params, layer_ids, total_layers, cfg.base_p, cfg.schedule, cfg.mode
    )
    n = jax.tree_util.tree_leaves(params)[0].shape[0]
    new_params = shf.apply_plan_stacked(
        plan, params, cfg.mode, use_pallas=cfg.pallas_shuffle
    )
    new_opt = opt_state
    comm = shf.plan_sent_scalars(plan, n, cfg.mode)
    if cfg.shuffles_optimizer() and opt_state is not None:
        moments = momentum_like_leaves(opt_state, params)
        new_opt = dict(opt_state)
        for mk, mv in moments.items():
            new_opt[mk] = shf.apply_plan_stacked(
                plan, mv, cfg.mode, use_pallas=cfg.pallas_shuffle
            )
            comm = comm + shf.plan_sent_scalars(plan, n, cfg.mode)
    return new_params, new_opt, comm


def _papa_pull_stacked(params: PyTree, alpha: float) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: alpha * x + (1.0 - alpha) * jnp.mean(x, axis=0, keepdims=True),
        params,
    )


def _average_stacked(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape),
        params,
    )


def mixing_due(step: int, cfg: MixingConfig) -> bool:
    """Python-side period/window test so jitted mixing is unconditional."""
    if cfg.kind == "none" or not active_window(step, cfg.start_step, cfg.stop_step):
        return False
    if cfg.kind in ("wash", "wash_opt"):
        return True
    if cfg.kind == "papa":
        return step > 0 and step % cfg.papa_every == 0
    if cfg.kind == "papa_all":
        return step > 0 and step % cfg.papa_all_every == 0
    raise ValueError(f"unknown mixing kind {cfg.kind!r}")


def mix_once(
    key: jax.Array,
    params: PyTree,
    opt_state: Optional[PyTree],
    cfg: MixingConfig,
    layer_ids: PyTree,
    total_layers: int,
) -> Tuple[PyTree, Optional[PyTree], jax.Array]:
    """Unconditionally apply the strategy's op (period logic lives in
    :func:`mixing_due`).  Safe to jit with cfg/layer_ids static."""
    zero = jnp.zeros((), jnp.float32)
    n = jax.tree_util.tree_leaves(params)[0].shape[0]
    d = sum(x.size // n for x in jax.tree_util.tree_leaves(params))
    if cfg.kind in ("wash", "wash_opt"):
        return _wash_step_stacked(key, params, opt_state, cfg, layer_ids, total_layers)
    if cfg.kind == "papa":
        return _papa_pull_stacked(params, cfg.papa_alpha), opt_state, zero + float(d)
    if cfg.kind == "papa_all":
        return _average_stacked(params), opt_state, zero + float(d)
    return params, opt_state, zero


def mix_stacked(
    step: int,
    key: jax.Array,
    params: PyTree,
    opt_state: Optional[PyTree],
    cfg: MixingConfig,
    layer_ids: PyTree,
    total_layers: int,
) -> Tuple[PyTree, Optional[PyTree], jax.Array]:
    """Apply the configured mixing strategy to a stacked population.

    ``step`` must be a Python int (the period/window tests are static so
    no-mix steps trace to a no-op instead of a masked collective).
    Delegates the period/window test to :func:`mixing_due` and the op to
    :func:`mix_once` so the three mixing entry points cannot drift.
    Returns (params, opt_state, scalars_sent_per_member).
    """
    if not mixing_due(step, cfg):
        return params, opt_state, jnp.zeros((), jnp.float32)
    return mix_once(key, params, opt_state, cfg, layer_ids, total_layers)


def static_mix_comm(
    member_params: PyTree,
    cfg: MixingConfig,
    layer_ids: PyTree,
    total_layers: int,
    n: int,
    opt_state: Optional[PyTree] = None,
) -> Optional[float]:
    """Exact scalars sent per member on a mixing-due step, computed
    host-side in float64.

    Bucketed plan sizes are a pure function of shapes/N/p (the key only
    picks *which* coordinates move), so the count never has to ride a
    float32 device computation — which truncates past 2^24 scalars, well
    below real model sizes.  Both training engines use this value for
    their ``comm`` accounting, accumulating per-step on the host.

    ``member_params`` may be arrays or ``jax.ShapeDtypeStruct`` templates
    (only shapes are read).  Returns ``None`` when the count is
    data-dependent (dense WASH draws Bernoulli masks on device); callers
    then fall back to the device-reported value.
    """
    import numpy as np

    if cfg.kind == "none":
        return 0.0
    if cfg.kind in ("papa", "papa_all"):
        d = sum(
            int(np.prod(l.shape, dtype=np.int64))
            for l in jax.tree_util.tree_leaves(member_params)
        )
        return float(d)
    if cfg.mode != "bucketed":
        return None
    plan_shapes = jax.eval_shape(lambda: shf.make_plan(
        jax.random.key(0), member_params, layer_ids, total_layers,
        cfg.base_p, cfg.schedule, mode="bucketed", n=n,
    ))
    sel = sum(
        int(np.prod(p.shape, dtype=np.int64))
        for p in jax.tree_util.tree_leaves(
            plan_shapes, is_leaf=lambda x: x is None
        )
        if p is not None
    )
    comm = sel * (n - 1) / n
    if cfg.shuffles_optimizer() and opt_state is not None:
        comm = comm * (1 + len(momentum_like_leaves(opt_state, member_params)))
    return comm


# ---------------------------------------------------------------------------
# collective variants (one member per shard_map instance, ens as mesh axis)
# ---------------------------------------------------------------------------


def mix_collective(
    step: int,
    key: jax.Array,
    params: PyTree,
    opt_state: Optional[PyTree],
    cfg: MixingConfig,
    layer_ids: PyTree,
    total_layers: int,
    axis_name: str,
) -> Tuple[PyTree, Optional[PyTree], jax.Array]:
    """Distributed mixing: called per member under shard_map(axis_name=ens).

    WASH uses the bucketed plan (built from the *shared* key, so every
    member computes identical indices) and ``ppermute`` exchanges; PAPA
    uses ``pmean`` (all-reduce).
    """
    zero = jnp.zeros((), jnp.float32)
    if cfg.kind == "none" or not active_window(step, cfg.start_step, cfg.stop_step):
        return params, opt_state, zero

    n = axis_size(axis_name)
    d = sum(x.size for x in jax.tree_util.tree_leaves(params))

    if cfg.kind in ("wash", "wash_opt"):
        plan = shf.make_plan(
            key, params, layer_ids, total_layers, cfg.base_p, cfg.schedule,
            mode="bucketed", n=n,
        )
        new_params = shf.apply_plan_collective(plan, params, axis_name)
        new_opt = opt_state
        comm = shf.plan_sent_scalars(plan, n, mode="bucketed")
        if cfg.shuffles_optimizer() and opt_state is not None:
            new_opt = dict(opt_state)
            for mk, mv in momentum_like_leaves(opt_state, params).items():
                new_opt[mk] = shf.apply_plan_collective(plan, mv, axis_name)
                comm = comm + shf.plan_sent_scalars(plan, n, mode="bucketed")
        return new_params, new_opt, zero + comm

    if cfg.kind == "papa" and step % cfg.papa_every == 0 and step > 0:
        pulled = jax.tree_util.tree_map(
            lambda x: cfg.papa_alpha * x
            + (1.0 - cfg.papa_alpha) * lax.pmean(x, axis_name),
            params,
        )
        return pulled, opt_state, zero + float(d)

    if cfg.kind == "papa_all" and step % cfg.papa_all_every == 0 and step > 0:
        avg = jax.tree_util.tree_map(lambda x: lax.pmean(x, axis_name), params)
        return avg, opt_state, zero + float(d)

    return params, opt_state, zero


def mix_collective_blocked(
    key: jax.Array,
    params: PyTree,
    opt_state: Optional[PyTree],
    cfg: MixingConfig,
    layer_ids: PyTree,
    total_layers: int,
    axis_name: str,
    gate: jax.Array,
    use_pallas: bool = False,
) -> Tuple[PyTree, Optional[PyTree]]:
    """Fused-engine mixing on a *block* of members under shard_map.

    ``params`` leaves carry a leading local-ens axis (n_local members per
    shard; global population n = n_local * axis_size, so the same code
    serves one-member-per-device TPU meshes and the 1-device CPU fallback).

    ``gate`` is a traced {0,1} scalar — the Python-side :func:`mixing_due`
    result for this step, threaded through ``lax.scan`` — so the collective
    always executes with static shapes and the result is masked.  The WASH
    plan is built once from the shared key and replayed on the optimizer
    moments (WASH+Opt), exactly as in the stacked reference.

    Communication is NOT accounted here: plan sizes are static, so the
    host computes the exact float64 count via :func:`static_mix_comm`
    instead of carrying a float32 scalar through ``lax.scan`` (which
    silently truncates past 2^24 scalars per step).
    """
    if cfg.kind == "none":
        return params, opt_state

    n_local = jax.tree_util.tree_leaves(params)[0].shape[0]
    n = n_local * axis_size(axis_name)

    def _gated(new_tree, old_tree):
        return jax.tree_util.tree_map(
            lambda a, b: jnp.where(gate > 0, a, b), new_tree, old_tree
        )

    if cfg.kind in ("wash", "wash_opt"):
        member = jax.tree_util.tree_map(lambda x: x[0], params)
        plan = shf.make_plan(
            key, member, layer_ids, total_layers, cfg.base_p, cfg.schedule,
            mode="bucketed", n=n,
        )
        new_params = shf.apply_plan_collective_blocked(
            plan, params, axis_name, use_pallas=use_pallas
        )
        new_opt = opt_state
        if cfg.shuffles_optimizer() and opt_state is not None:
            new_opt = dict(opt_state)
            for mk, mv in momentum_like_leaves(opt_state, params).items():
                new_opt[mk] = _gated(
                    shf.apply_plan_collective_blocked(
                        plan, mv, axis_name, use_pallas=use_pallas
                    ),
                    mv,
                )
        return _gated(new_params, params), new_opt

    if cfg.kind == "papa":
        pulled = jax.tree_util.tree_map(
            lambda x: cfg.papa_alpha * x
            + (1.0 - cfg.papa_alpha)
            * lax.pmean(jnp.mean(x, axis=0, keepdims=True), axis_name),
            params,
        )
        return _gated(pulled, params), opt_state

    if cfg.kind == "papa_all":
        avg = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                lax.pmean(jnp.mean(x, axis=0, keepdims=True), axis_name), x.shape
            ),
            params,
        )
        return _gated(avg, params), opt_state

    raise ValueError(f"unknown mixing kind {cfg.kind!r}")
