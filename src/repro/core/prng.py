"""Shared-randomness discipline for population training.

WASH requires every member of the population to agree on (a) which
coordinates are shuffled this step and (b) the permutation applied to each
coordinate.  We derive everything from a *shared* base key folded with the
step index, then fold in a stable per-leaf index.  In the distributed
(`shard_map`) path every member computes the same plan locally from the same
key — zero extra communication for coordination, exactly like the paper's
"a permutation is randomly chosen" with a synchronized seed.
"""

from __future__ import annotations

import jax


def step_key(base_key: jax.Array, step) -> jax.Array:
    """Key shared by all members for a given training step."""
    return jax.random.fold_in(base_key, step)


def leaf_key(key: jax.Array, leaf_index: int) -> jax.Array:
    """Per-leaf key derived from the shared step key."""
    return jax.random.fold_in(key, leaf_index)


def member_keys(key: jax.Array, n: int) -> jax.Array:
    """Independent keys per member (for data order / augmentations)."""
    return jax.random.split(key, n)
