"""Consensus (averaged-model) distance metrics — paper Fig. 2 / Eq. 2 / Eq. 5."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def consensus(population: PyTree) -> PyTree:
    """θ̄ = mean over the ens axis."""
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), population)


def sq_distance_to_consensus(population: PyTree) -> jax.Array:
    """Σ_n ‖θ_n − θ̄‖² — the exact quantity preserved by Eq. (5)."""
    total = jnp.zeros((), jnp.float32)
    for x in jax.tree_util.tree_leaves(population):
        xc = x.astype(jnp.float32)
        mean = jnp.mean(xc, axis=0, keepdims=True)
        total = total + jnp.sum((xc - mean) ** 2)
    return total


def avg_distance_to_consensus(population: PyTree) -> jax.Array:
    """(1/N) Σ_n ‖θ_n − θ̄‖ — the Fig. 2 trace."""
    leaves = jax.tree_util.tree_leaves(population)
    n = leaves[0].shape[0]
    per_member = jnp.zeros((n,), jnp.float32)
    for x in leaves:
        xc = x.astype(jnp.float32).reshape(n, -1)
        mean = jnp.mean(xc, axis=0, keepdims=True)
        per_member = per_member + jnp.sum((xc - mean) ** 2, axis=1)
    return jnp.mean(jnp.sqrt(per_member))


def pairwise_distance(population: PyTree) -> jax.Array:
    """Mean pairwise L2 distance between members (diversity diagnostic)."""
    leaves = jax.tree_util.tree_leaves(population)
    n = leaves[0].shape[0]
    sq = jnp.zeros((n, n), jnp.float32)
    for x in leaves:
        xc = x.astype(jnp.float32).reshape(n, -1)
        sq = sq + jnp.sum((xc[:, None] - xc[None]) ** 2, axis=-1)
    dist = jnp.sqrt(sq)
    mask = 1.0 - jnp.eye(n)
    return jnp.sum(dist * mask) / jnp.maximum(jnp.sum(mask), 1.0)
