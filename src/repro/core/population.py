"""Stacked-pytree populations.

A *population* of N models is represented as a single pytree whose every
leaf carries a leading ``ens`` axis of size N.  This representation works
unchanged whether the ens axis is

  * vmapped on a single host (faithful-reference mode),
  * sharded over a dedicated ``ens`` mesh axis, or
  * sharded over the ``pod`` axis of the production multi-pod mesh.
"""

from __future__ import annotations

from typing import Any, Callable, List

import jax
import jax.numpy as jnp

PyTree = Any


def population_size(population: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(population)
    if not leaves:
        raise ValueError("empty population pytree")
    return int(leaves[0].shape[0])


def stack(members: List[PyTree]) -> PyTree:
    """Stack a list of per-member pytrees into one stacked pytree."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *members)


def unstack(population: PyTree) -> List[PyTree]:
    n = population_size(population)
    return [jax.tree_util.tree_map(lambda x: x[i], population) for i in range(n)]


def member(population: PyTree, i) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x[i], population)


def host_gather(leaf):
    """Fetch a leaf to host memory iff it spans multiple devices.

    The one shared predicate for "can this leaf be consumed single-host
    as-is?" — ``np.asarray`` on a non-fully-addressable sharded array
    either errors or triggers an implicit cross-device transfer, so
    multi-device leaves are assembled explicitly via ``jax.device_get``.
    Used by checkpointing (``train.checkpoint``) and serving
    (``serving.engine``); keep them on this helper so they cannot drift.
    """
    devs = getattr(getattr(leaf, "sharding", None), "device_set", None)
    if devs is not None and len(devs) > 1:
        return jax.device_get(leaf)
    return leaf


def replicate(params: PyTree, n: int) -> PyTree:
    """Same-initialization population (the paper's default for WASH)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), params
    )


def init_population(
    init_fn: Callable[[jax.Array], PyTree],
    key: jax.Array,
    n: int,
    same_init: bool = True,
) -> PyTree:
    """Initialize a population.

    ``same_init=True`` follows WASH (all members start at θ0); ``False``
    follows PAPA's setup (independent initializations).
    """
    if same_init:
        return replicate(init_fn(key), n)
    keys = jax.random.split(key, n)
    return stack([init_fn(k) for k in keys])


def map_members(fn: Callable, population: PyTree, *rest) -> PyTree:
    """vmap a per-member function over the ens axis."""
    return jax.vmap(fn)(population, *rest)


def num_params(params: PyTree) -> int:
    """Total scalar count of a single member (population leaves: drop axis 0)."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
