"""Per-leaf layer indices for the layer-wise probability schedule (Eq. 6).

The paper assigns each parameter a depth l in [0, L-1]; the shuffle
probability is p_l = p * (1 - l/(L-1)): the first layer shuffles with the
base probability, the last layer never shuffles.

Convention used by every model in ``repro.models``:

  * token/patch/frame embeddings            -> depth 0
  * transformer block i (or conv stage i)   -> depth i + 1
  * final norm / lm head / classifier head  -> depth L_total - 1

We infer depths from pytree paths: a leaf whose path contains the dict key
``blocks`` (or ``enc_blocks``/``dec_blocks``) followed by a sequence index i
gets depth i+1; paths containing ``embed`` get 0; everything else gets the
maximum depth.  Models with unusual structure can provide explicit overrides.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

_BLOCK_KEYS = ("blocks", "enc_blocks", "dec_blocks", "stages")
_EMBED_RE = re.compile(r"(embed|patch_proj|frame_proj|conv_in|tok_)")


def _path_entries(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(int(p.idx))
        else:  # pragma: no cover - defensive
            out.append(str(p))
    return out


def leaf_depth(path, num_blocks: int) -> int:
    """Depth in [0, L-1] with L = num_blocks + 2 (embed + blocks + head)."""
    entries = _path_entries(path)
    l_total = num_blocks + 2
    for i, e in enumerate(entries):
        if isinstance(e, str) and e in _BLOCK_KEYS:
            nxt = entries[i + 1] if i + 1 < len(entries) else None
            if isinstance(nxt, int):
                return min(nxt + 1, l_total - 1)
            m = re.search(r"(\d+)$", str(nxt)) if nxt is not None else None
            if m:
                return min(int(m.group(1)) + 1, l_total - 1)
    joined = "/".join(str(e) for e in entries).lower()
    if _EMBED_RE.search(joined):
        return 0
    return l_total - 1


def _is_scanned_blocks(path, leaf, num_blocks: int) -> bool:
    """True for stacked-block leaves: path hits a block key with no
    per-layer sequence index, and the leading dim equals num_blocks."""
    entries = _path_entries(path)
    for i, e in enumerate(entries):
        if isinstance(e, str) and e in _BLOCK_KEYS:
            nxt = entries[i + 1] if i + 1 < len(entries) else None
            if not isinstance(nxt, int):
                return hasattr(leaf, "shape") and leaf.shape and leaf.shape[0] == num_blocks
    return False


def infer_layer_ids(params: PyTree, num_blocks: int) -> PyTree:
    """Pytree (same structure as params) of depths.

    Leaves are ints, except stacked-block leaves (scanned models: one leaf
    spans all blocks along axis 0) which get an np.arange depth vector so
    the Eq. 6 schedule stays per-layer exact.
    """
    import numpy as np

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    depths = []
    for path, leaf in flat:
        if _is_scanned_blocks(path, leaf, num_blocks):
            depths.append(np.arange(1, num_blocks + 1))
        else:
            depths.append(leaf_depth(path, num_blocks))
    return jax.tree_util.tree_unflatten(treedef, depths)


def total_layers(num_blocks: int) -> int:
    return num_blocks + 2


def stage_layer_bounds(num_blocks: int, num_stages: int):
    """Contiguous ``[lo, hi)`` block ranges per pipeline stage.

    Near-even split: stage ``s`` owns blocks ``[s*L//S, (s+1)*L//S)``, so
    uneven layer counts (kimi's 61 blocks over 8 stages) stay legal for the
    planner's per-stage accounting; the training/serving engines additionally
    require ``L % S == 0`` so stage shards share one shape.
    """
    if num_stages < 1:
        raise ValueError(f"num_stages must be >= 1, got {num_stages}")
    return tuple(
        (s * num_blocks // num_stages, (s + 1) * num_blocks // num_stages)
        for s in range(num_stages)
    )


def stage_of_depth(depth: int, num_blocks: int, num_stages: int) -> int:
    """Owner stage of a leaf by its depth index (see :func:`leaf_depth`).

    Depth 0 (embeddings) lives on stage 0; depth ``num_blocks + 1`` (final
    norm / head) on the last stage; block ``b`` (depth ``b + 1``) on the
    stage whose :func:`stage_layer_bounds` range contains it.
    """
    if depth <= 0:
        return 0
    if depth >= num_blocks + 1:
        return num_stages - 1
    b = depth - 1
    for s, (lo, hi) in enumerate(stage_layer_bounds(num_blocks, num_stages)):
        if lo <= b < hi:
            return s
    return num_stages - 1  # pragma: no cover - bounds always tile [0, L)


def depth_histogram(params: PyTree, num_blocks: int) -> dict:
    """Diagnostic: scalar count per depth (used by comm-volume accounting)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    hist: dict[int, int] = {}
    for path, leaf in flat:
        d = leaf_depth(path, num_blocks)
        size = int(jnp.size(leaf))
        hist[d] = hist.get(d, 0) + size
    return hist
