"""Metrics primitives: counters, gauges, fixed-bucket histograms, and the
exact-percentile utilities the serving SLO summaries are built on.

Design rules (they are what make the subsystem safe to leave on):

  * **Host-side only.**  Nothing here touches jax — observing a metric is
    a few dict/float operations, so instrumentation can run inside the
    engines' dispatch loops without perturbing what they compile or
    compute (the inertness contract ``tests/test_obs_parity.py`` holds).
  * **Fixed bucket edges.**  Histograms bucket into edges chosen at
    construction, so percentile estimates are deterministic functions of
    the observed multiset — two runs that observe the same values report
    the same p99, and merging shards of a histogram is associative on
    everything percentiles read (counts/min/max; the float ``sum`` is
    associative only to rounding, which ``merge`` documents).
  * **Monotone counters, last-write gauges.**  ``Counter.inc`` accepts
    only non-negative increments and *returns the accumulated value*, so
    a caller that mirrors an exact host-side accumulation (the train
    engine's float64 comm total) gets bit-identical totals — same adds,
    same order.

The exact (non-bucketed) :func:`percentile` is what
``serving.driver.summarize`` uses: raw-sample percentiles with the
degenerate cases (empty, single sample, ``None`` holes) guarded here once
instead of at every call site.
"""

from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry",
    "DEFAULT_TIME_EDGES", "RATIO_EDGES",
    "percentile", "percentile_ms", "summarize_samples",
]

#: log-spaced wall-time bucket edges (seconds), 100 us .. 500 s — wide
#: enough for a CPU bench tick and a full training run alike.
DEFAULT_TIME_EDGES: Tuple[float, ...] = tuple(
    round(m * 10.0 ** e, 10) for e in range(-4, 3) for m in (1.0, 2.5, 5.0)
)

#: linear edges for occupancy/ratio metrics in [0, 1].
RATIO_EDGES: Tuple[float, ...] = tuple(round(i / 10.0, 10) for i in range(11))


class Counter:
    """Monotone accumulator.  ``inc`` returns the post-increment total so
    exact host-side accumulations can be mirrored add-for-add."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: Optional[threading.RLock] = None):
        self.name = name
        self.value = 0.0
        self._lock = lock or threading.RLock()

    def inc(self, n: float = 1.0) -> float:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._lock:
            self.value += n
            return self.value

    def snapshot(self) -> Dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written value (occupancies, pool levels, rates)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: Optional[threading.RLock] = None):
        self.name = name
        self.value: Optional[float] = None
        self._lock = lock or threading.RLock()

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def snapshot(self) -> Dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram with deterministic percentiles.

    Bucket ``i < len(edges)`` counts observations ``v <= edges[i]``
    (with ``v > edges[i-1]`` for ``i > 0``); the final bucket is the
    overflow.  ``percentile`` walks the cumulative counts to the target
    rank and reports that bucket's upper edge clamped to the observed
    max (the overflow bucket reports the max itself) — a deterministic
    upper bound on the nearest-rank sample percentile that two
    differently sharded runs agree on after :meth:`merge`.
    """

    __slots__ = ("name", "edges", "counts", "count", "sum", "min", "max",
                 "_lock")

    def __init__(self, name: str,
                 edges: Sequence[float] = DEFAULT_TIME_EDGES,
                 lock: Optional[threading.RLock] = None):
        edges = tuple(float(e) for e in edges)
        if not edges or any(b <= a for a, b in zip(edges, edges[1:])):
            raise ValueError(
                f"histogram {name}: edges must be non-empty and strictly "
                f"increasing, got {edges!r}")
        self.name = name
        self.edges = edges
        self.counts: List[int] = [0] * (len(edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._lock = lock or threading.RLock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[bisect.bisect_left(self.edges, v)] += 1
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def percentile(self, q: float) -> Optional[float]:
        """Deterministic q-th percentile bound (q in [0, 100]); None when
        empty.  For a single sample every q returns that sample."""
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q={q} outside [0, 100]")
        with self._lock:
            if self.count == 0:
                return None
            # rank 1..count (ceil of q% of count); q=0 reads the first sample
            rank = max(1, min(self.count, int(-(-q * self.count // 100))))
            cum = 0
            for i, c in enumerate(self.counts):
                cum += c
                if cum >= rank:
                    if i < len(self.edges):
                        return min(self.edges[i], self.max)
                    return self.max
            return self.max  # unreachable: counts sum to count

    def merge(self, other: "Histogram") -> "Histogram":
        """New histogram holding both sides' observations.  Associative on
        counts/count/min/max (ints and order-free extrema); ``sum`` is a
        float add, associative only to rounding."""
        if other.edges != self.edges:
            raise ValueError(
                f"cannot merge histograms with different edges "
                f"({self.name}: {len(self.edges)}, "
                f"{other.name}: {len(other.edges)})")
        out = Histogram(self.name, self.edges)
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.count = self.count + other.count
        out.sum = self.sum + other.sum
        mins = [m for m in (self.min, other.min) if m is not None]
        maxs = [m for m in (self.max, other.max) if m is not None]
        out.min = min(mins) if mins else None
        out.max = max(maxs) if maxs else None
        return out

    def snapshot(self) -> Dict:
        with self._lock:
            return {"type": "histogram", "count": self.count,
                    "sum": self.sum, "min": self.min, "max": self.max,
                    "edges": list(self.edges), "counts": list(self.counts),
                    "p50": self.percentile(50), "p99": self.percentile(99)}


class Registry:
    """Name -> metric map with get-or-create accessors.

    One registry per telemetry instance; creation and all metric writes
    share one re-entrant lock, so the staging thread, the driver pump
    thread, and the main loop can all report concurrently."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.RLock()

    def _get(self, name: str, cls, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name, self._lock))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name, self._lock))

    def histogram(self, name: str,
                  edges: Sequence[float] = DEFAULT_TIME_EDGES) -> Histogram:
        return self._get(name, Histogram,
                         lambda: Histogram(name, edges, self._lock))

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str):
        return self._metrics.get(name)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> Dict[str, Dict]:
        """{name: metric snapshot dict} for every registered metric."""
        with self._lock:
            return {name: m.snapshot()
                    for name, m in sorted(self._metrics.items())}

    def prometheus_text(self) -> str:
        """Prometheus text-exposition snapshot (names dot->underscore,
        histograms in cumulative ``le`` form)."""
        lines: List[str] = []
        for name, snap in self.snapshot().items():
            pname = name.replace(".", "_").replace("-", "_")
            kind = snap["type"]
            lines.append(f"# TYPE {pname} {kind}")
            if kind == "histogram":
                cum = 0
                for edge, c in zip(snap["edges"], snap["counts"]):
                    cum += c
                    lines.append(f'{pname}_bucket{{le="{edge:g}"}} {cum}')
                lines.append(
                    f'{pname}_bucket{{le="+Inf"}} {snap["count"]}')
                lines.append(f"{pname}_sum {snap['sum']:g}")
                lines.append(f"{pname}_count {snap['count']}")
            else:
                v = snap["value"]
                lines.append(f"{pname} {'NaN' if v is None else f'{v:g}'}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# exact raw-sample percentiles (the SLO-summary path)
# ---------------------------------------------------------------------------


def percentile(values: Iterable[Optional[float]], q: float
               ) -> Optional[float]:
    """Exact linear-interpolation percentile over raw samples.

    Guards the degenerate cases the serving summaries hit: ``None``
    entries are dropped (unfinished requests), an empty sample set
    returns ``None`` instead of raising, and a single sample answers
    every q with itself.  Matches ``numpy.percentile``'s default
    (linear) interpolation bit-for-bit so the migration off the old
    ad-hoc ``np.percentile`` calls changed no reported number."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    vals = sorted(float(v) for v in values if v is not None)
    if not vals:
        return None
    if len(vals) == 1:
        return vals[0]
    pos = q / 100.0 * (len(vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


def percentile_ms(values: Iterable[Optional[float]], q: float
                  ) -> Optional[float]:
    """:func:`percentile` over seconds, reported in milliseconds."""
    p = percentile(values, q)
    return None if p is None else p * 1e3


def summarize_samples(values: Iterable[Optional[float]]) -> Dict:
    """{count, mean, p50, p99, min, max} over raw samples, all None-safe."""
    vals = [float(v) for v in values if v is not None]
    if not vals:
        return {"count": 0, "mean": None, "p50": None, "p99": None,
                "min": None, "max": None}
    return {"count": len(vals), "mean": sum(vals) / len(vals),
            "p50": percentile(vals, 50), "p99": percentile(vals, 99),
            "min": min(vals), "max": max(vals)}
