"""Telemetry core: sinks, spans, structured events, compile-event hooks.

A :class:`Telemetry` owns one :class:`~repro.obs.metrics.Registry` and a
list of sinks.  Instrumentation points in the engines talk to the
module-level default instance (``repro.obs.get()``); launchers call
:func:`configure` once to attach sinks from CLI flags.  With no sinks
attached the hot-path cost of a span is two ``time.perf_counter`` calls
and a histogram observe — and the *outputs* of instrumented code are
identical either way, because every hook here is a host-side Python
effect (see ``tests/test_obs_parity.py``).

Event stream schema (one JSON object per line, validated by
``tools/check_metrics_schema.py``):

  line 1           ``{"kind": "provenance", "jax_version": ..., ...}``
  span             ``{"kind": "span", "name", "ts", "dur_s", ...attrs}``
  event            ``{"kind": "event", "name", "ts", ...attrs}``
  compile          ``{"kind": "compile", "name", "ts", ...attrs}``
  metric snapshot  ``{"kind": "metric", "name", "ts", ...snapshot}``
                   (one per registered metric, emitted by ``finalize``)
"""

from __future__ import annotations

import contextlib
import io
import json
import os
import threading
import time
from typing import Dict, List, Optional

from .metrics import Registry, DEFAULT_TIME_EDGES
from .profiler import ProfileWindow

__all__ = [
    "Telemetry", "JsonlSink", "MemorySink", "ConsoleSink",
    "configure", "get", "reset", "provenance",
]


def provenance() -> Dict:
    """Environment fingerprint stamped on every event stream and bench
    JSON payload: enough to interpret a timing without the shell that
    produced it."""
    info: Dict = {"kind": "provenance",
                  "ts": time.time(),
                  "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z")}
    try:
        import jax

        dev = jax.devices()[0]
        info.update(jax_version=jax.__version__,
                    backend=jax.default_backend(),
                    device_kind=dev.device_kind,
                    device_count=jax.device_count(),
                    platform=dev.platform)
    except Exception:  # jax absent or not initialisable: still stamp time
        info.update(jax_version=None, backend=None, device_kind=None,
                    device_count=None, platform=None)
    return info


class JsonlSink:
    """Appends one JSON object per line; writes the provenance record
    first so a stream is self-describing from byte 0."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._fh: Optional[io.TextIOBase] = open(path, "w")
        self.emit(provenance())

    def emit(self, record: Dict) -> None:
        if self._fh is not None:
            self._fh.write(json.dumps(record) + "\n")

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class MemorySink:
    """Collects records in a list — the test-suite sink."""

    def __init__(self):
        self.records: List[Dict] = []
        self.emit(provenance())

    def emit(self, record: Dict) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass

    def named(self, name: str) -> List[Dict]:
        return [r for r in self.records if r.get("name") == name]


class ConsoleSink:
    """Silent during the run; prints a compact metric summary at close so
    CLI output stays readable (events would drown the training log)."""

    def __init__(self, registry: Registry):
        self._registry = registry

    def emit(self, record: Dict) -> None:
        pass

    def close(self) -> None:
        snap = self._registry.snapshot()
        if not snap:
            return
        print("-- telemetry summary --")
        for name, s in snap.items():
            if s["type"] == "histogram":
                if s["count"]:
                    print(f"  {name}: n={s['count']} mean="
                          f"{s['sum'] / s['count']:.6g} p50={s['p50']:.6g} "
                          f"p99={s['p99']:.6g} max={s['max']:.6g}")
            else:
                v = s["value"]
                if v is not None:
                    print(f"  {name}: {v:.6g}" if isinstance(v, float)
                          else f"  {name}: {v}")


class Telemetry:
    """Registry + sinks + optional profiler window.

    ``enabled=False`` short-circuits every hook to a no-op — the switch
    the serving_bench overhead row flips to measure instrumentation
    cost.  All sink writes happen under one lock: the train staging
    thread and the driver pump thread report concurrently."""

    def __init__(self):
        self.registry = Registry()
        self.enabled = True
        self._sinks: List = []
        self._lock = threading.Lock()
        self._profile: Optional[ProfileWindow] = None

    # -- configuration ----------------------------------------------------

    def add_sink(self, sink) -> None:
        with self._lock:
            self._sinks.append(sink)

    def set_profile(self, window: Optional[ProfileWindow]) -> None:
        self._profile = window

    def reset(self) -> None:
        """Drop sinks, metrics, and the profile window (tests; between
        bench rows)."""
        with self._lock:
            for s in self._sinks:
                s.close()
            self._sinks = []
        if self._profile is not None:
            self._profile.stop()
            self._profile = None
        self.registry.reset()
        self.enabled = True

    # -- emission ---------------------------------------------------------

    def _emit(self, record: Dict) -> None:
        with self._lock:
            for s in self._sinks:
                s.emit(record)

    def event(self, name: str, **attrs) -> None:
        """Structured point-in-time event (record boundaries, comm-volume
        checkpoints)."""
        if not self.enabled:
            return
        if self._sinks:
            self._emit({"kind": "event", "name": name, "ts": time.time(),
                        **attrs})

    def record_compile(self, kind: str, **attrs) -> None:
        """Called from *inside* traced function bodies, right next to the
        engines' ``_*_TRACES`` bumps: runs at trace time only, so the
        counter value equals the executable count."""
        if not self.enabled:
            return
        self.registry.counter(f"compile.{kind}").inc()
        if self._sinks:
            self._emit({"kind": "compile", "name": f"compile.{kind}",
                        "ts": time.time(), **attrs})

    @contextlib.contextmanager
    def span(self, name: str, edges=DEFAULT_TIME_EDGES, **attrs):
        """Time a host-side region into ``registry.histogram(name)`` and
        (with sinks) the event stream.  Never adds a device sync: for
        regions that dispatch async jax work this measures dispatch wall
        time, which is exactly what the engines' own timers measured.
        Under an active ``--profile-dir`` window the region is also
        wrapped in a ``jax.profiler`` trace annotation."""
        if not self.enabled:
            yield
            return
        prof = self._profile
        ann = prof.annotation(name) if prof is not None else None
        t0 = time.perf_counter()
        try:
            if ann is not None:
                with ann:
                    yield
            else:
                yield
        finally:
            dur = time.perf_counter() - t0
            self.registry.histogram(name, edges).observe(dur)
            if self._sinks:
                self._emit({"kind": "span", "name": name,
                            "ts": time.time(), "dur_s": dur, **attrs})
            if prof is not None:
                prof.tick()

    # -- shutdown ---------------------------------------------------------

    def finalize(self) -> None:
        """Emit a metric-snapshot line per registered metric, then close
        every sink (idempotent)."""
        if self._profile is not None:
            self._profile.stop()
            self._profile = None
        now = time.time()
        for name, snap in self.registry.snapshot().items():
            self._emit({"kind": "metric", "name": name, "ts": now, **snap})
        with self._lock:
            for s in self._sinks:
                s.close()
            self._sinks = []


_default = Telemetry()


def get() -> Telemetry:
    """The process-wide telemetry instance the engines report to."""
    return _default


def reset() -> None:
    """Reset the default instance to pristine (no sinks, empty registry,
    enabled)."""
    _default.reset()


def configure(jsonl: Optional[str] = None,
              memory: bool = False,
              console: bool = False,
              profile_dir: Optional[str] = None,
              profile_spans: int = 64,
              reset_first: bool = True) -> Telemetry:
    """One-call launcher setup: attach the requested sinks (and profiler
    window) to the default telemetry and return it.  Returns the
    MemorySink-bearing instance either way; callers that passed
    ``memory=True`` find it as the last sink."""
    tel = _default
    if reset_first:
        tel.reset()
    if jsonl:
        tel.add_sink(JsonlSink(jsonl))
    if console:
        tel.add_sink(ConsoleSink(tel.registry))
    if memory:
        tel.add_sink(MemorySink())
    if profile_dir:
        tel.set_profile(ProfileWindow(profile_dir, max_spans=profile_spans))
    return tel
