"""Bounded jax.profiler capture window for ``--profile-dir``.

The window starts a ``jax.profiler`` trace on construction and stops it
after ``max_spans`` instrumented spans have passed through — an
unconditional bound so a long training run can't fill the disk with
profile data.  Everything is wrapped defensively: if the profiler
backend is unavailable (some CPU wheels, already-active trace), the
window degrades to a no-op instead of failing the run.
"""

from __future__ import annotations

import threading
from typing import Optional

__all__ = ["ProfileWindow"]


class ProfileWindow:
    def __init__(self, logdir: str, max_spans: int = 64):
        self.logdir = logdir
        self.max_spans = max_spans
        self._spans = 0
        self._active = False
        self._lock = threading.Lock()
        try:
            import jax

            jax.profiler.start_trace(logdir)
            self._active = True
        except Exception:
            self._active = False

    @property
    def active(self) -> bool:
        return self._active

    def annotation(self, name: str):
        """A ``jax.profiler.TraceAnnotation`` for *name* while the window
        is open, else None."""
        if not self._active:
            return None
        try:
            import jax

            return jax.profiler.TraceAnnotation(name)
        except Exception:
            return None

    def tick(self) -> None:
        """Count one completed span; close the window at the bound."""
        if not self._active:
            return
        with self._lock:
            self._spans += 1
            if self._spans >= self.max_spans:
                self._stop_locked()

    def stop(self) -> None:
        with self._lock:
            self._stop_locked()

    def _stop_locked(self) -> None:
        if not self._active:
            return
        self._active = False
        try:
            import jax

            jax.profiler.stop_trace()
        except Exception:
            pass
