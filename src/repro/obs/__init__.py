"""Unified telemetry for the WASH repro: metrics registry, host-side
span tracing, pluggable sinks, and bounded jax.profiler capture.

Quick use::

    from repro import obs

    obs.configure(jsonl="metrics.jsonl", console=True)
    with obs.get().span("train.chunk_execute", step=k):
        ...                     # dispatch work
    obs.get().finalize()        # flush metric snapshots, close sinks

Everything is host-side Python: instrumented engine runs are bitwise
identical to uninstrumented ones and compile exactly the same number of
executables (``tests/test_obs_parity.py`` enforces this).  See
``docs/OBSERVABILITY.md`` for the event schema and metric catalog.
"""

from .metrics import (
    Counter, Gauge, Histogram, Registry,
    DEFAULT_TIME_EDGES, RATIO_EDGES,
    percentile, percentile_ms, summarize_samples,
)
from .events import (
    Telemetry, JsonlSink, MemorySink, ConsoleSink,
    configure, get, reset, provenance,
)
from .profiler import ProfileWindow

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry",
    "DEFAULT_TIME_EDGES", "RATIO_EDGES",
    "percentile", "percentile_ms", "summarize_samples",
    "Telemetry", "JsonlSink", "MemorySink", "ConsoleSink",
    "configure", "get", "reset", "provenance",
    "ProfileWindow",
]
