"""Paper Fig. 3: 2-D toy — shuffling escapes local minima.

    PYTHONPATH=src:. python examples/toy_2d.py

Trains two points on the exact Eq. (7)-(8) loss (two local minima, one
global) with SGD noise, comparing separate / PAPA / WASH training, and
prints an ASCII phase portrait of the final positions.
"""

import jax
import jax.numpy as jnp

from benchmarks.toy2d import GLOBAL, LOCALS, loss, train


def ascii_map(points_by_method):
    grid = [[" ."] * 13 for _ in range(13)]

    def put(x, y, ch):
        xi, yi = int(round(x)), int(round(y))
        if 0 <= xi <= 12 and 0 <= yi <= 12:
            grid[12 - yi][xi] = ch

    put(10, 10, " G")
    put(3, 8, " L")
    put(8, 3, " L")
    marks = {"separate": " s", "papa": " p", "wash": " W"}
    for method, pts in points_by_method.items():
        for pt in pts:
            put(float(pt[0]), float(pt[1]), marks[method])
    print("   " + "".join(f"{i:2d}" for i in range(13)))
    for r, row in enumerate(grid):
        print(f"{12-r:2d} " + "".join(row))


def main():
    key = jax.random.key(0)
    finals = {}
    for method in ("separate", "papa", "wash"):
        pts = train(method, key, noise=0.5)
        finals[method] = pts
        d = jnp.linalg.norm(pts - GLOBAL[None], axis=-1)
        print(f"{method:9s} final points {pts.round(2).tolist()} "
              f"dist-to-global {d.round(2).tolist()}")
    print("\nG = global minimum, L = local minima, s/p/W = final points\n")
    ascii_map(finals)
    print("\nWASH (W) reaches the global minimum; separate (s) points are "
          "stuck in the two locals.")


if __name__ == "__main__":
    main()
