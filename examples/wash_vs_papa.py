"""Fig. 2 reproduction: consensus-distance dynamics of all four methods.

    PYTHONPATH=src:. python examples/wash_vs_papa.py

Plots (ASCII) the average distance to consensus over training for
Baseline / PAPA / PAPA-all / WASH and prints the communication totals.
"""

import jax

from benchmarks.population_common import METHODS, ExpConfig, run_experiment


def ascii_plot(traces, steps, height=14):
    all_vals = [v for t in traces.values() for v in t]
    top = max(all_vals) * 1.05 + 1e-9
    marks = {"baseline": "b", "papa": "p", "papa_all": "a", "wash": "W"}
    cols = len(next(iter(traces.values())))
    grid = [[" "] * cols for _ in range(height)]
    for name, t in traces.items():
        for c, v in enumerate(t):
            r = height - 1 - int(v / top * (height - 1))
            grid[r][c] = marks[name]
    print(f"distance-to-consensus (top={top:.1f})")
    for row in grid:
        print("  |" + "".join(row))
    print("  +" + "-" * cols + f"-> step (0..{steps})")
    print("  b=baseline p=papa a=papa_all W=wash")


def main():
    ecfg = ExpConfig(model="mlp", width=64, depth=3, hw=12, noise=1.6,
                     steps=300, lr=0.15)
    traces, comms, accs = {}, {}, {}
    for name in ("baseline", "papa", "papa_all", "wash"):
        m = run_experiment(METHODS[name], ecfg, record_every=20)
        traces[name] = m["consensus"]
        comms[name] = m["comm_scalars"]
        accs[name] = (m["ensemble"], m["averaged"])
        print(f"{name:9s} ens={m['ensemble']:.3f} avg={m['averaged']:.3f} "
              f"final_dist={m['consensus'][-1]:.2f} comm={m['comm_scalars']:.2e}")
    print()
    ascii_plot(traces, ecfg.steps)
    print("\nWASH keeps more diversity than PAPA/PAPA-all (higher curve) "
          "while still averaging as well — at a fraction of the traffic.")


if __name__ == "__main__":
    main()
