"""Serve a WASH-averaged model with batched requests (prefill + decode).

    PYTHONPATH=src python examples/serve_batched.py

Quick-trains a tiny population on the Markov LM task, averages it, then
serves a batch of prompts through the KV-cache engine and reports
next-token accuracy against the generating chain (the averaged model beats
chance by a wide margin) and decode throughput.
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import averaging as avg
from repro.core.mixing import MixingConfig
from repro.data import make_lm_task, sample_tokens
from repro.models import transformer as M
from repro.serving import generate
from repro.train import train_population


def main():
    key = jax.random.key(0)
    cfg = ModelConfig(name="tiny-lm", num_layers=2, d_model=96, num_heads=4,
                      num_kv_heads=2, d_ff=192, vocab_size=128, dtype="float32")
    task = make_lm_task(jax.random.fold_in(key, 1), vocab=cfg.vocab_size)

    def data_fn(m, step, k):
        return {"tokens": sample_tokens(task, k, 8, 48)}

    def loss_fn(params, batch):
        loss, _ = M.loss_fn(params, cfg, batch)
        return loss

    print("training a 3-member WASH population on the Markov LM task...")
    res = train_population(
        key, lambda k: M.init_params(k, cfg), loss_fn, data_fn,
        TrainConfig(population=3, optimizer="adamw", lr=2e-3, total_steps=60),
        MixingConfig(kind="wash", base_p=0.02, mode="dense"),
        cfg.num_layers, record_every=50,
    )
    model = avg.uniform_soup(res.population)
    print(f"member losses -> {res.history['loss'][-1]:.3f}")

    # batched serving
    batch = 8
    prompts = sample_tokens(task, jax.random.fold_in(key, 2), batch, 24)
    t0 = time.time()
    out = generate(model, cfg, {"tokens": prompts}, max_new_tokens=16)
    dt = time.time() - t0
    new_tokens = out[:, 24:]

    # the chain's own most-likely continuation for each position
    pred = jnp.argmax(task.table, axis=-1)
    hits = float(jnp.mean(new_tokens[:, 1:] == pred[new_tokens[:, :-1]]))
    print(f"served {batch} prompts x 16 new tokens in {dt:.1f}s "
          f"({batch*16/dt:.0f} tok/s on CPU)")
    print(f"averaged model follows the chain's argmax {hits:.0%} of steps "
          f"(chance {1/cfg.vocab_size:.1%})")


if __name__ == "__main__":
    main()
