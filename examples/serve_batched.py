"""Serve a WASH population with batched requests through the fused engine.

    PYTHONPATH=src python examples/serve_batched.py

Quick-trains a tiny population on the Markov LM task, then serves a batch
of prompts under each serving mode — ``soup`` (uniform weight average,
single-model cost), ``member`` (one member), and ``ensemble`` (all members
decoded per step, logits averaged — the paper's accuracy ceiling at N×
compute) — reporting next-token accuracy against the generating chain and
decode throughput.  The whole generation is ONE compiled program per mode
(see ``repro/serving/README.md``), so the decode trace count stays 1 no
matter how many tokens or repeat requests are served.
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.mixing import MixingConfig
from repro.data import make_lm_task, sample_tokens
from repro.models import transformer as M
from repro.serving import (
    decode_trace_count, generate, reset_trace_counts, serving_params,
)
from repro.train import train_population


def main():
    key = jax.random.key(0)
    cfg = ModelConfig(name="tiny-lm", num_layers=2, d_model=96, num_heads=4,
                      num_kv_heads=2, d_ff=192, vocab_size=128, dtype="float32")
    task = make_lm_task(jax.random.fold_in(key, 1), vocab=cfg.vocab_size)

    def data_fn(m, step, k):
        return {"tokens": sample_tokens(task, k, 8, 48)}

    def loss_fn(params, batch):
        loss, _ = M.loss_fn(params, cfg, batch)
        return loss

    print("training a 3-member WASH population on the Markov LM task...")
    res = train_population(
        key, lambda k: M.init_params(k, cfg), loss_fn, data_fn,
        TrainConfig(population=3, optimizer="adamw", lr=2e-3, total_steps=60),
        MixingConfig(kind="wash", base_p=0.02, mode="dense"),
        cfg.num_layers, record_every=50,
    )
    print(f"member losses -> {res.history['loss'][-1]:.3f}")

    batch, prompt_len, max_new = 8, 24, 16
    prompts = sample_tokens(task, jax.random.fold_in(key, 2), batch, prompt_len)
    pred = jnp.argmax(task.table, axis=-1)  # the chain's own argmax rule

    reset_trace_counts()
    for mode in ("soup", "member", "ensemble"):
        # soup averaging / member slicing happens once per deployment;
        # warm call compiles (once per shape); timed call is the steady state
        params = serving_params(res, mode)
        gen_mode = "ensemble" if mode == "ensemble" else "soup"
        out = generate(params, cfg, {"tokens": prompts}, max_new, mode=gen_mode)
        t0 = time.time()
        out = generate(params, cfg, {"tokens": prompts}, max_new, mode=gen_mode)
        jax.block_until_ready(out)
        dt = max(time.time() - t0, 1e-9)
        new = out[:, prompt_len:]
        hits = float(jnp.mean(new[:, 1:] == pred[new[:, :-1]]))
        print(f"mode={mode:9s} {batch * max_new / dt:7.0f} tok/s   "
              f"follows chain argmax {hits:.0%} of steps "
              f"(chance {1 / cfg.vocab_size:.1%})")
    print(f"decode programs compiled: {decode_trace_count()} "
          f"(soup+member share one executable; ensemble adds its own)")


if __name__ == "__main__":
    main()
