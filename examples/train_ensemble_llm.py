"""End-to-end driver: WASH-train a ~100M-parameter transformer population.

NOTE: a full 300-step run takes hours on this 1-core CPU container (the
driver is sized for a real accelerator); use --steps 10 for a smoke run.

    PYTHONPATH=src python examples/train_ensemble_llm.py [--steps 300]

Builds a 100M dense LM (a scaled-down llama3.2 family member: same GQA
structure), trains a population of 2 with AdamW + WASH+Opt on a synthetic
Markov LM task for a few hundred steps, averages the weights, and shows
that the averaged model's perplexity tracks the members'.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import TrainConfig
from repro.core import averaging as avg
from repro.core.mixing import MixingConfig
from repro.data import make_lm_task, sample_tokens
from repro.models import transformer as M
from repro.train import train_population


def build_100m():
    """llama3.2 family, scaled to ~100M params."""
    base = get_arch("llama3.2-3b")
    return dataclasses.replace(
        base,
        name="llama-100m",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2304,
        vocab_size=16384,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--population", type=int, default=2)
    args = ap.parse_args()

    cfg = build_100m()
    key = jax.random.key(0)
    params_count = sum(
        x.size for x in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: M.init_params(key, cfg)))
    )
    print(f"model: {cfg.name} ({params_count/1e6:.1f}M params), "
          f"population={args.population}, steps={args.steps}")

    task = make_lm_task(jax.random.fold_in(key, 1), vocab=cfg.vocab_size)

    def data_fn(m, step, k):
        return {"tokens": sample_tokens(task, k, args.batch, args.seq)}

    def loss_fn(params, batch):
        loss, _ = M.loss_fn(params, cfg, batch)
        return loss

    tcfg = TrainConfig(population=args.population, optimizer="adamw", lr=3e-4,
                       total_steps=args.steps, batch_size=args.batch,
                       seq_len=args.seq, warmup_steps=20)
    mcfg = MixingConfig(kind="wash_opt", base_p=0.01, mode="bucketed")

    t0 = time.time()
    res = train_population(
        key, lambda k: M.init_params(k, cfg), loss_fn, data_fn,
        tcfg, mcfg, cfg.num_layers, record_every=max(args.steps // 10, 1),
    )
    dt = time.time() - t0

    eval_batch = data_fn(0, 0, jax.random.fold_in(key, 777))
    soup = avg.uniform_soup(res.population)
    loss_soup, _ = M.loss_fn(soup, cfg, eval_batch)
    member_losses = [
        float(M.loss_fn(jax.tree_util.tree_map(lambda x: x[i], res.population),
                        cfg, eval_batch)[0])
        for i in range(args.population)
    ]

    print(f"\ntrained {args.steps} steps in {dt:.0f}s "
          f"({dt/args.steps*1e3:.0f} ms/step for the whole population)")
    print(f"loss trace          : "
          + " ".join(f"{l:.3f}" for l in res.history["loss"]))
    print(f"member eval losses  : {[round(l,3) for l in member_losses]}")
    print(f"averaged-model loss : {float(loss_soup):.3f}  (ppl {float(jnp.exp(loss_soup)):.1f})")
    print(f"consensus distance  : {res.history['consensus'][-1]:.2f}")
    print(f"scalars sent/member : {res.comm_scalars:.3e} "
          f"({res.comm_scalars/params_count/args.steps:.2e} of d per step)")


if __name__ == "__main__":
    main()
