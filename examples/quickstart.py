"""Quickstart: train a WASH population of classifiers, average, evaluate.

    PYTHONPATH=src python examples/quickstart.py

Five minutes on a laptop CPU.  Shows the paper's central result end to end:
a population trained with parameter shuffling can be *weight averaged* into
a single model whose accuracy matches the ensemble, while independently
trained members cannot.
"""

import jax

from repro.configs.base import TrainConfig
from repro.core import averaging as avg
from repro.core.mixing import MixingConfig
from repro.data import (
    apply_policy,
    eval_images,
    make_image_task,
    member_policies,
    sample_images,
    soft_cross_entropy,
)
from repro.models.cnn import ClassifierConfig, apply_classifier, init_classifier
from repro.train import train_population


def main():
    key = jax.random.key(0)
    n_members = 4

    # a CIFAR-stand-in task (no datasets ship in this container)
    task = make_image_task(key, num_classes=10, hw=12, noise=1.6)
    ccfg = ClassifierConfig(kind="mlp", width=64, depth=3, num_classes=10, image_hw=12)

    # heterogeneous members: each draws its own augmentation policy (paper §4)
    policies = member_policies(jax.random.fold_in(key, 7), n_members, True)

    def data_fn(member, step, k):
        images, labels = sample_images(task, k, 48)
        x, y = apply_policy(jax.random.fold_in(k, 1), images, labels, 10,
                            policies[member])
        return {"x": x, "y": y}

    def loss_fn(params, batch):
        return soft_cross_entropy(apply_classifier(params, ccfg, batch["x"]),
                                  batch["y"])

    tcfg = TrainConfig(population=n_members, optimizer="sgd", lr=0.15,
                       total_steps=400, batch_size=48)

    print("training two populations (baseline vs WASH)...")
    results = {}
    for name, mcfg in (
        ("baseline", MixingConfig(kind="none")),
        ("wash", MixingConfig(kind="wash", base_p=0.05, mode="dense")),
    ):
        results[name] = train_population(
            key, lambda k: init_classifier(k, ccfg), loss_fn, data_fn,
            tcfg, mcfg, ccfg.num_blocks,
        )

    ex, ey = eval_images(task, jax.random.fold_in(key, 99), 512)
    apply_fn = lambda p, x: apply_classifier(p, ccfg, x)
    print(f"\n{'method':10s} {'Ensemble':>9s} {'Averaged':>9s} {'comm/member':>12s}")
    for name, res in results.items():
        ens = float(avg.ensemble_accuracy(apply_fn, res.population, ex, ey))
        soup = float(avg.model_accuracy(apply_fn, avg.uniform_soup(res.population), ex, ey))
        print(f"{name:10s} {ens:9.3f} {soup:9.3f} {res.comm_scalars:12.3e}")
    print("\nWASH: the averaged model keeps the ensemble's accuracy; the "
          "baseline's collapses.")


if __name__ == "__main__":
    main()
