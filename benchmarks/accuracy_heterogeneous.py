"""Paper Table 2: Ensemble vs Averaged accuracy, heterogeneous population
(per-member augmentations + regularizations).  Pattern targets:

  * Baseline: Ensemble high, Averaged ≈ chance / collapsed, Greedy ≈ best.
  * WASH / WASH+Opt / PAPA: Averaged ≈ Ensemble.
  * WASH communication ≪ PAPA.
"""

from __future__ import annotations

import time

from benchmarks._util import fmt
from benchmarks.population_common import METHODS, ExpConfig, run_experiment


def run(quick: bool = True):
    ecfg = ExpConfig(model="mlp", width=64, depth=3, hw=12, noise=1.6,
                     steps=400 if quick else 1000, lr=0.15, heterogeneous=True)
    rows = []
    methods = ("baseline", "papa", "wash", "wash_opt")
    for name in methods:
        t0 = time.perf_counter()
        m = run_experiment(METHODS[name], ecfg, record_every=200)
        us = (time.perf_counter() - t0) * 1e6 / ecfg.steps
        rows.append((
            f"table2_het_{name}",
            us,
            fmt({"ensemble": m["ensemble"], "averaged": m["averaged"],
                 "greedy": m["greedy"], "best": m["best_member"],
                 "consensus": m["consensus"][-1], "comm": m["comm_scalars"],
                 "chance": m["chance"]}),
        ))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
