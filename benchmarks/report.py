"""Assemble the EXPERIMENTS.md roofline tables from the dry-run JSONs.

    PYTHONPATH=src:. python -m benchmarks.report [--out-dir benchmarks/dryrun]

Prints markdown: the full single-pod baseline table, the multi-pod proof
table, and the WASH population runs.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "minitron-8b", "llama3.2-3b", "deepseek-v2-lite-16b", "whisper-medium",
    "qwen3-4b", "hymba-1.5b", "rwkv6-3b", "kimi-k2-1t-a32b", "internvl2-76b",
    "qwen1.5-4b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir):
    recs = {}
    for p in glob.glob(os.path.join(out_dir, "*.json")):
        r = json.load(open(p))
        recs[os.path.basename(p)[:-5]] = r
    return recs


def sci(x):
    return f"{x:.2e}" if isinstance(x, (int, float)) else "—"


def baseline_table(recs, suffix="_sp"):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get(f"{a}_{s}{suffix}")
            if r is None:
                continue
            if r.get("status") == "skip":
                lines.append(f"| {a} | {s} | — | — | — | skip | — | — | {r['note']} |")
                continue
            if r.get("status") != "ok":
                lines.append(f"| {a} | {s} | — | — | — | ERROR | — | — | {r.get('error','')[:60]} |")
                continue
            u = r.get("useful_flops_ratio")
            lines.append(
                f"| {a} | {s} | {sci(r['compute_s'])} | {sci(r['memory_s'])} | "
                f"{sci(r['collective_s'])} | {r['dominant'].replace('_s','')} | "
                f"{sci(r['model_flops'])} | {u and round(u,3)} | {r.get('note','')} |"
            )
    return "\n".join(lines)


def wash_table(recs):
    lines = [
        "| run | mesh | mixing | permute B/dev | all-reduce B/dev | "
        "all-to-all B/dev | compute s | memory s | collective s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for name in sorted(recs):
        r = recs[name]
        if not r.get("wash") or r.get("status") != "ok":
            continue
        mesh = "x".join(str(m) for m in r["mesh"])
        lines.append(
            f"| {name} | {mesh} | {r.get('mixing')} | "
            f"{sci(r.get('bytes_collective-permute', 0))} | "
            f"{sci(r.get('bytes_all-reduce', 0))} | "
            f"{sci(r.get('bytes_all-to-all', 0))} | "
            f"{sci(r['compute_s'])} | {sci(r['memory_s'])} | {sci(r['collective_s'])} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="benchmarks/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "sp", "mp", "wash"])
    args = ap.parse_args()
    recs = load(args.out_dir)
    if args.section in ("all", "sp"):
        print("### Single-pod baseline (16×16 = 256 chips)\n")
        print(baseline_table(recs, "_sp"))
    if args.section in ("all", "mp"):
        print("\n### Multi-pod proof (2×16×16 = 512 chips)\n")
        print(baseline_table(recs, "_mp"))
    if args.section in ("all", "wash"):
        print("\n### WASH population steps\n")
        print(wash_table(recs))


if __name__ == "__main__":
    main()
