"""Shared helpers for the benchmark harness.

Timing flows through :mod:`repro.obs`: every :func:`time_fn` sample also
lands in the telemetry registry (histogram ``bench.<name>``), so a bench
run's timings and a live run's spans read through one API, and the JSON
payloads all carry the same provenance block (:func:`with_provenance`)."""

from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple

import jax

from repro import obs

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2,
            name: Optional[str] = None) -> float:
    """Median wall-time per call in microseconds (jit-warmed).

    With ``name``, each timed sample is also observed into the telemetry
    histogram ``bench.<name>`` (seconds), so bench timings re-read from
    the same registry the engines report through."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    hist = (obs.get().registry.histogram(f"bench.{name}")
            if name is not None and obs.get().enabled else None)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
        if hist is not None:
            hist.observe(ts[-1])
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def with_provenance(payload: dict) -> dict:
    """Return ``payload`` with a ``provenance`` block (device kind, jax
    version, timestamp) stamped in — the shared header for every bench
    JSON artifact under ``benchmarks/out/``."""
    prov = obs.provenance()
    prov.pop("kind", None)
    return {"provenance": prov, **payload}


def fmt(kv: dict) -> str:
    return ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in kv.items())


def print_rows(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def tiny_engine_problem():
    """Shared model/loss for every engine benchmark row (fused-step,
    staging, comm-volume), so the rows measure the same workload by
    construction.  Returns ``(din, dout, init, loss_fn)``."""
    import jax.numpy as jnp

    din, dh, dout = 64, 128, 8

    def init(k):
        ks = jax.random.split(k, 3)
        return {"embed": {"w": jax.random.normal(ks[0], (din, dh)) * 0.1},
                "blocks": [{"w1": jax.random.normal(ks[1], (dh, dh)) * 0.1}],
                "head": {"w": jax.random.normal(ks[2], (dh, dout)) * 0.1}}

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["embed"]["w"] @ p["blocks"][0]["w1"])
        return jnp.mean((h @ p["head"]["w"] - b["y"]) ** 2)

    return din, dout, init, loss_fn
