"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit-warmed)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def fmt(kv: dict) -> str:
    return ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in kv.items())


def print_rows(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def tiny_engine_problem():
    """Shared model/loss for every engine benchmark row (fused-step,
    staging, comm-volume), so the rows measure the same workload by
    construction.  Returns ``(din, dout, init, loss_fn)``."""
    import jax.numpy as jnp

    din, dh, dout = 64, 128, 8

    def init(k):
        ks = jax.random.split(k, 3)
        return {"embed": {"w": jax.random.normal(ks[0], (din, dh)) * 0.1},
                "blocks": [{"w1": jax.random.normal(ks[1], (dh, dh)) * 0.1}],
                "head": {"w": jax.random.normal(ks[2], (dh, dout)) * 0.1}}

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["embed"]["w"] @ p["blocks"][0]["w1"])
        return jnp.mean((h @ p["head"]["w"] - b["y"]) ** 2)

    return din, dout, init, loss_fn
