"""Paper Fig. 3: the 2-D toy — WASH escapes local minima.

Exact Eq. (7)–(8) loss: two local minima at (3,8)/(8,3), global at (10,10).
Two points start at (0,5)/(5,0); SGD with Gaussian gradient noise,
lr 0.1, 1000 steps.  Separate training converges to the two local minima;
PAPA (α=0.99) reaches consensus in a local minimum; WASH (p=0.01 per
coordinate) gets both points to the global minimum.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks._util import fmt


def g(x, y, xm, ym, lam):
    return jnp.exp(-lam * jnp.sqrt(0.5 * ((x - xm) ** 2 + (y - ym) ** 2) + 1e-12))


def loss(p):
    x, y = p[..., 0], p[..., 1]
    return (
        -10 * g(x, y, 10.0, 10.0, 0.1)
        - 5 * g(x, y, 8.0, 3.0, 0.3)
        - 5 * g(x, y, 3.0, 8.0, 0.3)
    )


GLOBAL = jnp.asarray([10.0, 10.0])
LOCALS = jnp.asarray([[3.0, 8.0], [8.0, 3.0]])


def train(method: str, key, steps: int = 1000, lr: float = 0.1, noise: float = 1.0):
    pts = jnp.asarray([[0.0, 5.0], [5.0, 0.0]])
    grad = jax.vmap(jax.grad(lambda p: jnp.sum(loss(p))))

    @jax.jit
    def step(pts, k):
        g_ = grad(pts) + noise * jax.random.normal(k, pts.shape)
        pts = pts - lr * g_
        return pts

    for i in range(steps):
        k = jax.random.fold_in(key, i)
        pts = step(pts, k)
        if method == "papa":
            mean = jnp.mean(pts, axis=0, keepdims=True)
            pts = 0.99 * pts + 0.01 * mean
        elif method == "wash":
            ks = jax.random.fold_in(k, 1)
            # one Bernoulli gate per COORDINATE, shared by both points:
            # the N=2 "uniform permutation" is a swap of that coordinate.
            mask = jax.random.bernoulli(ks, 0.01, (1, 2))
            pts = jnp.where(mask, pts[::-1], pts)
    return pts


def run(quick: bool = True):
    """Report, per method, how often BOTH points reach the global minimum
    (over seeds) — the paper's Fig. 3 shows one representative trajectory."""
    rows = []
    seeds = (0, 7) if quick else (0, 1, 2, 3, 7)
    for method in ("separate", "papa", "wash"):
        t0 = time.perf_counter()
        hits, d_globals = 0, []
        for s in seeds:
            pts = train(method, jax.random.key(s), noise=0.5)
            d_global = float(jnp.max(jnp.linalg.norm(pts - GLOBAL[None], axis=-1)))
            d_globals.append(d_global)
            hits += int(d_global < 2.0)
        us = (time.perf_counter() - t0) * 1e6 / len(seeds)
        rows.append(
            (
                f"toy2d_{method}",
                us,
                fmt({"frac_both_reach_global": hits / len(seeds),
                     "mean_max_dist_to_global": sum(d_globals) / len(d_globals)}),
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
