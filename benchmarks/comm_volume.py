"""Paper Table 1: communication volume of Ensemble / PAPA / WASH / WASH+Opt.

Two measurements:
  1. *step accounting* — scalars sent per member per step, counted by the
     mixing layer during a real (CPU-scale) run, normalized so PAPA = 1.
  2. *HLO accounting* — collective-permute vs all-reduce bytes parsed from
     the lowered population dry-runs (benchmarks/dryrun/*_wash*.json), i.e.
     what the TPU fabric would actually carry (amortized per step:
     PAPA's all-reduce fires every T=10 steps).

Paper targets (CIFAR p=0.001 / ImageNet p=0.05, T=10):
  WASH/PAPA = p·T/2 -> 1/200 (CIFAR) or 1/4 (ImageNet); WASH+Opt doubles.
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs import get_arch
from repro.core.layer_index import total_layers
from repro.core.schedules import layer_probability
from repro.models import transformer as M

import jax

from benchmarks._util import fmt, tiny_engine_problem

PAPA_T = 10


def analytic_ratio(arch_id: str, base_p: float):
    """Expected WASH scalars/step (Eq. 6 schedule) vs PAPA's d/T, on the
    FULL architecture (layered depths for the scanned block leaves)."""
    import numpy as np
    from repro.core.layer_index import infer_layer_ids
    from repro.core.schedules import layer_probability_array

    cfg = get_arch(arch_id)
    params = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    lids = infer_layer_ids(params, cfg.num_layers)
    tl = total_layers(cfg.num_layers)
    leaves = jax.tree_util.tree_leaves(params)
    lid_leaves = jax.tree_util.tree_leaves(lids)
    d = sum(int(l.size) for l in leaves)
    wash = 0.0
    for leaf, lid in zip(leaves, lid_leaves):
        if isinstance(lid, int):
            wash += layer_probability(base_p, lid, tl, "decreasing") * leaf.size
        else:
            per_layer = int(np.prod(leaf.shape[1:])) if len(leaf.shape) > 1 else 1
            probs = layer_probability_array(base_p, lid, tl, "decreasing")
            wash += float(probs.sum()) * per_layer
    papa = d / PAPA_T
    return wash / papa, d


def measured_engine_volume(base_p: float = 0.1, steps: int = 8, n: int = 4):
    """Measured ppermute volume of the fused shard_map engine.

    Trains a tiny population with the fused engine and reports the comm
    its accounting recorded (exact host-side float64 count of scalars
    sent per member per step over the ppermute exchanges), next to the
    static expectation Σ_leaves k_per·(N-1) recomputed from one plan —
    the two must agree exactly — plus the run's chunk-executable trace
    count (the padded scheduler compiles each variant once).
    """
    import jax.numpy as jnp

    from repro.configs.base import TrainConfig
    from repro.core import shuffle as shf
    from repro.core.layer_index import infer_layer_ids
    from repro.core.mixing import MixingConfig
    from repro.core.schedules import layer_probability  # noqa: F401 (doc link)
    from repro.train import engine as engine_mod
    from repro.train.engine import train_population_sharded

    key = jax.random.key(0)

    din, dout, init, loss_fn = tiny_engine_problem()

    def data_fn(m, step, k):
        return {"x": jax.random.normal(k, (4, din)),
                "y": jax.random.normal(jax.random.fold_in(k, 1), (4, dout))}

    tcfg = TrainConfig(population=n, optimizer="sgd", lr=0.05,
                       total_steps=steps, batch_size=4)
    mcfg = MixingConfig(kind="wash", base_p=base_p, mode="bucketed")
    engine_mod.reset_chunk_trace_count()
    res = train_population_sharded(
        key, init, loss_fn, data_fn, tcfg, mcfg, 1, record_every=steps
    )
    traces = engine_mod.chunk_trace_count()

    # exact static expectation from one step's plan (plans are equal-sized
    # every step: k_per depends only on shapes, N, p)
    lids = infer_layer_ids(init(key), 1)
    plan = shf.make_plan(
        jax.random.fold_in(key, 0), init(key), lids, total_layers(1),
        base_p, "decreasing", mode="bucketed", n=n,
    )
    expected_per_step = float(shf.plan_sent_scalars(plan, n, mode="bucketed"))
    measured_per_step = res.comm_scalars / steps
    return measured_per_step, expected_per_step, traces


def shardlocal_volume(arch_id: str = "llama3.2-3b", base_p: float = 0.05,
                      n: int = 4):
    """Shard-local planner accounting on the production (ens, data, model)
    ensemble mesh: per-member scalars sent summed over its model shards vs
    the global-plan volume (the planner's budget split guarantees ≤), plus
    how many leaves actually shard.  Pure host-side shape math — no
    devices are touched (the planner only reads axis names/sizes)."""
    import types

    from repro.core import shardplan
    from repro.core.layer_index import infer_layer_ids
    from repro.core.mixing import MixingConfig, static_mix_comm
    from repro.sharding import rules

    cfg = get_arch(arch_id)
    member = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    mesh = types.SimpleNamespace(
        axis_names=("ens", "data", "model"),
        shape={"ens": n, "data": 256 // (n * 16), "model": 16},
    )
    specs = rules.param_pspecs(member, cfg, mesh)
    mcfg = MixingConfig(kind="wash", base_p=base_p, mode="bucketed")
    lids = infer_layer_ids(member, cfg.num_layers)
    tl = total_layers(cfg.num_layers)
    pplan = shardplan.plan_population_mixing(
        mesh, member, specs, mcfg, lids, tl, n)
    local = shardplan.static_shard_mix_comm(pplan)
    glob = static_mix_comm(member, mcfg, lids, tl, n)
    sharded = sum(1 for i in pplan.infos if i.sharded_dims)
    return local, glob, sharded, len(pplan.infos)


def pipeline_volume(arch_id: str = "kimi-k2-1t-a32b", stages: int = 4,
                    n: int = 4):
    """Per-stage exact WASH accounting on an (ens, pipe) mesh vs the
    single-stage plan.  Pure host-side shape math (fake mesh, no devices):
    the per-stage budgets must sum to the pipe-plan's global volume to the
    last ulp, and never exceed what the single-stage plan moves
    (``pipeline_report`` asserts both)."""
    from repro.launch.dryrun import pipeline_report

    return pipeline_report(arch_id, n, stages, mixing_kind="wash")


def run(quick: bool = True):
    rows = []
    # 1. analytic Eq. 6 accounting on a real arch config
    for p, tag in ((0.001, "cifar_p"), (0.05, "imagenet_p")):
        ratio, d = analytic_ratio("llama3.2-3b", p)
        rows.append((
            f"table1_analytic_{tag}={p}",
            0.0,
            fmt({"wash_over_papa": ratio, "washopt_over_papa": 2 * ratio,
                 "papa_scalars_per_step": d / PAPA_T}),
        ))

    # 1b. shard-local plans on the production ens×data×model mesh
    local, global_vol, nsharded, nleaves = shardlocal_volume()
    rows.append((
        "table1_shardlocal_ens4_data4_model16",
        0.0,
        fmt({"sent_per_member_shardlocal": local,
             "sent_per_member_global_plan": global_vol,
             "ratio": local / global_vol if global_vol else None,
             "sharded_leaves": f"{nsharded}/{nleaves}"}),
    ))

    # 1c. per-stage budgets on pipeline meshes (Eq. 6 makes deep stages
    # cheap: the decreasing schedule concentrates volume in stage 0)
    for arch_id, stages, n in (("kimi-k2-1t-a32b", 4, 4),
                               ("internvl2-76b", 8, 2)):
        rec = pipeline_volume(arch_id, stages=stages, n=n)
        rows.append((
            f"table1_pipeline_{arch_id}_s{stages}",
            0.0,
            fmt({"per_stage_scalars": [float(v) for v in
                 rec["per_stage_scalars"]],
                 "total_scalars": rec["total_scalars"],
                 "single_stage_scalars": rec["single_stage_scalars"],
                 "stage0_share": (rec["per_stage_scalars"][0]
                                  / rec["total_scalars"])}),
        ))

    # 2. measured ppermute volume of the fused shard_map engine (tiny run)
    measured, expected, traces = measured_engine_volume()
    rows.append((
        "table1_measured_fused_engine",
        0.0,
        fmt({"sent_scalars_per_member_per_step": measured,
             "static_plan_expectation": expected,
             "bytes_per_member_per_step_f32": measured * 4,
             "chunk_traces": traces}),
    ))

    # 3. HLO-measured bytes from the population dry-runs
    for path in sorted(glob.glob("benchmarks/dryrun/*_wash*_fu.json")):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        name = os.path.basename(path).replace(".json", "")
        shuffle_bytes = rec.get("bytes_collective-permute", 0) + rec.get(
            "bytes_all-to-all", 0)
        ar_bytes = rec.get("bytes_all-reduce", 0)
        mixing = rec.get("mixing")
        # PAPA's pull all-reduce fires every T steps; grads all-reduce every
        # step in both methods.  Report the raw per-lowered-step numbers.
        rows.append((
            f"table1_hlo_{name}",
            0.0,
            fmt({"mixing": mixing, "collective_permute_B": shuffle_bytes,
                 "all_reduce_B": ar_bytes,
                 "total_collective_B": rec.get("collective_bytes", 0)}),
        ))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
