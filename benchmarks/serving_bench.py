"""Serving engine benchmark: per-token loop vs fused scan, soup vs ensemble.

Rows (CSV via benchmarks/run.py, mirrored into
``benchmarks/out/serving_bench.json``):

  serve_oldloop_*    the legacy per-token Python loop (fresh jit closure
                     per request + one host dispatch per token) — the bug
                     the engine replaced; its re-trace count per request
                     is reported in the derived column.
  serve_scan_*       the fused engine: one compiled decode program per
                     shape, reused across requests (0 traces after warm).
  serve_member       mode=member (single unaveraged member).
  serve_ensemble     mode=ensemble — all N members decoded per step,
                     logits averaged in-scan: the paper's accuracy
                     ceiling, priced here in tokens/sec against the soup.

Timings are steady-state (compile excluded); trace counts are measured by
the engine's counters, not inferred.  ``--smoke`` runs the CI fast-lane
guard: tiny config, 8 new tokens, assert the scan path compiled decode
exactly once and beat zero — then still emits the JSON row.
"""

from __future__ import annotations

import json
import os

import jax

from benchmarks._util import Row, fmt, time_fn

KEY = jax.random.key(0)

JSON_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "out", "serving_bench.json")


def _problem(batch: int, prompt: int):
    from repro.configs.base import ModelConfig
    from repro.models import transformer as M

    cfg = ModelConfig(name="serve-bench", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    popn = jax.vmap(lambda k: M.init_params(k, cfg))(jax.random.split(KEY, 4))
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (batch, prompt),
                                0, cfg.vocab_size)
    return cfg, popn, {"tokens": tokens}


def run(quick: bool = True):
    from repro.serving import engine as serving

    batch, prompt = (4, 16) if quick else (16, 64)
    max_new = 16 if quick else 64
    iters = 3 if quick else 5
    cfg, popn, req = _problem(batch, prompt)
    soup = serving.averaged_params(popn)
    toks = batch * max_new

    rows: list[Row] = []
    results = {}

    def add(name, us, derived):
        rows.append((name, us, fmt(derived)))
        results[name] = {"us_per_call": us, **derived}

    # --- legacy per-token loop (the replaced path) ------------------------
    serving.reset_trace_counts()
    us = time_fn(
        lambda: serving.generate_reference(soup, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    calls = iters + 1
    old_traces = serving.reference_trace_count() / calls
    old_toks = toks / (us * 1e-6)
    add("serve_oldloop_soup", us,
        {"tok_s": old_toks, "traces_per_request": old_traces,
         "dispatches_per_request": max_new - 1})

    # --- fused scan engine ------------------------------------------------
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    us = time_fn(
        lambda: serving.generate(soup, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    scan_traces = serving.decode_trace_count()  # total, across ALL requests
    scan_toks = toks / (us * 1e-6)
    add("serve_scan_soup", us,
        {"tok_s": scan_toks, "traces_total": scan_traces,
         "dispatches_per_request": 1, "speedup_vs_oldloop": scan_toks / old_toks})

    # params are resolved once per mode (deployment-time work) so the rows
    # time the decode engine, not per-request soup/member routing
    member = serving.serving_params(popn, "member", 0)
    us = time_fn(
        lambda: serving.generate(member, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    add("serve_member", us, {"tok_s": toks / (us * 1e-6)})

    stacked = serving.serving_params(popn, "ensemble")
    us = time_fn(
        lambda: serving.generate(stacked, cfg, req, max_new, mode="ensemble"),
        iters=iters, warmup=1,
    )
    ens_toks = toks / (us * 1e-6)
    add("serve_ensemble", us,
        {"tok_s": ens_toks, "members": 4,
         "soup_speedup_vs_ensemble": scan_toks / ens_toks})

    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump({"batch": batch, "prompt": prompt, "max_new": max_new,
                   "rows": results}, f, indent=2)
    return rows


def smoke() -> None:
    """CI fast-lane guard: tiny config, 8 new tokens, trace-count assert."""
    from repro.serving import engine as serving

    cfg, popn, req = _problem(batch=2, prompt=8)
    soup = serving.averaged_params(popn)
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    out = serving.generate(soup, cfg, req, 8)
    out2 = serving.generate(soup, cfg, req, 8)
    assert out.shape == out2.shape == (2, 16), out.shape
    assert serving.decode_trace_count() == 1, (
        f"scan decode must compile exactly once per shape, "
        f"traced {serving.decode_trace_count()}x"
    )
    assert serving.prefill_trace_count() == 1
    rows = run(quick=True)
    from benchmarks._util import print_rows

    print_rows(rows)
    print(f"# serving smoke OK; wrote {JSON_OUT}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        from benchmarks._util import print_rows

        print_rows(run(quick=not args.full))
