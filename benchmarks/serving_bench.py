"""Serving engine benchmark: per-token loop vs fused scan, soup vs ensemble,
static batches vs continuous batching on mixed-length traffic.

Rows (CSV via benchmarks/run.py, mirrored into
``benchmarks/out/serving_bench.json``):

  serve_oldloop_*    the legacy per-token Python loop (fresh jit closure
                     per request + one host dispatch per token) — the bug
                     the engine replaced; its re-trace count per request
                     is reported in the derived column.
  serve_scan_*       the fused engine: one compiled decode program per
                     shape, reused across requests (0 traces after warm).
  serve_member       mode=member (single unaveraged member).
  serve_ensemble     mode=ensemble — all N members decoded per step,
                     logits averaged in-scan: the paper's accuracy
                     ceiling, priced here in tokens/sec against the soup.
  serve_static_mixed      a MIXED-length request stream served by the scan
                          engine: requests bucketed by exact (S, max_new)
                          shape, one compile per bucket — the per-shape
                          compiles ARE the cost of static batching under
                          mixed traffic, so they are timed, not excluded.
  serve_continuous_mixed  the same stream through the continuous-batching
                          paged-KV runtime: one decode compile total
                          (asserted), per-prompt-length prefill compiles,
                          admissions/retirements never retrace.
  serve_driver_whole      an SLO workload (two long prompts arriving just
                          ahead of a burst of short ones) through the
                          async request driver with whole-prompt prefill:
                          a long admission blocks the queue, so the short
                          requests' tail TTFT absorbs both long prefills.
  serve_driver_chunked    the same workload with chunked prefill
                          (interleaved round-robin with decode): short
                          requests slip between a long prompt's chunks,
                          so their tail TTFT is bounded by one chunk, not
                          one prompt.  Derived columns report p50/p99
                          TTFT over all requests AND over the shorts
                          alone — the latter is the SLO number chunking
                          exists to fix.
  serve_ensemble_paged    ensemble mode through the continuous runtime:
                          every emitted token pays one vmapped N-member
                          decode step — the baseline the speculative row
                          races.
  serve_speculative       population-powered speculative decode: the
                          soup drafts ``draft_k`` tokens, the ensemble
                          verifies all of them in ONE batched step.  The
                          bench population stacks ONE member N times —
                          the limit case of WASH's members sharing a
                          basin — so the accept rate is deterministically
                          1.0 and the row isolates the mechanism: k
                          tokens per ensemble dispatch instead of one.
                          Real WASH populations sit below that ceiling;
                          the accept-rate column is the number to watch.
  serve_quantized_kv      the soup continuous server with int8 paged KV
                          (per-page symmetric scales): tokens/sec plus
                          the capacity ledger — pages per GB vs fp32 at
                          fixed HBM, measured from the live pools' actual
                          nbytes, not a formula.

Steady-state rows (oldloop/scan/member/ensemble) exclude compile; the two
mixed-stream rows are cold on purpose; the driver rows are warmed (their
compiles are shared executables, not per-request work) so the TTFT
percentiles measure scheduling, not tracing.  Trace counts are measured
by the engines' counters, not inferred.  ``--smoke`` runs the CI
fast-lane guard: tiny config, assert the scan path compiled decode
exactly once, the continuous runtime compiled decode exactly once for
the whole stream, continuous beat static on the mixed stream, chunked
beat whole-prompt on the shorts' tail TTFT, a resubmitted prompt's
suffix-only prefill skipped its LRU-cached prefix pages (FLOP accounting
by the server's own token counters), speculative decode accepted every
draft AND out-threw the plain paged ensemble, and int8 KV fit >3x the
fp32 page count at fixed HBM — then still emits the JSON.
"""

from __future__ import annotations

import json
import os

import jax

from benchmarks._util import Row, fmt, time_fn, with_provenance

KEY = jax.random.key(0)

JSON_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "out", "serving_bench.json")


def _problem(batch: int, prompt: int):
    from repro.configs.base import ModelConfig
    from repro.models import transformer as M

    cfg = ModelConfig(name="serve-bench", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    popn = jax.vmap(lambda k: M.init_params(k, cfg))(jax.random.split(KEY, 4))
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (batch, prompt),
                                0, cfg.vocab_size)
    return cfg, popn, {"tokens": tokens}


def _mixed_stream(cfg, n_requests: int, max_prompt: int, max_new: int,
                  seed: int = 0):
    """Mixed-length traffic with some shared prompt prefixes (so the
    prefix-page dedup path is exercised, not just measured at zero).
    The generator lives in ``repro.launch.serve`` — one traffic shape for
    the CLI and the bench."""
    from repro.launch.serve import mixed_stream

    return mixed_stream(cfg, n_requests, max_prompt, max_new, seed,
                        share_prefix_every=4)


def _run_mixed(cfg, soup, reqs, page_size: int, max_slots: int):
    """(static_seconds, static_traces, continuous_seconds, server) — both
    runtimes serve the stream cold (compiles included: under mixed traffic
    the static engine's per-shape compiles are the point)."""
    import time as _time
    from collections import defaultdict

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serving import batching
    from repro.serving import engine as serving

    # --- static: bucket by exact shape, one scan-engine call per bucket
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    buckets = defaultdict(list)
    for r in reqs:
        buckets[(len(r.tokens), r.max_new)].append(r)
    t0 = _time.perf_counter()
    for (S, mn), group in buckets.items():
        toks = jnp.asarray(np.stack([r.tokens for r in group]))
        jax.block_until_ready(
            serving.generate(soup, cfg, {"tokens": toks}, mn))
    static_s = _time.perf_counter() - t0
    static_traces = serving.decode_trace_count()

    # --- continuous: one server, one decode compile for the whole stream
    max_pages = max(
        -(-(len(r.tokens) + r.max_new) // page_size) for r in reqs)
    server = batching.ContinuousServer(
        soup, cfg, page_size=page_size, max_slots=max_slots,
        num_pages=max_slots * max_pages + 8, max_pages_per_slot=max_pages)
    batching.reset_trace_counts()
    t0 = _time.perf_counter()
    out = server.run(reqs)
    cont_s = _time.perf_counter() - t0
    assert len(out) == len(reqs)
    return static_s, static_traces, cont_s, server


def _driver_workload(cfg, quick: bool = True):
    """The SLO stress shape: two LONG prompts arrive first, then a burst
    of short ones right behind them.  Whole-prompt admission makes every
    short wait out both long prefills; chunked admission lets them
    interleave.  Fresh Request objects every call (runs mutate nothing,
    but sharing uids across servers would make the metrics lie)."""
    import numpy as np

    from repro.serving import batching

    rng = np.random.default_rng(7)
    L, S, n_short, max_new = (96, 12, 6, 8) if quick else (256, 24, 12, 16)
    reqs = [batching.Request(f"long{i}",
                             rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32),
                             max_new)
            for i in range(2)]
    reqs += [batching.Request(f"short{i}",
                              rng.integers(0, cfg.vocab_size, (S,)).astype(np.int32),
                              max_new)
             for i in range(n_short)]
    return reqs


def _run_driver(cfg, soup, chunk, quick: bool = True, page_size: int = 8):
    """(summary, short_summary, server, seconds) for one driver variant.
    One warm pass populates the shared executable cache; the timed pass
    uses a fresh server so its stats and the TTFT percentiles are clean."""
    import time as _time

    from repro.serving import batching
    from repro.serving.driver import RequestDriver, summarize

    def serve(reqs):
        pages = sum(-(-(len(r.tokens) + r.max_new) // page_size)
                    for r in reqs)
        server = batching.ContinuousServer(
            soup, cfg, page_size=page_size, max_slots=len(reqs),
            num_pages=pages + 8, retain_pages=True)
        driver = RequestDriver(server, prefill_chunk=chunk)
        t0 = _time.perf_counter()
        metrics = driver.run(reqs)
        return metrics, server, _time.perf_counter() - t0

    serve(_driver_workload(cfg, quick))                      # warm compiles
    batching.reset_trace_counts()
    metrics, server, dt = serve(_driver_workload(cfg, quick))
    shorts = {uid: m for uid, m in metrics.items()
              if str(uid).startswith("short")}
    return summarize(metrics), summarize(shorts), server, dt


def _spec_workload(cfg, quick: bool = True):
    """Decode-heavy traffic for the ensemble-vs-speculative race: short
    prompts, long generations — the regime speculation targets (a
    prefill-bound stream pays the same prefill either way and would just
    dilute the decode-side difference being measured)."""
    import numpy as np

    from repro.serving import batching

    rng = np.random.default_rng(11)
    n, S, max_new = (8, 12, 24) if quick else (16, 24, 64)
    return [batching.Request(f"spec{i}",
                             rng.integers(0, cfg.vocab_size, (S,)).astype(np.int32),
                             max_new)
            for i in range(n)]


def _run_population(cfg, stacked, reqs_fn, speculative: bool,
                    draft_k: int = 4, page_size: int = 8):
    """(summary, server, seconds) for an ensemble-mode continuous server —
    plain or speculative — timed warm through the async driver so the
    tok/s and TTFT numbers measure decode scheduling, not tracing.
    ``max_pages_per_slot`` is sized to the workload: the paged attend
    gathers every table column, so a sloppy width taxes the verify
    step's B·k rows fourfold."""
    import time as _time

    from repro.serving import batching
    from repro.serving.driver import RequestDriver, summarize

    def serve():
        reqs = reqs_fn()
        per_slot = max(-(-(len(r.tokens) + r.max_new) // page_size)
                       for r in reqs)
        server = batching.ContinuousServer(
            stacked, cfg, mode="ensemble", page_size=page_size,
            max_slots=len(reqs), num_pages=len(reqs) * per_slot + 8,
            max_pages_per_slot=per_slot,
            speculative=speculative, draft_k=draft_k)
        driver = RequestDriver(server)
        t0 = _time.perf_counter()
        metrics = driver.run(reqs)
        return summarize(metrics), server, _time.perf_counter() - t0

    serve()                                              # warm compiles
    return serve()


def _pool_bytes(server) -> int:
    """Live nbytes of the server's verify KV pools (int8 pools are dicts
    holding the quantized pages plus their per-page f32 scales — the
    scales are part of the footprint and are counted)."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(
                   (server._k_pool, server._v_pool)))


def run(quick: bool = True):
    from repro.serving import engine as serving

    batch, prompt = (4, 16) if quick else (16, 64)
    max_new = 16 if quick else 64
    iters = 3 if quick else 5
    cfg, popn, req = _problem(batch, prompt)
    soup = serving.averaged_params(popn)
    toks = batch * max_new

    rows: list[Row] = []
    results = {}

    def add(name, us, derived):
        rows.append((name, us, fmt(derived)))
        results[name] = {"us_per_call": us, **derived}

    # --- legacy per-token loop (the replaced path) ------------------------
    serving.reset_trace_counts()
    us = time_fn(
        lambda: serving.generate_reference(soup, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    calls = iters + 1
    old_traces = serving.reference_trace_count() / calls
    old_toks = toks / (us * 1e-6)
    add("serve_oldloop_soup", us,
        {"tok_s": old_toks, "traces_per_request": old_traces,
         "dispatches_per_request": max_new - 1})

    # --- fused scan engine ------------------------------------------------
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    us = time_fn(
        lambda: serving.generate(soup, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    scan_traces = serving.decode_trace_count()  # total, across ALL requests
    scan_toks = toks / (us * 1e-6)
    add("serve_scan_soup", us,
        {"tok_s": scan_toks, "traces_total": scan_traces,
         "dispatches_per_request": 1, "speedup_vs_oldloop": scan_toks / old_toks})

    # params are resolved once per mode (deployment-time work) so the rows
    # time the decode engine, not per-request soup/member routing
    member = serving.serving_params(popn, "member", 0)
    us = time_fn(
        lambda: serving.generate(member, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    add("serve_member", us, {"tok_s": toks / (us * 1e-6)})

    stacked = serving.serving_params(popn, "ensemble")
    us = time_fn(
        lambda: serving.generate(stacked, cfg, req, max_new, mode="ensemble"),
        iters=iters, warmup=1,
    )
    ens_toks = toks / (us * 1e-6)
    add("serve_ensemble", us,
        {"tok_s": ens_toks, "members": 4,
         "soup_speedup_vs_ensemble": scan_toks / ens_toks})

    # --- static batches vs continuous batching, mixed-length stream -------
    from repro.serving import batching

    n_req = 8 if quick else 24
    reqs = _mixed_stream(cfg, n_req, max_prompt=prompt, max_new=max_new)
    static_s, static_traces, cont_s, server = _run_mixed(
        cfg, soup, reqs, page_size=4 if quick else 16, max_slots=4)
    stream_toks = sum(r.max_new for r in reqs)
    static_toks = stream_toks / static_s
    cont_toks = stream_toks / cont_s
    st = server.stats
    add("serve_static_mixed", static_s * 1e6,
        {"tok_s": static_toks, "requests": n_req,
         "decode_traces": static_traces,
         "shape_buckets": static_traces})
    add("serve_continuous_mixed", cont_s * 1e6,
        {"tok_s": cont_toks, "requests": n_req,
         "decode_traces": batching.decode_trace_count(),
         "prefill_traces": batching.prefill_trace_count(),
         "decode_steps": st["decode_steps"],
         "pages_shared": st["pages_shared"],
         "peak_pages": st["peak_pages_in_use"],
         "speedup_vs_static": cont_toks / static_toks})

    # --- async driver: whole-prompt vs chunked prefill, SLO percentiles ---
    for label, chunk in (("whole", None), ("chunked", 16)):
        s, shorts, server, dt = _run_driver(cfg, soup, chunk, quick)
        st = server.stats
        add(f"serve_driver_{label}", dt * 1e6,
            {"tok_s": s["tokens_per_s"], "requests": s["requests"],
             "ttft_p50_ms": s["ttft_p50_ms"], "ttft_p99_ms": s["ttft_p99_ms"],
             "short_ttft_p50_ms": shorts["ttft_p50_ms"],
             "short_ttft_p99_ms": shorts["ttft_p99_ms"],
             "intertoken_p99_ms": s["intertoken_p99_ms"],
             "decode_traces": batching.decode_trace_count(),
             "prefill_traces": batching.prefill_trace_count(),
             "prefill_tokens": st["prefill_tokens"],
             "prefix_tokens_reused": st["prefix_tokens_reused"],
             "prefill_chunk": chunk or 0})
        if label == "chunked":
            # suffix-only prefill: resubmit a prompt sharing the first
            # long prompt's opening pages — they are parked on the
            # retained server's LRU, so the new admission must share
            # them and prefill ONLY the fresh suffix (token accounting
            # by the server's own counters, not wall clock)
            import numpy as np

            long0 = _driver_workload(cfg, quick)[0].tokens
            keep = (64 // server.page_size) * server.page_size
            re_prompt = np.concatenate([
                np.asarray(long0[:keep]),
                np.full((server.page_size,), 3, np.int32)])
            before = dict(st)
            server.run([batching.Request("resubmit", re_prompt, 4)])
            reused = st["prefix_tokens_reused"] - before["prefix_tokens_reused"]
            suffix = st["prefill_tokens"] - before["prefill_tokens"]
            results["serve_driver_chunked"]["resubmit_prefix_reused"] = reused
            results["serve_driver_chunked"]["resubmit_suffix_tokens"] = suffix
            results["serve_driver_chunked"]["resubmit_prompt_tokens"] = len(re_prompt)

    # --- population speculative decode vs plain ensemble, paged runtime --
    import jax.numpy as jnp

    # the limit case of WASH's same-basin population: ONE member stacked
    # N times, so the soup's argmax always agrees with the ensemble's and
    # the accept rate is deterministically 1.0 — the row isolates the
    # mechanism (k emitted tokens per ensemble dispatch instead of one);
    # trained populations land below this ceiling, which is why the
    # accept_rate column is reported rather than assumed
    member0 = jax.tree_util.tree_map(lambda x: x[0], popn)
    ident = jax.tree_util.tree_map(lambda x: jnp.stack([x] * 4), member0)
    draft_k = 4

    def reqs_fn():
        return _spec_workload(cfg, quick)

    ens_sum, ens_server, ens_dt = _run_population(cfg, ident, reqs_fn, False)
    est = ens_server.stats
    add("serve_ensemble_paged", ens_dt * 1e6,
        {"tok_s": ens_sum["tokens_per_s"], "members": 4,
         "decode_steps": est["decode_steps"],
         "ttft_p99_ms": ens_sum["ttft_p99_ms"]})

    spec_sum, spec_server, spec_dt = _run_population(
        cfg, ident, reqs_fn, True, draft_k=draft_k)
    sst = spec_server.stats
    accept = sst["spec_accepted"] / max(sst["spec_drafted"], 1)
    add("serve_speculative", spec_dt * 1e6,
        {"tok_s": spec_sum["tokens_per_s"], "members": 4,
         "draft_k": draft_k, "accept_rate": accept,
         "drafted": sst["spec_drafted"], "accepted": sst["spec_accepted"],
         "decode_steps": sst["decode_steps"],
         "ttft_p99_ms": spec_sum["ttft_p99_ms"],
         "speedup_vs_ensemble":
             spec_sum["tokens_per_s"] / ens_sum["tokens_per_s"]})

    # --- quantized paged KV: int8 capacity at fixed HBM -------------------
    import time as _time

    ps_q = 4 if quick else 16
    reqs_q = _mixed_stream(cfg, n_req, max_prompt=prompt, max_new=max_new,
                           seed=1)
    max_pages_q = max(-(-(len(r.tokens) + r.max_new) // ps_q)
                      for r in reqs_q)
    q_server = batching.ContinuousServer(
        soup, cfg, page_size=ps_q, max_slots=4,
        num_pages=4 * max_pages_q + 8, max_pages_per_slot=max_pages_q,
        kv_dtype="int8")
    t0 = _time.perf_counter()
    q_out = q_server.run(reqs_q)
    q_s = _time.perf_counter() - t0
    assert len(q_out) == len(reqs_q)
    # capacity from LIVE pools' nbytes (int8 counts its scales) at
    # IDENTICAL geometry: a fresh fp32 sibling server, not an earlier
    # row's server whose page size differs
    ref_server = batching.ContinuousServer(
        soup, cfg, page_size=ps_q, max_slots=4,
        num_pages=q_server.num_pages, max_pages_per_slot=max_pages_q)
    per_page_fp32 = _pool_bytes(ref_server) / ref_server.num_pages
    per_page_int8 = _pool_bytes(q_server) / q_server.num_pages
    add("serve_quantized_kv", q_s * 1e6,
        {"tok_s": stream_toks / q_s,
         "kv_bytes_per_page_fp32": per_page_fp32,
         "kv_bytes_per_page_int8": per_page_int8,
         "capacity_ratio": per_page_fp32 / per_page_int8,
         "pages_per_gb_int8": int(2 ** 30 / per_page_int8),
         "pages_per_gb_fp32": int(2 ** 30 / per_page_fp32)})

    # --- telemetry overhead: same driver workload, obs on vs off ---------
    from repro import obs

    def best_driver_s(reps: int = 2) -> float:
        return min(_run_driver(cfg, soup, 16, quick)[3] for _ in range(reps))

    on_s = best_driver_s()
    tel = obs.get()
    tel.enabled = False
    try:
        off_s = best_driver_s()
    finally:
        tel.enabled = True
    add("serve_obs_overhead", (on_s - off_s) * 1e6,
        {"enabled_s": on_s, "disabled_s": off_s,
         "overhead_ratio": on_s / off_s})

    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump(with_provenance(
            {"batch": batch, "prompt": prompt, "max_new": max_new,
             "rows": results}), f, indent=2)
    return rows


def smoke() -> None:
    """CI fast-lane guard: tiny config, 8 new tokens, trace-count asserts
    for BOTH runtimes + the static-vs-continuous throughput win."""
    from repro.serving import batching
    from repro.serving import engine as serving

    cfg, popn, req = _problem(batch=2, prompt=8)
    soup = serving.averaged_params(popn)
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    out = serving.generate(soup, cfg, req, 8)
    out2 = serving.generate(soup, cfg, req, 8)
    assert out.shape == out2.shape == (2, 16), out.shape
    assert serving.decode_trace_count() == 1, (
        f"scan decode must compile exactly once per shape, "
        f"traced {serving.decode_trace_count()}x"
    )
    assert serving.prefill_trace_count() == 1
    rows = run(quick=True)
    # assert on the structured JSON run() just wrote, not the formatted
    # row strings (a substring match on "decode_traces=1" would also pass
    # for 10+ traces — the exact regression this guard exists to catch)
    with open(JSON_OUT) as f:
        results = json.load(f)["rows"]
    cont = results["serve_continuous_mixed"]
    stat = results["serve_static_mixed"]
    assert cont["decode_traces"] == 1, (
        f"continuous decode must compile exactly once for the whole "
        f"mixed stream, traced {cont['decode_traces']}x"
    )
    assert cont["pages_shared"] > 0, (
        "the mixed stream shares prompt prefixes; dedup must trigger"
    )
    assert cont["tok_s"] > stat["tok_s"], (
        f"continuous ({cont['tok_s']:.0f} tok/s) must beat static "
        f"shape-bucketing ({stat['tok_s']:.0f} tok/s) on mixed traffic"
    )
    whole = results["serve_driver_whole"]
    chunked = results["serve_driver_chunked"]
    # the driver rows are warmed, so the timed pass must hit the shared
    # executable cache: ZERO new decode/prefill traces, not even one
    assert whole["decode_traces"] == 0 and chunked["decode_traces"] == 0, (
        f"warmed driver runs must not retrace decode "
        f"(whole {whole['decode_traces']}, chunked {chunked['decode_traces']})"
    )
    assert whole["prefill_traces"] == 0 and chunked["prefill_traces"] == 0, (
        f"warmed driver runs must not retrace prefill chunks "
        f"(whole {whole['prefill_traces']}, chunked {chunked['prefill_traces']})"
    )
    assert chunked["short_ttft_p99_ms"] < whole["short_ttft_p99_ms"], (
        f"chunked prefill must beat whole-prompt on the short requests' "
        f"tail TTFT (chunked p99 {chunked['short_ttft_p99_ms']:.1f}ms vs "
        f"whole {whole['short_ttft_p99_ms']:.1f}ms)"
    )
    assert chunked["resubmit_prefix_reused"] > 0, (
        "resubmitted prompt must share its LRU-retained prefix pages"
    )
    assert (chunked["resubmit_suffix_tokens"]
            == chunked["resubmit_prompt_tokens"]
            - chunked["resubmit_prefix_reused"]), (
        f"suffix-only prefill must compute exactly the uncached tokens: "
        f"prefilled {chunked['resubmit_suffix_tokens']} of "
        f"{chunked['resubmit_prompt_tokens']} with "
        f"{chunked['resubmit_prefix_reused']} reused"
    )
    ens = results["serve_ensemble_paged"]
    spec = results["serve_speculative"]
    # identical-member population + greedy => the soup's draft always
    # matches the ensemble's verify: the accept rate must be exactly 1
    # (any miss means the draft/verify sampling paths diverged)
    assert spec["accept_rate"] >= 0.999, (
        f"identical-member greedy population must accept every draft, "
        f"got accept_rate={spec['accept_rate']:.3f} "
        f"({spec['accepted']}/{spec['drafted']})"
    )
    assert spec["decode_steps"] < ens["decode_steps"], (
        f"speculation must emit multiple tokens per ensemble dispatch "
        f"(spec {spec['decode_steps']} steps vs plain {ens['decode_steps']})"
    )
    assert spec["tok_s"] > ens["tok_s"], (
        f"speculative decode ({spec['tok_s']:.0f} tok/s) must beat the "
        f"plain paged ensemble ({ens['tok_s']:.0f} tok/s) at accept~1"
    )
    quant = results["serve_quantized_kv"]
    # int8 pages carry a per-page f32 scale, so the ratio sits just under
    # the dtype's 4x; anything <= 3 means the pools aren't quantized
    assert quant["capacity_ratio"] > 3.0, (
        f"int8 paged KV must fit >3x the pages of fp32 at fixed HBM, "
        f"got {quant['capacity_ratio']:.2f}x"
    )
    overhead = results["serve_obs_overhead"]["overhead_ratio"]
    # registry observes are a handful of dict ops per decode step; the
    # generous bound absorbs CPU wall-clock noise on these tiny shapes
    assert overhead < 1.5, (
        f"telemetry overhead ratio {overhead:.3f} exceeds the 1.5x smoke "
        f"bound — instrumentation is supposed to be a few host-side ops"
    )
    from benchmarks._util import print_rows

    print_rows(rows)
    print(f"# serving smoke OK; wrote {JSON_OUT}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        from benchmarks._util import print_rows

        print_rows(run(quick=not args.full))
