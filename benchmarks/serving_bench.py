"""Serving engine benchmark: per-token loop vs fused scan, soup vs ensemble,
static batches vs continuous batching on mixed-length traffic.

Rows (CSV via benchmarks/run.py, mirrored into
``benchmarks/out/serving_bench.json``):

  serve_oldloop_*    the legacy per-token Python loop (fresh jit closure
                     per request + one host dispatch per token) — the bug
                     the engine replaced; its re-trace count per request
                     is reported in the derived column.
  serve_scan_*       the fused engine: one compiled decode program per
                     shape, reused across requests (0 traces after warm).
  serve_member       mode=member (single unaveraged member).
  serve_ensemble     mode=ensemble — all N members decoded per step,
                     logits averaged in-scan: the paper's accuracy
                     ceiling, priced here in tokens/sec against the soup.
  serve_static_mixed      a MIXED-length request stream served by the scan
                          engine: requests bucketed by exact (S, max_new)
                          shape, one compile per bucket — the per-shape
                          compiles ARE the cost of static batching under
                          mixed traffic, so they are timed, not excluded.
  serve_continuous_mixed  the same stream through the continuous-batching
                          paged-KV runtime: one decode compile total
                          (asserted), per-prompt-length prefill compiles,
                          admissions/retirements never retrace.

Steady-state rows (oldloop/scan/member/ensemble) exclude compile; the two
mixed-stream rows are cold on purpose.  Trace counts are measured by the
engines' counters, not inferred.  ``--smoke`` runs the CI fast-lane guard:
tiny config, assert the scan path compiled decode exactly once, the
continuous runtime compiled decode exactly once for the whole stream, and
continuous beat static on the mixed stream — then still emits the JSON.
"""

from __future__ import annotations

import json
import os

import jax

from benchmarks._util import Row, fmt, time_fn

KEY = jax.random.key(0)

JSON_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "out", "serving_bench.json")


def _problem(batch: int, prompt: int):
    from repro.configs.base import ModelConfig
    from repro.models import transformer as M

    cfg = ModelConfig(name="serve-bench", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    popn = jax.vmap(lambda k: M.init_params(k, cfg))(jax.random.split(KEY, 4))
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (batch, prompt),
                                0, cfg.vocab_size)
    return cfg, popn, {"tokens": tokens}


def _mixed_stream(cfg, n_requests: int, max_prompt: int, max_new: int,
                  seed: int = 0):
    """Mixed-length traffic with some shared prompt prefixes (so the
    prefix-page dedup path is exercised, not just measured at zero).
    The generator lives in ``repro.launch.serve`` — one traffic shape for
    the CLI and the bench."""
    from repro.launch.serve import mixed_stream

    return mixed_stream(cfg, n_requests, max_prompt, max_new, seed,
                        share_prefix_every=4)


def _run_mixed(cfg, soup, reqs, page_size: int, max_slots: int):
    """(static_seconds, static_traces, continuous_seconds, server) — both
    runtimes serve the stream cold (compiles included: under mixed traffic
    the static engine's per-shape compiles are the point)."""
    import time as _time
    from collections import defaultdict

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serving import batching
    from repro.serving import engine as serving

    # --- static: bucket by exact shape, one scan-engine call per bucket
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    buckets = defaultdict(list)
    for r in reqs:
        buckets[(len(r.tokens), r.max_new)].append(r)
    t0 = _time.perf_counter()
    for (S, mn), group in buckets.items():
        toks = jnp.asarray(np.stack([r.tokens for r in group]))
        jax.block_until_ready(
            serving.generate(soup, cfg, {"tokens": toks}, mn))
    static_s = _time.perf_counter() - t0
    static_traces = serving.decode_trace_count()

    # --- continuous: one server, one decode compile for the whole stream
    max_pages = max(
        -(-(len(r.tokens) + r.max_new) // page_size) for r in reqs)
    server = batching.ContinuousServer(
        soup, cfg, page_size=page_size, max_slots=max_slots,
        num_pages=max_slots * max_pages + 8, max_pages_per_slot=max_pages)
    batching.reset_trace_counts()
    t0 = _time.perf_counter()
    out = server.run(reqs)
    cont_s = _time.perf_counter() - t0
    assert len(out) == len(reqs)
    return static_s, static_traces, cont_s, server


def run(quick: bool = True):
    from repro.serving import engine as serving

    batch, prompt = (4, 16) if quick else (16, 64)
    max_new = 16 if quick else 64
    iters = 3 if quick else 5
    cfg, popn, req = _problem(batch, prompt)
    soup = serving.averaged_params(popn)
    toks = batch * max_new

    rows: list[Row] = []
    results = {}

    def add(name, us, derived):
        rows.append((name, us, fmt(derived)))
        results[name] = {"us_per_call": us, **derived}

    # --- legacy per-token loop (the replaced path) ------------------------
    serving.reset_trace_counts()
    us = time_fn(
        lambda: serving.generate_reference(soup, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    calls = iters + 1
    old_traces = serving.reference_trace_count() / calls
    old_toks = toks / (us * 1e-6)
    add("serve_oldloop_soup", us,
        {"tok_s": old_toks, "traces_per_request": old_traces,
         "dispatches_per_request": max_new - 1})

    # --- fused scan engine ------------------------------------------------
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    us = time_fn(
        lambda: serving.generate(soup, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    scan_traces = serving.decode_trace_count()  # total, across ALL requests
    scan_toks = toks / (us * 1e-6)
    add("serve_scan_soup", us,
        {"tok_s": scan_toks, "traces_total": scan_traces,
         "dispatches_per_request": 1, "speedup_vs_oldloop": scan_toks / old_toks})

    # params are resolved once per mode (deployment-time work) so the rows
    # time the decode engine, not per-request soup/member routing
    member = serving.serving_params(popn, "member", 0)
    us = time_fn(
        lambda: serving.generate(member, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    add("serve_member", us, {"tok_s": toks / (us * 1e-6)})

    stacked = serving.serving_params(popn, "ensemble")
    us = time_fn(
        lambda: serving.generate(stacked, cfg, req, max_new, mode="ensemble"),
        iters=iters, warmup=1,
    )
    ens_toks = toks / (us * 1e-6)
    add("serve_ensemble", us,
        {"tok_s": ens_toks, "members": 4,
         "soup_speedup_vs_ensemble": scan_toks / ens_toks})

    # --- static batches vs continuous batching, mixed-length stream -------
    from repro.serving import batching

    n_req = 8 if quick else 24
    reqs = _mixed_stream(cfg, n_req, max_prompt=prompt, max_new=max_new)
    static_s, static_traces, cont_s, server = _run_mixed(
        cfg, soup, reqs, page_size=4 if quick else 16, max_slots=4)
    stream_toks = sum(r.max_new for r in reqs)
    static_toks = stream_toks / static_s
    cont_toks = stream_toks / cont_s
    st = server.stats
    add("serve_static_mixed", static_s * 1e6,
        {"tok_s": static_toks, "requests": n_req,
         "decode_traces": static_traces,
         "shape_buckets": static_traces})
    add("serve_continuous_mixed", cont_s * 1e6,
        {"tok_s": cont_toks, "requests": n_req,
         "decode_traces": batching.decode_trace_count(),
         "prefill_traces": batching.prefill_trace_count(),
         "decode_steps": st["decode_steps"],
         "pages_shared": st["pages_shared"],
         "peak_pages": st["peak_pages_in_use"],
         "speedup_vs_static": cont_toks / static_toks})

    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump({"batch": batch, "prompt": prompt, "max_new": max_new,
                   "rows": results}, f, indent=2)
    return rows


def smoke() -> None:
    """CI fast-lane guard: tiny config, 8 new tokens, trace-count asserts
    for BOTH runtimes + the static-vs-continuous throughput win."""
    from repro.serving import batching
    from repro.serving import engine as serving

    cfg, popn, req = _problem(batch=2, prompt=8)
    soup = serving.averaged_params(popn)
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    out = serving.generate(soup, cfg, req, 8)
    out2 = serving.generate(soup, cfg, req, 8)
    assert out.shape == out2.shape == (2, 16), out.shape
    assert serving.decode_trace_count() == 1, (
        f"scan decode must compile exactly once per shape, "
        f"traced {serving.decode_trace_count()}x"
    )
    assert serving.prefill_trace_count() == 1
    rows = run(quick=True)
    # assert on the structured JSON run() just wrote, not the formatted
    # row strings (a substring match on "decode_traces=1" would also pass
    # for 10+ traces — the exact regression this guard exists to catch)
    with open(JSON_OUT) as f:
        results = json.load(f)["rows"]
    cont = results["serve_continuous_mixed"]
    stat = results["serve_static_mixed"]
    assert cont["decode_traces"] == 1, (
        f"continuous decode must compile exactly once for the whole "
        f"mixed stream, traced {cont['decode_traces']}x"
    )
    assert cont["pages_shared"] > 0, (
        "the mixed stream shares prompt prefixes; dedup must trigger"
    )
    assert cont["tok_s"] > stat["tok_s"], (
        f"continuous ({cont['tok_s']:.0f} tok/s) must beat static "
        f"shape-bucketing ({stat['tok_s']:.0f} tok/s) on mixed traffic"
    )
    from benchmarks._util import print_rows

    print_rows(rows)
    print(f"# serving smoke OK; wrote {JSON_OUT}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        from benchmarks._util import print_rows

        print_rows(run(quick=not args.full))
