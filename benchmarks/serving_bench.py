"""Serving engine benchmark: per-token loop vs fused scan, soup vs ensemble,
static batches vs continuous batching on mixed-length traffic.

Rows (CSV via benchmarks/run.py, mirrored into
``benchmarks/out/serving_bench.json``):

  serve_oldloop_*    the legacy per-token Python loop (fresh jit closure
                     per request + one host dispatch per token) — the bug
                     the engine replaced; its re-trace count per request
                     is reported in the derived column.
  serve_scan_*       the fused engine: one compiled decode program per
                     shape, reused across requests (0 traces after warm).
  serve_member       mode=member (single unaveraged member).
  serve_ensemble     mode=ensemble — all N members decoded per step,
                     logits averaged in-scan: the paper's accuracy
                     ceiling, priced here in tokens/sec against the soup.
  serve_static_mixed      a MIXED-length request stream served by the scan
                          engine: requests bucketed by exact (S, max_new)
                          shape, one compile per bucket — the per-shape
                          compiles ARE the cost of static batching under
                          mixed traffic, so they are timed, not excluded.
  serve_continuous_mixed  the same stream through the continuous-batching
                          paged-KV runtime: one decode compile total
                          (asserted), per-prompt-length prefill compiles,
                          admissions/retirements never retrace.
  serve_driver_whole      an SLO workload (two long prompts arriving just
                          ahead of a burst of short ones) through the
                          async request driver with whole-prompt prefill:
                          a long admission blocks the queue, so the short
                          requests' tail TTFT absorbs both long prefills.
  serve_driver_chunked    the same workload with chunked prefill
                          (interleaved round-robin with decode): short
                          requests slip between a long prompt's chunks,
                          so their tail TTFT is bounded by one chunk, not
                          one prompt.  Derived columns report p50/p99
                          TTFT over all requests AND over the shorts
                          alone — the latter is the SLO number chunking
                          exists to fix.

Steady-state rows (oldloop/scan/member/ensemble) exclude compile; the two
mixed-stream rows are cold on purpose; the driver rows are warmed (their
compiles are shared executables, not per-request work) so the TTFT
percentiles measure scheduling, not tracing.  Trace counts are measured
by the engines' counters, not inferred.  ``--smoke`` runs the CI
fast-lane guard: tiny config, assert the scan path compiled decode
exactly once, the continuous runtime compiled decode exactly once for
the whole stream, continuous beat static on the mixed stream, chunked
beat whole-prompt on the shorts' tail TTFT, and a resubmitted prompt's
suffix-only prefill skipped its LRU-cached prefix pages (FLOP accounting
by the server's own token counters) — then still emits the JSON.
"""

from __future__ import annotations

import json
import os

import jax

from benchmarks._util import Row, fmt, time_fn, with_provenance

KEY = jax.random.key(0)

JSON_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "out", "serving_bench.json")


def _problem(batch: int, prompt: int):
    from repro.configs.base import ModelConfig
    from repro.models import transformer as M

    cfg = ModelConfig(name="serve-bench", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    popn = jax.vmap(lambda k: M.init_params(k, cfg))(jax.random.split(KEY, 4))
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (batch, prompt),
                                0, cfg.vocab_size)
    return cfg, popn, {"tokens": tokens}


def _mixed_stream(cfg, n_requests: int, max_prompt: int, max_new: int,
                  seed: int = 0):
    """Mixed-length traffic with some shared prompt prefixes (so the
    prefix-page dedup path is exercised, not just measured at zero).
    The generator lives in ``repro.launch.serve`` — one traffic shape for
    the CLI and the bench."""
    from repro.launch.serve import mixed_stream

    return mixed_stream(cfg, n_requests, max_prompt, max_new, seed,
                        share_prefix_every=4)


def _run_mixed(cfg, soup, reqs, page_size: int, max_slots: int):
    """(static_seconds, static_traces, continuous_seconds, server) — both
    runtimes serve the stream cold (compiles included: under mixed traffic
    the static engine's per-shape compiles are the point)."""
    import time as _time
    from collections import defaultdict

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serving import batching
    from repro.serving import engine as serving

    # --- static: bucket by exact shape, one scan-engine call per bucket
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    buckets = defaultdict(list)
    for r in reqs:
        buckets[(len(r.tokens), r.max_new)].append(r)
    t0 = _time.perf_counter()
    for (S, mn), group in buckets.items():
        toks = jnp.asarray(np.stack([r.tokens for r in group]))
        jax.block_until_ready(
            serving.generate(soup, cfg, {"tokens": toks}, mn))
    static_s = _time.perf_counter() - t0
    static_traces = serving.decode_trace_count()

    # --- continuous: one server, one decode compile for the whole stream
    max_pages = max(
        -(-(len(r.tokens) + r.max_new) // page_size) for r in reqs)
    server = batching.ContinuousServer(
        soup, cfg, page_size=page_size, max_slots=max_slots,
        num_pages=max_slots * max_pages + 8, max_pages_per_slot=max_pages)
    batching.reset_trace_counts()
    t0 = _time.perf_counter()
    out = server.run(reqs)
    cont_s = _time.perf_counter() - t0
    assert len(out) == len(reqs)
    return static_s, static_traces, cont_s, server


def _driver_workload(cfg, quick: bool = True):
    """The SLO stress shape: two LONG prompts arrive first, then a burst
    of short ones right behind them.  Whole-prompt admission makes every
    short wait out both long prefills; chunked admission lets them
    interleave.  Fresh Request objects every call (runs mutate nothing,
    but sharing uids across servers would make the metrics lie)."""
    import numpy as np

    from repro.serving import batching

    rng = np.random.default_rng(7)
    L, S, n_short, max_new = (96, 12, 6, 8) if quick else (256, 24, 12, 16)
    reqs = [batching.Request(f"long{i}",
                             rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32),
                             max_new)
            for i in range(2)]
    reqs += [batching.Request(f"short{i}",
                              rng.integers(0, cfg.vocab_size, (S,)).astype(np.int32),
                              max_new)
             for i in range(n_short)]
    return reqs


def _run_driver(cfg, soup, chunk, quick: bool = True, page_size: int = 8):
    """(summary, short_summary, server, seconds) for one driver variant.
    One warm pass populates the shared executable cache; the timed pass
    uses a fresh server so its stats and the TTFT percentiles are clean."""
    import time as _time

    from repro.serving import batching
    from repro.serving.driver import RequestDriver, summarize

    def serve(reqs):
        pages = sum(-(-(len(r.tokens) + r.max_new) // page_size)
                    for r in reqs)
        server = batching.ContinuousServer(
            soup, cfg, page_size=page_size, max_slots=len(reqs),
            num_pages=pages + 8, retain_pages=True)
        driver = RequestDriver(server, prefill_chunk=chunk)
        t0 = _time.perf_counter()
        metrics = driver.run(reqs)
        return metrics, server, _time.perf_counter() - t0

    serve(_driver_workload(cfg, quick))                      # warm compiles
    batching.reset_trace_counts()
    metrics, server, dt = serve(_driver_workload(cfg, quick))
    shorts = {uid: m for uid, m in metrics.items()
              if str(uid).startswith("short")}
    return summarize(metrics), summarize(shorts), server, dt


def run(quick: bool = True):
    from repro.serving import engine as serving

    batch, prompt = (4, 16) if quick else (16, 64)
    max_new = 16 if quick else 64
    iters = 3 if quick else 5
    cfg, popn, req = _problem(batch, prompt)
    soup = serving.averaged_params(popn)
    toks = batch * max_new

    rows: list[Row] = []
    results = {}

    def add(name, us, derived):
        rows.append((name, us, fmt(derived)))
        results[name] = {"us_per_call": us, **derived}

    # --- legacy per-token loop (the replaced path) ------------------------
    serving.reset_trace_counts()
    us = time_fn(
        lambda: serving.generate_reference(soup, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    calls = iters + 1
    old_traces = serving.reference_trace_count() / calls
    old_toks = toks / (us * 1e-6)
    add("serve_oldloop_soup", us,
        {"tok_s": old_toks, "traces_per_request": old_traces,
         "dispatches_per_request": max_new - 1})

    # --- fused scan engine ------------------------------------------------
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    us = time_fn(
        lambda: serving.generate(soup, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    scan_traces = serving.decode_trace_count()  # total, across ALL requests
    scan_toks = toks / (us * 1e-6)
    add("serve_scan_soup", us,
        {"tok_s": scan_toks, "traces_total": scan_traces,
         "dispatches_per_request": 1, "speedup_vs_oldloop": scan_toks / old_toks})

    # params are resolved once per mode (deployment-time work) so the rows
    # time the decode engine, not per-request soup/member routing
    member = serving.serving_params(popn, "member", 0)
    us = time_fn(
        lambda: serving.generate(member, cfg, req, max_new),
        iters=iters, warmup=1,
    )
    add("serve_member", us, {"tok_s": toks / (us * 1e-6)})

    stacked = serving.serving_params(popn, "ensemble")
    us = time_fn(
        lambda: serving.generate(stacked, cfg, req, max_new, mode="ensemble"),
        iters=iters, warmup=1,
    )
    ens_toks = toks / (us * 1e-6)
    add("serve_ensemble", us,
        {"tok_s": ens_toks, "members": 4,
         "soup_speedup_vs_ensemble": scan_toks / ens_toks})

    # --- static batches vs continuous batching, mixed-length stream -------
    from repro.serving import batching

    n_req = 8 if quick else 24
    reqs = _mixed_stream(cfg, n_req, max_prompt=prompt, max_new=max_new)
    static_s, static_traces, cont_s, server = _run_mixed(
        cfg, soup, reqs, page_size=4 if quick else 16, max_slots=4)
    stream_toks = sum(r.max_new for r in reqs)
    static_toks = stream_toks / static_s
    cont_toks = stream_toks / cont_s
    st = server.stats
    add("serve_static_mixed", static_s * 1e6,
        {"tok_s": static_toks, "requests": n_req,
         "decode_traces": static_traces,
         "shape_buckets": static_traces})
    add("serve_continuous_mixed", cont_s * 1e6,
        {"tok_s": cont_toks, "requests": n_req,
         "decode_traces": batching.decode_trace_count(),
         "prefill_traces": batching.prefill_trace_count(),
         "decode_steps": st["decode_steps"],
         "pages_shared": st["pages_shared"],
         "peak_pages": st["peak_pages_in_use"],
         "speedup_vs_static": cont_toks / static_toks})

    # --- async driver: whole-prompt vs chunked prefill, SLO percentiles ---
    for label, chunk in (("whole", None), ("chunked", 16)):
        s, shorts, server, dt = _run_driver(cfg, soup, chunk, quick)
        st = server.stats
        add(f"serve_driver_{label}", dt * 1e6,
            {"tok_s": s["tokens_per_s"], "requests": s["requests"],
             "ttft_p50_ms": s["ttft_p50_ms"], "ttft_p99_ms": s["ttft_p99_ms"],
             "short_ttft_p50_ms": shorts["ttft_p50_ms"],
             "short_ttft_p99_ms": shorts["ttft_p99_ms"],
             "intertoken_p99_ms": s["intertoken_p99_ms"],
             "decode_traces": batching.decode_trace_count(),
             "prefill_traces": batching.prefill_trace_count(),
             "prefill_tokens": st["prefill_tokens"],
             "prefix_tokens_reused": st["prefix_tokens_reused"],
             "prefill_chunk": chunk or 0})
        if label == "chunked":
            # suffix-only prefill: resubmit a prompt sharing the first
            # long prompt's opening pages — they are parked on the
            # retained server's LRU, so the new admission must share
            # them and prefill ONLY the fresh suffix (token accounting
            # by the server's own counters, not wall clock)
            import numpy as np

            long0 = _driver_workload(cfg, quick)[0].tokens
            keep = (64 // server.page_size) * server.page_size
            re_prompt = np.concatenate([
                np.asarray(long0[:keep]),
                np.full((server.page_size,), 3, np.int32)])
            before = dict(st)
            server.run([batching.Request("resubmit", re_prompt, 4)])
            reused = st["prefix_tokens_reused"] - before["prefix_tokens_reused"]
            suffix = st["prefill_tokens"] - before["prefill_tokens"]
            results["serve_driver_chunked"]["resubmit_prefix_reused"] = reused
            results["serve_driver_chunked"]["resubmit_suffix_tokens"] = suffix
            results["serve_driver_chunked"]["resubmit_prompt_tokens"] = len(re_prompt)

    # --- telemetry overhead: same driver workload, obs on vs off ---------
    from repro import obs

    def best_driver_s(reps: int = 2) -> float:
        return min(_run_driver(cfg, soup, 16, quick)[3] for _ in range(reps))

    on_s = best_driver_s()
    tel = obs.get()
    tel.enabled = False
    try:
        off_s = best_driver_s()
    finally:
        tel.enabled = True
    add("serve_obs_overhead", (on_s - off_s) * 1e6,
        {"enabled_s": on_s, "disabled_s": off_s,
         "overhead_ratio": on_s / off_s})

    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump(with_provenance(
            {"batch": batch, "prompt": prompt, "max_new": max_new,
             "rows": results}), f, indent=2)
    return rows


def smoke() -> None:
    """CI fast-lane guard: tiny config, 8 new tokens, trace-count asserts
    for BOTH runtimes + the static-vs-continuous throughput win."""
    from repro.serving import batching
    from repro.serving import engine as serving

    cfg, popn, req = _problem(batch=2, prompt=8)
    soup = serving.averaged_params(popn)
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    out = serving.generate(soup, cfg, req, 8)
    out2 = serving.generate(soup, cfg, req, 8)
    assert out.shape == out2.shape == (2, 16), out.shape
    assert serving.decode_trace_count() == 1, (
        f"scan decode must compile exactly once per shape, "
        f"traced {serving.decode_trace_count()}x"
    )
    assert serving.prefill_trace_count() == 1
    rows = run(quick=True)
    # assert on the structured JSON run() just wrote, not the formatted
    # row strings (a substring match on "decode_traces=1" would also pass
    # for 10+ traces — the exact regression this guard exists to catch)
    with open(JSON_OUT) as f:
        results = json.load(f)["rows"]
    cont = results["serve_continuous_mixed"]
    stat = results["serve_static_mixed"]
    assert cont["decode_traces"] == 1, (
        f"continuous decode must compile exactly once for the whole "
        f"mixed stream, traced {cont['decode_traces']}x"
    )
    assert cont["pages_shared"] > 0, (
        "the mixed stream shares prompt prefixes; dedup must trigger"
    )
    assert cont["tok_s"] > stat["tok_s"], (
        f"continuous ({cont['tok_s']:.0f} tok/s) must beat static "
        f"shape-bucketing ({stat['tok_s']:.0f} tok/s) on mixed traffic"
    )
    whole = results["serve_driver_whole"]
    chunked = results["serve_driver_chunked"]
    # the driver rows are warmed, so the timed pass must hit the shared
    # executable cache: ZERO new decode/prefill traces, not even one
    assert whole["decode_traces"] == 0 and chunked["decode_traces"] == 0, (
        f"warmed driver runs must not retrace decode "
        f"(whole {whole['decode_traces']}, chunked {chunked['decode_traces']})"
    )
    assert whole["prefill_traces"] == 0 and chunked["prefill_traces"] == 0, (
        f"warmed driver runs must not retrace prefill chunks "
        f"(whole {whole['prefill_traces']}, chunked {chunked['prefill_traces']})"
    )
    assert chunked["short_ttft_p99_ms"] < whole["short_ttft_p99_ms"], (
        f"chunked prefill must beat whole-prompt on the short requests' "
        f"tail TTFT (chunked p99 {chunked['short_ttft_p99_ms']:.1f}ms vs "
        f"whole {whole['short_ttft_p99_ms']:.1f}ms)"
    )
    assert chunked["resubmit_prefix_reused"] > 0, (
        "resubmitted prompt must share its LRU-retained prefix pages"
    )
    assert (chunked["resubmit_suffix_tokens"]
            == chunked["resubmit_prompt_tokens"]
            - chunked["resubmit_prefix_reused"]), (
        f"suffix-only prefill must compute exactly the uncached tokens: "
        f"prefilled {chunked['resubmit_suffix_tokens']} of "
        f"{chunked['resubmit_prompt_tokens']} with "
        f"{chunked['resubmit_prefix_reused']} reused"
    )
    overhead = results["serve_obs_overhead"]["overhead_ratio"]
    # registry observes are a handful of dict ops per decode step; the
    # generous bound absorbs CPU wall-clock noise on these tiny shapes
    assert overhead < 1.5, (
        f"telemetry overhead ratio {overhead:.3f} exceeds the 1.5x smoke "
        f"bound — instrumentation is supposed to be a few host-side ops"
    )
    from benchmarks._util import print_rows

    print_rows(rows)
    print(f"# serving smoke OK; wrote {JSON_OUT}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        from benchmarks._util import print_rows

        print_rows(run(quick=not args.full))
