"""Paper Fig. 6 (appendix): accuracy over weight-space interpolations.

For Baseline populations, random convex combinations of members score at
chance; for WASH populations, *every* interpolation stays at high accuracy
(all members share one basin)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import averaging as avg
from repro.data import eval_images, make_image_task
from repro.models.cnn import apply_classifier

from benchmarks._util import fmt
from benchmarks.population_common import METHODS, ExpConfig, run_experiment


def run(quick: bool = True):
    # re-train two small populations and probe random interpolations
    from repro.configs.base import TrainConfig
    from repro.core.mixing import MixingConfig
    from repro.data import member_policies, sample_images, apply_policy, soft_cross_entropy
    from repro.models.cnn import ClassifierConfig, init_classifier
    from repro.train import train_population

    key = jax.random.key(11)
    ecfg = ExpConfig(model="mlp", width=64, depth=3, hw=12, noise=1.6,
                     steps=300 if quick else 800, lr=0.15, population=3)
    task = make_image_task(jax.random.fold_in(key, 1), ecfg.num_classes,
                           ecfg.hw, ecfg.noise)
    ccfg = ClassifierConfig(kind="mlp", width=ecfg.width, depth=ecfg.depth,
                            num_classes=ecfg.num_classes, image_hw=ecfg.hw)
    pols = member_policies(jax.random.fold_in(key, 7), ecfg.population, True)

    def data_fn(m, step, k):
        imgs, labels = sample_images(task, k, ecfg.batch_size)
        x, y = apply_policy(jax.random.fold_in(k, 1), imgs, labels,
                            ecfg.num_classes, pols[m])
        return {"x": x, "y": y}

    def loss_fn(params, batch):
        return soft_cross_entropy(apply_classifier(params, ccfg, batch["x"]),
                                  batch["y"])

    tcfg = TrainConfig(population=ecfg.population, optimizer="sgd", lr=ecfg.lr,
                       total_steps=ecfg.steps, batch_size=ecfg.batch_size)
    ex, ey = eval_images(task, jax.random.fold_in(key, 99), 512)
    apply_fn = lambda p, x: apply_classifier(p, ccfg, x)

    rows = []
    for name in ("baseline", "wash"):
        t0 = time.perf_counter()
        res = train_population(key, lambda k: init_classifier(k, ccfg),
                               loss_fn, data_fn, tcfg, METHODS[name],
                               ccfg.num_blocks, record_every=150)
        accs = []
        for i in range(8 if quick else 25):
            w = jax.random.dirichlet(jax.random.fold_in(key, 100 + i),
                                     jnp.ones(ecfg.population))
            m = avg.interpolate(res.population, w)
            accs.append(float(avg.model_accuracy(apply_fn, m, ex, ey)))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"fig6_interp_{name}",
            us,
            fmt({"min_acc": min(accs), "mean_acc": sum(accs) / len(accs),
                 "max_acc": max(accs), "chance": 1.0 / ecfg.num_classes}),
        ))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
