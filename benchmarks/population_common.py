"""Shared driver for the CPU-scale population experiments (Tables 2–3,
Fig. 2, Fig. 5, Tab. 4 reproductions).

The paper's CIFAR/ImageNet runs are replaced by reduced-width members of
the same model families on a synthetic Gaussian-mixture image task (no
datasets ship in this container) — the validation targets are the
*patterns*: Baseline averages at chance, WASH averages ≈ its ensemble,
WASH beats PAPA at a fraction of the communication.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import averaging as avg
from repro.core.mixing import MixingConfig
from repro.data import (
    apply_policy,
    eval_images,
    make_image_task,
    member_policies,
    sample_images,
    soft_cross_entropy,
)
from repro.models.cnn import ClassifierConfig, apply_classifier, init_classifier
from repro.train import train_population


@dataclasses.dataclass(frozen=True)
class ExpConfig:
    model: str = "resnet"  # resnet | vgg | mlp
    width: int = 24
    depth: int = 3
    num_classes: int = 10
    hw: int = 12
    noise: float = 1.6
    population: int = 3
    steps: int = 400
    batch_size: int = 48
    lr: float = 0.1
    heterogeneous: bool = True
    seed: int = 0


def run_experiment(mcfg: MixingConfig, ecfg: ExpConfig,
                   record_every: int = 50) -> Dict[str, object]:
    key = jax.random.key(ecfg.seed)
    task = make_image_task(jax.random.fold_in(key, 1), ecfg.num_classes,
                           ecfg.hw, ecfg.noise)
    ccfg = ClassifierConfig(kind=ecfg.model, width=ecfg.width, depth=ecfg.depth,
                            num_classes=ecfg.num_classes, image_hw=ecfg.hw)
    pols = member_policies(jax.random.fold_in(key, 7), ecfg.population,
                           ecfg.heterogeneous)

    def data_fn(m, step, k):
        imgs, labels = sample_images(task, k, ecfg.batch_size)
        x, y = apply_policy(jax.random.fold_in(k, 1), imgs, labels,
                            ecfg.num_classes, pols[m])
        return {"x": x, "y": y}

    def loss_fn(params, batch):
        return soft_cross_entropy(
            apply_classifier(params, ccfg, batch["x"]), batch["y"]
        )

    tcfg = TrainConfig(population=ecfg.population, optimizer="sgd", lr=ecfg.lr,
                       total_steps=ecfg.steps, batch_size=ecfg.batch_size,
                       weight_decay=1e-4, seed=ecfg.seed)
    res = train_population(
        key, lambda k: init_classifier(k, ccfg), loss_fn, data_fn,
        tcfg, mcfg, ccfg.num_blocks, record_every=record_every,
    )

    ex, ey = eval_images(task, jax.random.fold_in(key, 99), 512)
    vx, vy = eval_images(task, jax.random.fold_in(key, 98), 256)  # val (greedy)
    apply_fn = lambda p, x: apply_classifier(p, ccfg, x)

    ens = float(avg.ensemble_accuracy(apply_fn, res.population, ex, ey))
    soup = float(avg.model_accuracy(apply_fn, avg.uniform_soup(res.population), ex, ey))
    greedy = float(
        avg.model_accuracy(apply_fn, avg.greedy_soup(apply_fn, res.population, vx, vy),
                           ex, ey)
    )
    members = avg.member_accuracies(apply_fn, res.population, ex, ey)
    return {
        "ensemble": ens,
        "averaged": soup,
        "greedy": greedy,
        "best_member": float(jnp.max(members)),
        "worst_member": float(jnp.min(members)),
        "consensus": res.history["consensus"],
        "steps_rec": res.history["step"],
        "loss": res.history["loss"][-1],
        "comm_scalars": res.comm_scalars,
        "chance": 1.0 / ecfg.num_classes,
    }


# PAPA's EMA coefficient is horizon-dependent (the paper anneals it with
# the lr over 300 epochs); at our ~400-step horizon α=0.95 per T=10 steps
# matches the paper's "strong pull" regime (total contraction ≈ 0.95^40).
METHODS = {
    "baseline": MixingConfig(kind="none"),
    "papa": MixingConfig(kind="papa", papa_every=10, papa_alpha=0.95),
    "papa_all": MixingConfig(kind="papa_all", papa_all_every=50),
    "wash": MixingConfig(kind="wash", base_p=0.05, mode="dense"),
    "wash_opt": MixingConfig(kind="wash_opt", base_p=0.05, mode="dense"),
}
