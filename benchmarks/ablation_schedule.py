"""Paper Tab. 4: layer-wise probability schedule ablation
(decreasing — the paper's default — vs constant vs increasing)."""

from __future__ import annotations

import time

from repro.core.mixing import MixingConfig

from benchmarks._util import fmt
from benchmarks.population_common import ExpConfig, run_experiment


def run(quick: bool = True):
    ecfg = ExpConfig(model="mlp", width=64, depth=3, hw=12, noise=1.6,
                     steps=300 if quick else 800, lr=0.15)
    rows = []
    for schedule in ("decreasing", "constant", "increasing"):
        mcfg = MixingConfig(kind="wash", base_p=0.05, mode="dense",
                            schedule=schedule)
        t0 = time.perf_counter()
        m = run_experiment(mcfg, ecfg, record_every=150)
        us = (time.perf_counter() - t0) * 1e6 / ecfg.steps
        rows.append((
            f"tab4_{schedule}",
            us,
            fmt({"ensemble": m["ensemble"], "averaged": m["averaged"],
                 "greedy": m["greedy"], "best": m["best_member"],
                 "worst": m["worst_member"], "comm": m["comm_scalars"]}),
        ))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
