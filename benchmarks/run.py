"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Default is the quick suite
(~15 min on 1 CPU core); pass --full for the long versions and --only to
select modules.

  table1  comm_volume            (paper Tab. 1, analytic + HLO-measured)
  table2  accuracy_heterogeneous (paper Tab. 2 pattern)
  table3  accuracy_homogeneous   (paper Tab. 3 pattern)
  fig2    consensus_distance     (paper Fig. 2)
  fig3    toy2d                  (paper Fig. 3)
  fig5a   ablation_probability   (paper Fig. 5a)
  fig5b   ablation_start_stop    (paper Fig. 5b)
  tab4    ablation_schedule      (paper Tab. 4)
  kernels kernels_bench          (Pallas kernels, interpret mode)
  serving serving_bench          (old-loop vs scan decode, soup vs ensemble)
  roofline roofline              (deliverable g, from dry-run JSONs)
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks._util import print_rows

MODULES = {
    "table1": "benchmarks.comm_volume",
    "table2": "benchmarks.accuracy_heterogeneous",
    "table3": "benchmarks.accuracy_homogeneous",
    "fig2": "benchmarks.consensus_distance",
    "fig3": "benchmarks.toy2d",
    "fig5a": "benchmarks.ablation_probability",
    "fig5b": "benchmarks.ablation_start_stop",
    "tab4": "benchmarks.ablation_schedule",
    "fig6": "benchmarks.interpolation_heatmap",
    "kernels": "benchmarks.kernels_bench",
    "serving": "benchmarks.serving_bench",
    "roofline": "benchmarks.roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()

    names = list(MODULES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failed = False
    for name in names:
        modname = MODULES[name]
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run(quick=not args.full)
            print_rows(rows)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failed = True
            print(f"# {name} FAILED:\n" + traceback.format_exc(), file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
