"""Paper Fig. 2: average distance to consensus during training.

Targets: WASH's distance stays BELOW the baseline's (averaging works) but
ABOVE PAPA's / PAPA-all's (diversity preserved) — the paper's central
diversity/averageability trade-off."""

from __future__ import annotations

import time

from benchmarks._util import fmt
from benchmarks.population_common import METHODS, ExpConfig, run_experiment


def run(quick: bool = True):
    ecfg = ExpConfig(model="mlp", width=64, depth=3, hw=12, noise=1.6,
                     steps=300 if quick else 800, lr=0.15)
    rows = []
    finals = {}
    for name in ("baseline", "papa", "papa_all", "wash"):
        t0 = time.perf_counter()
        m = run_experiment(METHODS[name], ecfg, record_every=50)
        us = (time.perf_counter() - t0) * 1e6 / ecfg.steps
        finals[name] = m["consensus"][-1]
        trace = ",".join(f"{c:.2f}" for c in m["consensus"])
        rows.append((f"fig2_consensus_{name}", us,
                     fmt({"final": m["consensus"][-1]}) + f";trace={trace}"))
    ordered = (finals["papa_all"] <= finals["papa"] + 1e-6
               and finals["papa"] <= finals["wash"]
               and finals["wash"] <= finals["baseline"])
    rows.append(("fig2_ordering_papaall<=papa<=wash<=baseline", 0.0,
                 fmt({"holds": int(ordered)})))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
