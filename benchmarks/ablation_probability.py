"""Paper Fig. 5a: base-probability sweep — the phase transition.

Below a critical p the Averaged model is no better than the Baseline's
averaged model; above it, Averaged ≈ Ensemble.  The paper also notes
resilience even at p = 1."""

from __future__ import annotations

import dataclasses
import time

from repro.core.mixing import MixingConfig

from benchmarks._util import fmt
from benchmarks.population_common import ExpConfig, run_experiment

PROBS_QUICK = (0.0001, 0.01, 0.05, 1.0)
PROBS_FULL = (0.0001, 0.001, 0.005, 0.01, 0.05, 0.2, 1.0)


def run(quick: bool = True):
    ecfg = ExpConfig(model="mlp", width=64, depth=3, hw=12, noise=1.6,
                     steps=300 if quick else 800, lr=0.15)
    rows = []
    for p in (PROBS_QUICK if quick else PROBS_FULL):
        mcfg = MixingConfig(kind="wash", base_p=p, mode="dense")
        t0 = time.perf_counter()
        m = run_experiment(mcfg, ecfg, record_every=150)
        us = (time.perf_counter() - t0) * 1e6 / ecfg.steps
        rows.append((
            f"fig5a_p={p}",
            us,
            fmt({"ensemble": m["ensemble"], "averaged": m["averaged"],
                 "gap": m["ensemble"] - m["averaged"],
                 "consensus": m["consensus"][-1]}),
        ))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
