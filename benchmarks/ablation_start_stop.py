"""Paper Fig. 5b: shuffle-window ablation.

Stopping the shuffle early costs less Averaged accuracy than starting it
late — WASH matters most early in training, before models commit to
basins."""

from __future__ import annotations

import time

from repro.core.mixing import MixingConfig

from benchmarks._util import fmt
from benchmarks.population_common import ExpConfig, run_experiment


def run(quick: bool = True):
    steps = 300 if quick else 800
    half = steps // 2
    ecfg = ExpConfig(model="mlp", width=64, depth=3, hw=12, noise=1.6,
                     steps=steps, lr=0.15)
    windows = {
        "always": (0, None),
        "stop_half": (0, half),
        "start_half": (half, None),
    }
    rows = []
    results = {}
    for name, (start, stop) in windows.items():
        mcfg = MixingConfig(kind="wash", base_p=0.05, mode="dense",
                            start_step=start, stop_step=stop)
        t0 = time.perf_counter()
        m = run_experiment(mcfg, ecfg, record_every=150)
        us = (time.perf_counter() - t0) * 1e6 / steps
        results[name] = m
        rows.append((
            f"fig5b_{name}",
            us,
            fmt({"ensemble": m["ensemble"], "averaged": m["averaged"],
                 "gap": m["ensemble"] - m["averaged"]}),
        ))
    # paper claim: early shuffling matters more -> stop_half degrades less
    gap_stop = results["stop_half"]["ensemble"] - results["stop_half"]["averaged"]
    gap_start = results["start_half"]["ensemble"] - results["start_half"]["averaged"]
    rows.append(("fig5b_early_more_important", 0.0,
                 fmt({"gap_stop_half": gap_stop, "gap_start_half": gap_start,
                      "holds": int(gap_stop <= gap_start + 0.02)})))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
