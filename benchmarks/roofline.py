"""Roofline table (deliverable g): reads the dry-run JSONs and prints, per
(arch × shape × mesh), the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS, and a one-line lever on the dominant term."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

LEVERS = {
    "compute_s": "raise arithmetic intensity (larger per-chip tiles, fuse "
                 "small ops, bf16 everywhere)",
    "memory_s": "cut HBM traffic: blockwise/flash attention (no S×S scores), "
                "remat instead of storing, fuse elementwise chains",
    "collective_s": "reshard: overlap grad all-reduce with backward, "
                    "reduce-scatter instead of all-reduce, keep activations "
                    "on fewer axes",
}


def load(out_dir: str = "benchmarks/dryrun") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(p))
        r["_file"] = os.path.basename(p)
        recs.append(r)
    return recs


def run(quick: bool = True, out_dir: str = "benchmarks/dryrun"):
    rows = []
    for r in load(out_dir):
        tag = r["_file"].replace(".json", "")
        if r.get("status") == "skip":
            rows.append((f"roofline_{tag}", 0.0, f"status=skip;note={r['note']}"))
            continue
        if r.get("status") != "ok":
            rows.append((f"roofline_{tag}", 0.0, f"status=ERROR;err={r.get('error','?')}"))
            continue
        dom = r["dominant"]
        derived = (
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};dominant={dom};"
            f"model_flops={r['model_flops']:.3e};"
            f"useful_ratio={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)};"
            f"lever={LEVERS[dom]}"
        )
        rows.append((f"roofline_{tag}", 0.0, derived))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
