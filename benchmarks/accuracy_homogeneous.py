"""Paper Table 3: the homogeneous setting — no augmentations; members
differ only through data order.  Same pattern targets as Table 2."""

from __future__ import annotations

import time

from benchmarks._util import fmt
from benchmarks.population_common import METHODS, ExpConfig, run_experiment


def run(quick: bool = True):
    ecfg = ExpConfig(model="mlp", width=64, depth=3, hw=12, noise=1.6,
                     steps=400 if quick else 1000, lr=0.15, heterogeneous=False)
    rows = []
    for name in ("baseline", "papa", "wash"):
        t0 = time.perf_counter()
        m = run_experiment(METHODS[name], ecfg, record_every=200)
        us = (time.perf_counter() - t0) * 1e6 / ecfg.steps
        rows.append((
            f"table3_hom_{name}",
            us,
            fmt({"ensemble": m["ensemble"], "averaged": m["averaged"],
                 "greedy": m["greedy"], "consensus": m["consensus"][-1],
                 "comm": m["comm_scalars"]}),
        ))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
