"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-scale
timings only; the derived column reports achieved GB/s / GFLOP/s against
the jnp reference implementation on the same shapes)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from benchmarks._util import Row, fmt, time_fn

KEY = jax.random.key(0)


def run(quick: bool = True):
    rows = []

    # wash_shuffle: one stacked (N, D) leaf
    n, d = 5, 1 << 18
    x = jax.random.normal(KEY, (n, d), jnp.float32)
    perm = jnp.argsort(jax.random.uniform(jax.random.fold_in(KEY, 1), (n, d)), 0).astype(jnp.int32)
    mask = jax.random.bernoulli(jax.random.fold_in(KEY, 2), 0.05, (d,))
    us_k = time_fn(lambda: ops.wash_shuffle(x, perm, mask, block_d=4096), iters=3)
    us_r = time_fn(jax.jit(lambda: ref.wash_shuffle_ref(x, perm, mask)), iters=3)
    bytes_moved = (x.size * 4 * 2) + perm.size * 4 + mask.size
    rows.append(("kernel_wash_shuffle", us_k,
                 fmt({"ref_us": us_r, "bytes": bytes_moved,
                      "interp_gbps": bytes_moved / us_k / 1e3})))

    # flash attention: prefill-like block
    B, S, H, KV, hd = 1, 512, 4, 2, 64
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, KV, hd), jnp.float32)
    us_k = time_fn(lambda: ops.flash_attention(q, k, v, block_q=128, block_k=128), iters=3)
    us_r = time_fn(jax.jit(lambda: ref.flash_attention_ref(q, k, v)), iters=3)
    flops = 4 * B * H * S * S * hd / 2  # causal
    rows.append(("kernel_flash_attention", us_k,
                 fmt({"ref_us": us_r, "flops": flops,
                      "interp_gflops": flops / us_k / 1e3})))

    # rwkv6 scan
    B, T, H, hd = 1, 256, 4, 64
    r = jax.random.normal(KEY, (B, T, H, hd), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(KEY, 5), (B, T, H, hd), jnp.float32)
    vv = jax.random.normal(jax.random.fold_in(KEY, 6), (B, T, H, hd), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 7), (B, T, H, hd)))
    u = jax.random.normal(jax.random.fold_in(KEY, 8), (H, hd)) * 0.1
    us_k = time_fn(lambda: ops.rwkv6_scan(r, kk, vv, w, u, chunk=32), iters=3)
    us_r = time_fn(jax.jit(lambda: ref.rwkv6_scan_ref(r, kk, vv, w, u)), iters=3)
    flops = 4 * B * T * H * hd * hd
    rows.append(("kernel_rwkv6_scan", us_k,
                 fmt({"ref_us": us_r, "flops": flops})))
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
