"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-scale
timings only; the derived column reports achieved GB/s / GFLOP/s against
the jnp reference implementation on the same shapes).

Also times the fused shard_map training engine against the two-jit vmap
reference on the same tiny population (dispatch overhead + fusion win is
host-side, so it is measurable even on CPU), and mirrors every row into
``benchmarks/out/kernels_bench.json`` for downstream tooling.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from benchmarks._util import (Row, fmt, time_fn, tiny_engine_problem,
                              with_provenance)

KEY = jax.random.key(0)

JSON_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "out", "kernels_bench.json")


def _engine_step_rows(steps: int = 16):
    """Fused single-dispatch chunk (the engine's own ``make_fused_chunk_fn``,
    so the published timing measures the shipped body) vs the reference's
    2 jits/step."""
    from jax.sharding import PartitionSpec as P

    from repro.core import population as pop
    from repro.core.layer_index import infer_layer_ids, total_layers
    from repro.core.mixing import MixingConfig, mix_once
    from repro.launch.mesh import make_host_ensemble_mesh
    from repro.optim import make_optimizer
    from repro.train.engine import make_fused_chunk_fn

    n, B = 4, 8
    din, dout, init, loss_fn = tiny_engine_problem()
    mcfg = MixingConfig(kind="wash", base_p=0.1, mode="bucketed")
    key = jax.random.key(0)

    population = pop.init_population(init, key, n, same_init=False)
    lids = infer_layer_ids(pop.member(population, 0), 1)
    tl = total_layers(1)
    opt_init, opt_update = make_optimizer("sgd")
    opt_state = jax.vmap(opt_init)(population)
    lr = jnp.float32(0.05)
    batches = {
        "x": jax.random.normal(jax.random.fold_in(key, 1), (steps, n, B, din)),
        "y": jax.random.normal(jax.random.fold_in(key, 2), (steps, n, B, dout)),
    }
    keydata = jnp.stack([
        jax.random.key_data(jax.random.fold_in(key, 100 + t)) for t in range(steps)
    ])
    gates = jnp.ones((steps,), jnp.float32)

    def one(pm, sm, bm):
        loss, g = jax.value_and_grad(loss_fn)(pm, bm)
        p2, s2 = opt_update(pm, g, sm, lr)
        return p2, s2, loss

    # --- unfused reference: 2 dispatches per step, Python step loop -------
    @jax.jit
    def train_step(p, s, b):
        return jax.vmap(one)(p, s, b)

    @jax.jit
    def mix_step(p, s, kd):
        return mix_once(jax.random.wrap_key_data(kd), p, s, mcfg, lids, tl)

    def unfused(p, s):
        for t in range(steps):
            b = {k: v[t] for k, v in batches.items()}
            p, s, _ = train_step(p, s, b)
            p, s, _ = mix_step(p, s, keydata[t])
        return p

    # --- fused engine chunk: one dispatch for all steps (the engine's own
    # builder; donate=False so timing iterations can reuse their inputs) ---
    mesh = make_host_ensemble_mesh(n)
    lrs = jnp.full((steps,), lr)
    n_valid = jnp.asarray(steps, jnp.int32)
    pspec = jax.tree_util.tree_map(lambda _: P("ens"), population)
    ospec = jax.tree_util.tree_map(lambda _: P("ens"), opt_state)
    bspec = jax.tree_util.tree_map(lambda _: P(None, "ens"), batches)
    fused = make_fused_chunk_fn(
        mesh, mcfg, lids, tl, opt_update, loss_fn, pspec, ospec, bspec,
        donate=False,
    )

    us_unfused = time_fn(lambda: unfused(population, opt_state), iters=3,
                         name="engine_unfused_step")
    us_fused = time_fn(
        lambda: fused(population, opt_state, batches, lrs, keydata, gates,
                      n_valid),
        iters=3, name="engine_fused_chunk",
    )
    per_un, per_fu = us_unfused / steps, us_fused / steps
    return [
        ("engine_unfused_step", per_un,
         fmt({"dispatches_per_step": 2, "steps": steps})),
        ("engine_fused_step", per_fu,
         fmt({"dispatches_per_step": 1.0 / steps, "steps": steps,
              "speedup_vs_unfused": per_un / per_fu})),
    ]


def _staging_and_compile_rows(steps: int = 24):
    """End-to-end fused engine wall clock: double-buffered async staging
    vs synchronous per-chunk staging, plus the run's compile count (the
    padded scheduler must trace each variant exactly once)."""
    import time as _time

    import jax.numpy as jnp

    from repro.configs.base import TrainConfig
    from repro.core.mixing import MixingConfig
    from repro.train import engine as engine_mod
    from repro.train.engine import build_schedule, train_population_sharded

    key = jax.random.key(0)
    n, B = 4, 8
    din, dout, init, loss_fn = tiny_engine_problem()

    def data_fn(m, step, k):
        return {"x": jax.random.normal(k, (B, din)),
                "y": jax.random.normal(jax.random.fold_in(k, 1), (B, dout))}

    tcfg = TrainConfig(population=n, optimizer="sgd", lr=0.05,
                       total_steps=steps, batch_size=8)
    mcfg = MixingConfig(kind="wash", base_p=0.1, mode="bucketed")

    def run(async_staging):
        engine_mod.reset_chunk_trace_count()
        t0 = _time.time()
        train_population_sharded(
            key, init, loss_fn, data_fn, tcfg, mcfg, 1, record_every=4,
            async_staging=async_staging,
        )
        return (_time.time() - t0) * 1e6, engine_mod.chunk_trace_count()

    run(True)  # warm backend/dispatch state; each run still compiles fresh
    us_sync, _ = run(False)
    us_async, traces = run(True)
    us_auto, _ = run(None)  # default path: engine.resolve_async_staging
    sched = build_schedule(steps, 4, mcfg)
    variants = len(sched.variants())
    resolved = engine_mod.resolve_async_staging(None, sched.chunks)
    # the tri-state default must never pick the losing mode: loose 1.25x
    # bound against the WORSE forced mode so wall-clock noise (runs are a
    # few seconds, compile included) cannot flake the guard while a gate
    # that resolves backwards still trips it
    assert us_auto <= max(us_sync, us_async) * 1.25, (
        f"auto staging gate ({us_auto / 1e6:.2f}s, resolved "
        f"async={resolved}) slower than both forced modes "
        f"(sync {us_sync / 1e6:.2f}s, async {us_async / 1e6:.2f}s)"
    )
    # CPU caveat: both walls include the per-run compile, and the staging
    # thread competes with XLA for the same cores here — the overlap pays
    # off on a real accelerator, where the device executes while the host
    # stages; this row exists to track the trend and the compile count.
    return [
        ("engine_run_sync_staging", us_sync / steps,
         fmt({"steps": steps, "record_every": 4})),
        ("engine_run_async_staging", us_async / steps,
         fmt({"steps": steps, "record_every": 4,
              "speedup_vs_sync": us_sync / us_async,
              "chunk_traces": traces, "schedule_variants": variants,
              "padded_steps": sched.num_padded_steps()})),
        ("engine_run_auto_staging", us_auto / steps,
         fmt({"steps": steps, "record_every": 4,
              "resolved_async": int(resolved),
              "speedup_vs_sync": us_sync / us_auto})),
    ]


def _pipeline_rows(steps: int = 16):
    """Microbatched GPipe engine vs the single-shot fused step on the same
    toy population.  On this host's mesh (1-device CPU degenerates to
    S=1), ``microbatches=1`` delegates to the single-stage engine — the
    baseline — while ``microbatches=M`` pays the M+S-1-tick schedule, so
    the ratio is the measured bubble + scheduling overhead the pipeline
    trades for 1/S per-chip memory at scale."""
    import time as _time

    from jax import lax

    from repro.configs.base import TrainConfig
    from repro.core.mixing import MixingConfig
    from repro.train import StageFns, train_population_pipelined

    L, DIN, D, DOUT, B, n = 4, 16, 8, 4, 8, 4

    def init(k):
        ks = jax.random.split(k, 3)
        return {"embed": {"w": jax.random.normal(ks[0], (DIN, D)) * 0.3},
                "blocks": {"w1": jax.random.normal(ks[1], (L, D, D)) * 0.3},
                "head": {"w": jax.random.normal(ks[2], (D, DOUT)) * 0.3}}

    def embed_fn(p, b):
        return b["x"] @ p["embed"]["w"]

    def blocks_fn(p, x):
        def body(h, wl):
            return jnp.tanh(h @ wl) + h, None
        h, _ = lax.scan(body, x, p["blocks"]["w1"])
        return h

    def head_fn(p, x, b):
        return jnp.mean((x @ p["head"]["w"] - b["y"]) ** 2)

    def data_fn(m, step, k):
        kx, ky = jax.random.split(k)
        return {"x": jax.random.normal(kx, (B, DIN)),
                "y": jax.random.normal(ky, (B, DOUT))}

    fns = StageFns(embed_fn, blocks_fn, head_fn)
    tcfg = TrainConfig(population=n, optimizer="sgd", lr=0.05,
                       total_steps=steps, batch_size=B, seq_len=1, seed=0)
    mcfg = MixingConfig(kind="wash", base_p=0.1, mode="bucketed")
    key = jax.random.key(0)

    def run(micro):
        t0 = _time.time()
        train_population_pipelined(
            key, init, fns, data_fn, tcfg, mcfg, L,
            record_every=max(steps // 2, 1), microbatches=micro)
        return (_time.time() - t0) * 1e6

    run(1)  # warm dispatch state; each timed run still compiles fresh
    us_single = run(1)
    us_micro = run(4)
    from repro.launch.mesh import make_host_mesh
    S = int(make_host_mesh(n, "ens_pp").shape["pipe"])
    return [
        ("engine_pipelined_single_shot", us_single / steps,
         fmt({"steps": steps, "microbatches": 1, "stages": S})),
        ("engine_pipelined_microbatched", us_micro / steps,
         fmt({"steps": steps, "microbatches": 4, "stages": S,
              "ticks_per_step": 4 + S - 1,
              "overhead_vs_single_shot": us_micro / us_single})),
    ]


def _write_json(rows):
    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    by_name = {name: {"us_per_call": us, "derived": derived}
               for name, us, derived in rows}
    report = {
        "rows": by_name,
        "engine_fused_step_us": by_name.get("engine_fused_step", {}).get("us_per_call"),
        "engine_unfused_step_us": by_name.get("engine_unfused_step", {}).get("us_per_call"),
        "engine_run_sync_staging_us_per_step": by_name.get(
            "engine_run_sync_staging", {}).get("us_per_call"),
        "engine_run_async_staging_us_per_step": by_name.get(
            "engine_run_async_staging", {}).get("us_per_call"),
    }
    with open(JSON_OUT, "w") as f:
        json.dump(with_provenance(report), f, indent=2)


def run(quick: bool = True):
    rows = []

    # wash_shuffle: one stacked (N, D) leaf
    n, d = 5, 1 << 18
    x = jax.random.normal(KEY, (n, d), jnp.float32)
    perm = jnp.argsort(jax.random.uniform(jax.random.fold_in(KEY, 1), (n, d)), 0).astype(jnp.int32)
    mask = jax.random.bernoulli(jax.random.fold_in(KEY, 2), 0.05, (d,))
    us_k = time_fn(lambda: ops.wash_shuffle(x, perm, mask, block_d=4096),
                   iters=3, name="kernel_wash_shuffle")
    us_r = time_fn(jax.jit(lambda: ref.wash_shuffle_ref(x, perm, mask)), iters=3)
    bytes_moved = (x.size * 4 * 2) + perm.size * 4 + mask.size
    rows.append(("kernel_wash_shuffle", us_k,
                 fmt({"ref_us": us_r, "bytes": bytes_moved,
                      "interp_gbps": bytes_moved / us_k / 1e3})))

    # bucketed_shuffle: same stacked leaf, TPU-native index-plan mode
    from repro.core import shuffle as shf
    idx = shf.bucketed_plan(jax.random.fold_in(KEY, 9), d, n, 0.05)
    us_k = time_fn(lambda: ops.bucketed_shuffle(x, idx, block_d=4096), iters=3)
    # jit over real arguments so XLA cannot constant-fold the reference away
    us_r = time_fn(jax.jit(shf.bucketed_apply_stacked), x, idx, iters=3)
    rows.append(("kernel_bucketed_shuffle", us_k,
                 fmt({"ref_us": us_r, "selected": idx.size,
                      "sent_per_member": idx.shape[1] * (n - 1)})))

    # the SHIPPED stacked apply path behind --pallas-shuffle (mix_once /
    # apply_plan_stacked): fused kernel vs the N-1-round roll path on the
    # same population pytree (bitwise-equal; tests/test_kernels.py)
    pop_tree = {"w": x}
    plan_tree = {"w": idx}
    us_roll = time_fn(
        jax.jit(lambda p_, t_: shf.apply_plan_stacked(t_, p_, "bucketed")),
        pop_tree, plan_tree, iters=3)
    us_pal = time_fn(
        jax.jit(lambda p_, t_: shf.apply_plan_stacked(
            t_, p_, "bucketed", use_pallas=True)),
        pop_tree, plan_tree, iters=3)
    rows.append(("stacked_apply_roll", us_roll,
                 fmt({"n": n, "d": d, "rounds": n - 1})))
    rows.append(("stacked_apply_pallas", us_pal,
                 fmt({"n": n, "d": d, "hbm_passes": 1,
                      "speedup_vs_roll": us_roll / us_pal})))

    # flash attention: prefill-like block
    B, S, H, KV, hd = 1, 512, 4, 2, 64
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, KV, hd), jnp.float32)
    us_k = time_fn(lambda: ops.flash_attention(q, k, v, block_q=128, block_k=128), iters=3)
    us_r = time_fn(jax.jit(lambda: ref.flash_attention_ref(q, k, v)), iters=3)
    flops = 4 * B * H * S * S * hd / 2  # causal
    rows.append(("kernel_flash_attention", us_k,
                 fmt({"ref_us": us_r, "flops": flops,
                      "interp_gflops": flops / us_k / 1e3})))

    # rwkv6 scan
    B, T, H, hd = 1, 256, 4, 64
    r = jax.random.normal(KEY, (B, T, H, hd), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(KEY, 5), (B, T, H, hd), jnp.float32)
    vv = jax.random.normal(jax.random.fold_in(KEY, 6), (B, T, H, hd), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 7), (B, T, H, hd)))
    u = jax.random.normal(jax.random.fold_in(KEY, 8), (H, hd)) * 0.1
    us_k = time_fn(lambda: ops.rwkv6_scan(r, kk, vv, w, u, chunk=32), iters=3)
    us_r = time_fn(jax.jit(lambda: ref.rwkv6_scan_ref(r, kk, vv, w, u)), iters=3)
    flops = 4 * B * T * H * hd * hd
    rows.append(("kernel_rwkv6_scan", us_k,
                 fmt({"ref_us": us_r, "flops": flops})))

    rows.extend(_engine_step_rows(steps=8 if quick else 32))
    rows.extend(_staging_and_compile_rows(steps=24 if quick else 96))
    rows.extend(_pipeline_rows(steps=8 if quick else 32))
    _write_json(rows)
    return rows


if __name__ == "__main__":
    from benchmarks._util import print_rows

    print_rows(run())
