"""Unit + property tests for the telemetry primitives (repro.obs).

The hypothesis layer (skipped when hypothesis isn't installed — it is a
dev-only dependency) explores the sample space for the histogram /
percentile invariants; the fixed-seed tests below pin the same
invariants on handcrafted inputs so CI without hypothesis still
exercises every branch.  The invariants:

  * merge is associative on everything percentiles read (counts, count,
    min, max — ``sum`` only to float rounding);
  * p50 <= p99 <= observed max, and every bucket percentile upper-bounds
    the exact sample percentile;
  * the exact (raw-sample) percentile matches numpy's default linear
    interpolation and guards the degenerate shapes summarize() hits.
"""

import json

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import Histogram, Registry

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

EDGES = (0.001, 0.01, 0.1, 1.0, 10.0)


def _hist(values, name="h"):
    h = Histogram(name, EDGES)
    for v in values:
        h.observe(v)
    return h


# ---------------------------------------------------------------------------
# fixed-seed invariants (always run)
# ---------------------------------------------------------------------------


def _check_merge_associative(a, b, c):
    ab_c = _hist(a).merge(_hist(b)).merge(_hist(c))
    a_bc = _hist(a).merge(_hist(b).merge(_hist(c)))
    assert ab_c.counts == a_bc.counts
    assert ab_c.count == a_bc.count == len(a) + len(b) + len(c)
    assert ab_c.min == a_bc.min and ab_c.max == a_bc.max
    assert ab_c.sum == pytest.approx(a_bc.sum, rel=1e-12, abs=1e-15)
    # merged percentiles equal observing everything into one histogram
    one = _hist(list(a) + list(b) + list(c))
    for q in (0, 50, 90, 99, 100):
        assert ab_c.percentile(q) == one.percentile(q)


def _check_percentile_bounds(values):
    h = _hist(values)
    if not values:
        assert h.percentile(50) is None
        return
    p50, p99 = h.percentile(50), h.percentile(99)
    assert p50 <= p99 <= h.max
    ordered = sorted(values)
    for q in (10, 50, 90, 99):
        # the bucket estimate upper-bounds the nearest-rank percentile
        # (the rank-th order statistic) and never exceeds the observed max
        rank = max(1, min(len(ordered), -(-q * len(ordered) // 100)))
        assert ordered[int(rank) - 1] <= h.percentile(q) <= h.max
    # single sample: every q answers with that sample's bucket value
    h1 = _hist([values[0]])
    assert h1.percentile(0) == h1.percentile(50) == h1.percentile(100)


def test_merge_associative_fixed():
    rng = np.random.default_rng(0)
    for _ in range(10):
        parts = [rng.exponential(0.05, rng.integers(0, 30)).tolist()
                 for _ in range(3)]
        _check_merge_associative(*parts)
    _check_merge_associative([], [], [])          # all-empty merge
    _check_merge_associative([5.0], [], [1e9])    # overflow bucket


def test_percentile_bounds_fixed():
    rng = np.random.default_rng(1)
    for _ in range(10):
        _check_percentile_bounds(
            rng.exponential(0.05, rng.integers(1, 50)).tolist())
    _check_percentile_bounds([])
    _check_percentile_bounds([1e9, 2e9])          # overflow-only: max wins
    h = _hist([1e9, 2e9])
    assert h.percentile(99) == 2e9


if HAVE_HYPOTHESIS:
    class TestHypothesis:
        @settings(max_examples=50, deadline=None)
        @given(st.lists(st.floats(0, 100, allow_nan=False), max_size=30),
               st.lists(st.floats(0, 100, allow_nan=False), max_size=30),
               st.lists(st.floats(0, 100, allow_nan=False), max_size=30))
        def test_merge_associative(self, a, b, c):
            _check_merge_associative(a, b, c)

        @settings(max_examples=50, deadline=None)
        @given(st.lists(st.floats(1e-6, 1e6, allow_nan=False), min_size=1,
                        max_size=50))
        def test_percentile_bounds(self, values):
            _check_percentile_bounds(values)

        @settings(max_examples=50, deadline=None)
        @given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=2,
                        max_size=50),
               st.floats(0, 100))
        def test_exact_percentile_matches_numpy(self, values, q):
            assert obs.percentile(values, q) == pytest.approx(
                float(np.percentile(values, q)), rel=1e-9, abs=1e-9)


# ---------------------------------------------------------------------------
# exact percentile: degenerate cases summarize() depends on
# ---------------------------------------------------------------------------


def test_exact_percentile_degenerate():
    assert obs.percentile([], 50) is None
    assert obs.percentile([None, None], 99) is None
    assert obs.percentile([0.25], 0) == 0.25          # single sample
    assert obs.percentile([0.25], 99) == 0.25
    assert obs.percentile([None, 0.5, None, 0.1], 0) == 0.1
    assert obs.percentile_ms([0.5], 50) == 500.0
    vals = [0.3, 0.1, 0.9, 0.5, 0.2]
    for q in (0, 25, 50, 75, 99, 100):
        assert obs.percentile(vals, q) == pytest.approx(
            float(np.percentile(vals, q)), abs=1e-12)
    with pytest.raises(ValueError):
        obs.percentile([1.0], 101)


def test_summarize_samples():
    assert obs.summarize_samples([])["count"] == 0
    s = obs.summarize_samples([0.1, None, 0.3])
    assert s["count"] == 2 and s["min"] == 0.1 and s["max"] == 0.3


def test_driver_summarize_degenerate():
    """The migrated serving summary survives every fragile shape."""
    from repro.serving.driver import RequestMetrics, summarize

    # empty metrics dict
    s = summarize({})
    assert s["requests"] == 0 and s["ttft_p99_ms"] is None
    assert s["tokens_per_s"] is None
    # all-cancelled
    m = RequestMetrics(uid=0, arrival=0.0)
    m.cancelled, m.finished = True, 1.0
    s = summarize({0: m})
    assert s["requests"] == 0 and s["cancelled"] == 1
    # single request, zero generated tokens, no first token
    m2 = RequestMetrics(uid=1, arrival=0.0)
    m2.finished = 2.0
    s = summarize({1: m2})
    assert s["requests"] == 1
    assert s["ttft_p50_ms"] is None                  # no first token
    assert s["intertoken_p99_ms"] is None            # zero-token request
    assert s["latency_p99_ms"] == pytest.approx(2000.0)  # single-sample p99
    # one token: no gaps, but a TTFT
    m3 = RequestMetrics(uid=2, arrival=0.0)
    m3.first_token, m3.finished = 0.5, 1.0
    m3.token_times = [0.5]
    s = summarize({2: m3})
    assert s["ttft_p50_ms"] == pytest.approx(500.0)
    assert s["intertoken_p99_ms"] is None


# ---------------------------------------------------------------------------
# registry, sinks, counters, gauges
# ---------------------------------------------------------------------------


def test_registry_accessors_and_conflicts():
    r = Registry()
    c = r.counter("a.count")
    assert r.counter("a.count") is c
    c.inc(); c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    with pytest.raises(ValueError):
        r.gauge("a.count")  # type conflict
    r.gauge("g").set(7)
    assert r.snapshot()["g"]["value"] == 7.0
    assert r.names() == ["a.count", "g"]
    r.reset()
    assert r.names() == []


def test_counter_mirrors_float_accumulation():
    """inc-per-step reproduces a += accumulation bit-for-bit — the
    property the train engines' comm mirror depends on."""
    r = Registry()
    c = r.counter("comm")
    per = 0.1  # not exactly representable: order matters
    total = 0.0
    for _ in range(1000):
        total += per
        c.inc(per)
    assert c.value == total  # bitwise, not approx


def test_prometheus_text():
    r = Registry()
    r.counter("a.b").inc(2)
    r.histogram("lat", (0.1, 1.0)).observe(0.05)
    txt = r.prometheus_text()
    assert "# TYPE a_b counter" in txt
    assert "a_b 2" in txt
    assert 'lat_bucket{le="0.1"} 1' in txt
    assert 'lat_bucket{le="+Inf"} 1' in txt
    assert "lat_count 1" in txt


def test_histogram_validation():
    with pytest.raises(ValueError):
        Histogram("bad", ())
    with pytest.raises(ValueError):
        Histogram("bad", (1.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", EDGES).percentile(101)
    with pytest.raises(ValueError):
        Histogram("a", (1.0,)).merge(Histogram("b", (2.0,)))


def test_telemetry_sinks_and_events(tmp_path):
    tel = obs.Telemetry()
    mem = obs.MemorySink()
    tel.add_sink(mem)
    path = str(tmp_path / "out.jsonl")
    tel.add_sink(obs.JsonlSink(path))
    with tel.span("x.span", step=3):
        pass
    tel.event("x.event", foo=1)
    tel.record_compile("x_kind", shape=4)
    assert tel.registry.counter("compile.x_kind").value == 1
    assert tel.registry.histogram("x.span").count == 1
    tel.finalize()
    records = [json.loads(l) for l in open(path)]
    assert records[0]["kind"] == "provenance"
    kinds = {r["kind"] for r in records}
    assert {"span", "event", "compile", "metric"} <= kinds
    assert mem.named("x.event")[0]["foo"] == 1
    # and the stream passes the CI checker
    import subprocess, sys
    proc = subprocess.run(
        [sys.executable, "tools/check_metrics_schema.py", path],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


def test_disabled_telemetry_is_noop(tmp_path):
    tel = obs.Telemetry()
    mem = obs.MemorySink()
    tel.add_sink(mem)
    tel.enabled = False
    with tel.span("x"):
        pass
    tel.event("e")
    tel.record_compile("k")
    assert tel.registry.names() == []
    assert [r["kind"] for r in mem.records] == ["provenance"]


def test_configure_resets_default():
    tel = obs.configure(memory=True)
    assert tel is obs.get()
    tel.registry.counter("x").inc()
    obs.reset()
    assert obs.get().registry.names() == []
