"""Property tests for population-powered speculative decoding.

The contract under test (``serving/speculative.py``): at fp32 KV the
speculative continuous server is **bitwise identical** to the plain
(non-speculative) continuous server — token for token, for greedy AND
temperature sampling, for every draft length ``k`` in ``[1, 8]``, over
mixed-length streams whose staggered admissions put slots at different
progress inside one verify step.  On top of parity:

  * **zero-leak partition**: after a stream drains — through however
    many speculative rollbacks (``_grow`` lookahead then ``_shrink``) —
    free + LRU-parked + refcounted pages sum to the pool size and no
    page is still referenced;
  * **trace discipline**: one decode executable per (geometry, mode,
    greedy, draft_k) — the module tracks every distinct combination it
    has served and the cumulative trace counter must equal exactly that;
  * **budget clamping**: ``draft_k`` larger than a request's remaining
    budget never overruns ``max_new`` (``n_valid`` clamp).

The hypothesis layer (dev-only dependency) explores the stream space;
fixed-seed fallbacks below pin the same invariants on handcrafted worst
cases so CI without hypothesis still exercises every branch.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as M
from repro.serving import batching
from repro.serving.driver import RequestDriver
from repro.serving.speculative import MAX_DRAFT_K, speculative_supported

CFG = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                  d_ff=64, vocab_size=50, dtype="float32")
PARAMS = M.init_params(jax.random.key(0), CFG)
# a genuinely diverse population: the soup's drafts DO get rejected, so
# the rollback (_shrink) path runs on nearly every ensemble step
POPN = jax.vmap(lambda k: M.init_params(k, CFG))(
    jax.random.split(jax.random.key(1), 3))
# ONE pool geometry for the whole module; max_slots < stream length so
# admissions stagger and verify steps mix slots at different depths
PAGE_SIZE, MAX_SLOTS, NUM_PAGES = 4, 3, 64

#: every (ensemble, greedy, draft_k-or-None) combination served so far;
#: the decode trace counter must equal its size after every stream
_SEEN_PROGRAMS = set()


@pytest.fixture(scope="module", autouse=True)
def _fresh_module_cache():
    batching.clear_executable_cache()
    batching.reset_trace_counts()
    _SEEN_PROGRAMS.clear()
    yield
    batching.clear_executable_cache()


def _make_stream(seed, n):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, CFG.vocab_size,
                            (int(rng.integers(1, 18)),)).astype(np.int32)
               for _ in range(n)]
    max_news = [int(rng.integers(1, 9)) for _ in range(n)]
    return prompts, max_news


def _serve(prompts, max_news, *, mode, temperature, speculative,
           draft_k=4):
    params = POPN if mode == "ensemble" else PARAMS
    server = batching.ContinuousServer(
        params, CFG, mode=mode, temperature=temperature,
        page_size=PAGE_SIZE, max_slots=MAX_SLOTS, num_pages=NUM_PAGES,
        speculative=speculative, draft_k=draft_k)
    reqs = [batching.Request(uid, p, mn,
                             key=jax.random.key(1000 + uid))
            for uid, (p, mn) in enumerate(zip(prompts, max_news))]
    out = server.run(reqs)
    # jit traces on first CALL: a stream of max_new=1 requests retires
    # every slot at admission and never runs the decode program at all
    if server.stats["decode_steps"]:
        _SEEN_PROGRAMS.add((mode == "ensemble", temperature <= 0.0,
                            draft_k if speculative else None))
    assert batching.decode_trace_count() == len(_SEEN_PROGRAMS), (
        f"decode must compile once per (geometry, mode, greedy, draft_k): "
        f"{batching.decode_trace_count()} traces for "
        f"{len(_SEEN_PROGRAMS)} distinct programs")
    return out, server


def _check_parity_and_pool(prompts, max_news, *, mode, temperature,
                           draft_k):
    """The shared invariant harness: same stream through the plain and
    the speculative server, bitwise compare, then audit the pool."""
    plain, _ = _serve(prompts, max_news, mode=mode,
                      temperature=temperature, speculative=False)
    spec, server = _serve(prompts, max_news, mode=mode,
                          temperature=temperature, speculative=True,
                          draft_k=draft_k)
    assert sorted(spec) == sorted(plain)
    for uid in plain:
        np.testing.assert_array_equal(
            plain[uid].tokens, spec[uid].tokens,
            err_msg=f"uid {uid} (mode={mode}, T={temperature}, "
                    f"k={draft_k}): speculative decode diverged from the "
                    f"non-speculative oracle")
        # budget clamp: never a token past max_new, whatever draft_k
        assert (len(spec[uid].tokens)
                == len(prompts[uid]) + max_news[uid])

    # zero-leak partition after every grow/shrink cycle: free + parked +
    # refcounted pages account for the whole pool (page 0 is scratch)
    pool = server._pool
    assert not pool.refcount, f"leaked refcounts at drain: {pool.refcount}"
    assert (pool.free_count + pool.retained_count + len(pool.refcount)
            == NUM_PAGES - 1), "pool three-state invariant broken"
    return server


# ---------------------------------------------------------------------------
# hypothesis layer (dev-only dependency; fixed-seed tests below cover CI)
# ---------------------------------------------------------------------------

# NOT pytest.importorskip: that would skip the WHOLE module, including
# the fixed-seed fallback tests that must run on the base image
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    settings.register_profile(
        "ci", settings(max_examples=8, deadline=None, derandomize=True))
    if os.environ.get("HYPOTHESIS_PROFILE"):
        settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])

    SETTINGS = dict(max_examples=10, deadline=None)

    @st.composite
    def spec_cases(draw):
        n = draw(st.integers(1, 5))
        seed = draw(st.integers(0, 2**31 - 1))
        draft_k = draw(st.integers(1, MAX_DRAFT_K))
        mode = draw(st.sampled_from(["soup", "ensemble"]))
        temperature = draw(st.sampled_from([0.0, 0.8]))
        return n, seed, draft_k, mode, temperature

    @given(spec_cases())
    @settings(**SETTINGS)
    def test_random_streams_match_plain_decode_bitwise(case):
        n, seed, draft_k, mode, temperature = case
        prompts, max_news = _make_stream(seed, n)
        _check_parity_and_pool(prompts, max_news, mode=mode,
                               temperature=temperature, draft_k=draft_k)


# ---------------------------------------------------------------------------
# fixed-seed fallbacks: same harness, handcrafted worst cases, no
# hypothesis needed (these DO run on the base CI image)
# ---------------------------------------------------------------------------


def test_fixed_greedy_ensemble_stream_with_rollbacks():
    """Diverse population + greedy: drafts get rejected, so _shrink runs
    — and the stream must STILL be bitwise the plain ensemble's."""
    prompts, max_news = _make_stream(200, 5)
    server = _check_parity_and_pool(prompts, max_news, mode="ensemble",
                                    temperature=0.0, draft_k=4)
    st = server.stats
    assert st["spec_drafted"] > 0
    assert st["spec_accepted"] < st["spec_drafted"], (
        "a diverse population must reject some drafts, or this test "
        "isn't exercising the rollback path at all")


def test_fixed_temperature_sampling_stays_bitwise():
    """Sampled (T=0.8) decode is still deterministic per (key, step), so
    speculation must reproduce it bit-for-bit too."""
    prompts, max_news = _make_stream(201, 4)
    _check_parity_and_pool(prompts, max_news, mode="soup",
                           temperature=0.8, draft_k=3)


def test_fixed_draft_k_edges_and_budget_clamp():
    """k=1 (speculation degenerates to plain stepping) and k=8 against
    tiny budgets (every call clamps far below the draft length)."""
    prompts, _ = _make_stream(202, 4)
    _check_parity_and_pool(prompts, [1, 2, 1, 3], mode="soup",
                           temperature=0.0, draft_k=MAX_DRAFT_K)
    prompts, max_news = _make_stream(203, 3)
    _check_parity_and_pool(prompts, max_news, mode="ensemble",
                           temperature=0.0, draft_k=1)


def test_fixed_staggered_admissions_through_driver():
    """Chunked-prefill driver admissions land mid-stream: slots inside
    one verify step sit at different depths, some freshly admitted."""
    prompts, max_news = _make_stream(204, 6)
    reqs = [batching.Request(uid, p, mn)
            for uid, (p, mn) in enumerate(zip(prompts, max_news))]

    def drive(speculative):
        server = batching.ContinuousServer(
            POPN, CFG, mode="ensemble", page_size=PAGE_SIZE,
            max_slots=MAX_SLOTS, num_pages=NUM_PAGES,
            speculative=speculative, draft_k=4)
        driver = RequestDriver(server, prefill_chunk=4)
        for r in reqs:
            driver.submit(batching.Request(r.uid, r.tokens, r.max_new))
        return driver.drain(), server

    plain, _ = drive(False)
    spec, server = drive(True)
    for uid in plain:
        np.testing.assert_array_equal(plain[uid].tokens, spec[uid].tokens)
    pool = server._pool
    assert not pool.refcount
    assert (pool.free_count + pool.retained_count + len(pool.refcount)
            == NUM_PAGES - 1)


def test_speculative_rejects_unsupported_configs():
    moe_cfg = ModelConfig(num_layers=2, d_model=32, num_heads=4,
                          num_kv_heads=2, d_ff=64, vocab_size=50,
                          dtype="float32", moe=True, n_routed_experts=4,
                          top_k=2)
    assert speculative_supported(moe_cfg) is not None
    with pytest.raises(NotImplementedError, match="[Ss]peculative"):
        batching.ContinuousServer(
            M.init_params(jax.random.key(0), moe_cfg), moe_cfg,
            page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
            num_pages=NUM_PAGES, speculative=True)
