"""Pipeline-stage axis: planner budgets, microbatched training engine,
stage-split decode, and the pp-aware mesh constructors.

Host-side logic (mesh shapes, schedule arithmetic, stage assignment,
support gates) runs in-process on the 1-device pytest host; everything
that needs real stages runs in a subprocess with a forced multi-device
CPU host (jax locks the device count at first init), following
tests/test_distributed.py.

Contracts asserted here:
  * ``(E, 1, 1)`` pipeline mesh with one microbatch delegates to the
    fused engine bitwise (tokens, losses, params, comm);
  * ``S=4 / M=4`` GPipe schedule matches the single-stage engine to
    tolerance, with WASH comm ≤ the single-stage plan's;
  * staged decode is bitwise-identical to the unstaged serving engine
    (greedy + temperature), compiles once per shape, and its HLO moves
    activations only one stage forward per hop;
  * the shard-local WASH mixer on an (ens, pipe) mesh lowers to
    collective-permutes that stay inside stage rings (src ≡ tgt mod S);
  * mesh constructors survive prime device counts, degenerate to
    all-ones on 1 device, and reject bad --mesh-shape overrides loudly.
"""

import os
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.layer_index import (
    infer_layer_ids, stage_layer_bounds, stage_of_depth, total_layers,
)
from repro.train.schedule import num_pipeline_ticks, split_microbatch_sizes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def fake_mesh(**shape):
    return types.SimpleNamespace(axis_names=tuple(shape), shape=shape)


TINY = dict(name="tiny", d_model=32, d_ff=64, num_layers=4, num_heads=4,
            num_kv_heads=2, vocab_size=64, max_position=128)


# ---------------------------------------------------------------------------
# host-side: stage assignment + schedule arithmetic
# ---------------------------------------------------------------------------


def test_stage_layer_bounds_cover_uneven_depths():
    assert stage_layer_bounds(4, 2) == ((0, 2), (2, 4))
    # kimi-style uneven split: contiguous, covering, monotone
    bounds = stage_layer_bounds(61, 4)
    assert bounds[0][0] == 0 and bounds[-1][1] == 61
    assert all(a[1] == b[0] for a, b in zip(bounds, bounds[1:]))
    assert sum(hi - lo for lo, hi in bounds) == 61
    with pytest.raises(ValueError):
        stage_layer_bounds(4, 0)


def test_stage_of_depth_owner_assignment():
    # embed (depth 0) -> first stage, head (depth L+1) -> last stage
    assert stage_of_depth(0, 4, 2) == 0
    assert stage_of_depth(5, 4, 2) == 1
    # block b sits in the stage whose bounds contain it
    for s, (lo, hi) in enumerate(stage_layer_bounds(61, 4)):
        for b in (lo, hi - 1):
            assert stage_of_depth(b + 1, 61, 4) == s


def test_pipeline_schedule_arithmetic():
    assert num_pipeline_ticks(4, 4) == 7
    assert num_pipeline_ticks(1, 1) == 1
    with pytest.raises(ValueError):
        num_pipeline_ticks(0, 2)
    assert split_microbatch_sizes(8, 4) == (4, 2)
    with pytest.raises(ValueError, match="microbatches"):
        split_microbatch_sizes(8, 3)


# ---------------------------------------------------------------------------
# host-side: stage-sharded specs + support gates
# ---------------------------------------------------------------------------


def test_stage_member_specs_targets_scanned_leaves_only():
    from repro.sharding import rules

    member = {
        "embed": {"w": jax.ShapeDtypeStruct((32, 16), jnp.float32)},
        "blocks": {"w1": jax.ShapeDtypeStruct((4, 16, 16), jnp.float32)},
        "head": {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32)},
    }
    lids = infer_layer_ids(member, 4)
    specs = jax.tree_util.tree_map(
        lambda _: P(), member, is_leaf=lambda x: hasattr(x, "shape")
    )
    staged = rules.stage_member_specs(specs, lids, "pipe")
    assert staged["blocks"]["w1"] == P("pipe")
    assert staged["embed"]["w"] == P() and staged["head"]["w"] == P()
    # a layer axis already sharded by another mesh axis is an error
    specs["blocks"]["w1"] = P("model", None, None)
    with pytest.raises(ValueError, match="stage-split"):
        rules.stage_member_specs(specs, lids, "pipe")
    # population_pspecs routes through it and stacks the pop axis in front
    specs["blocks"]["w1"] = P()
    pop = rules.population_pspecs(specs, ("ens",), layer_ids=lids,
                                  pipe_axis="pipe")
    assert pop["blocks"]["w1"] == P("ens", "pipe")
    with pytest.raises(ValueError, match="layer_ids"):
        rules.population_pspecs(specs, ("ens",), pipe_axis="pipe")


def test_support_gates_reject_out_of_family_configs():
    from repro.models import transformer as M

    assert M.staged_decode_supported(ModelConfig(**TINY)) is None
    assert M.pipeline_supported(ModelConfig(**TINY)) is None
    ssm = ModelConfig(**{**TINY, "block_kind": "rwkv6"})
    assert "block_kind" in M.staged_decode_supported(ssm)
    assert "block_kind" in M.pipeline_supported(ssm)
    vlm = ModelConfig(**{**TINY, "frontend": "vision"})
    assert "frontend" in M.staged_decode_supported(vlm)
    moe_cfg = ModelConfig(**{**TINY, "moe": True, "n_routed_experts": 4,
                             "top_k": 2})
    assert M.staged_decode_supported(moe_cfg) is None  # decode is fine
    assert "aux" in M.pipeline_supported(moe_cfg)  # training is not
    with pytest.raises(NotImplementedError, match="block_kind"):
        M.pipeline_stage_fns(ssm)


def test_generate_rejects_bad_staged_requests():
    from repro.models import transformer as M
    from repro.serving import engine as serving

    cfg = ModelConfig(**TINY)
    params = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    mesh = fake_mesh(pipe=4)
    with pytest.raises(ValueError, match="ensemble"):
        serving.generate(params, cfg, batch, 4, mode="ensemble", mesh=mesh)
    cfg5 = ModelConfig(**{**TINY, "num_layers": 5})
    with pytest.raises(ValueError, match="split evenly"):
        serving.generate(params, cfg5, batch, 4, mesh=mesh)
    ssm = ModelConfig(**{**TINY, "block_kind": "rwkv6"})
    with pytest.raises(NotImplementedError, match="staged decode"):
        serving.generate(params, ssm, batch, 4, mesh=mesh)
    with pytest.raises(ValueError, match="pipe-only"):
        serving.generate(params, cfg, batch, 4,
                         mesh=fake_mesh(data=2, pipe=4))


# ---------------------------------------------------------------------------
# host-side: mesh constructors (1-device degeneration + shape overrides)
# ---------------------------------------------------------------------------


def test_make_host_mesh_pipe_kinds_degenerate_on_one_device():
    from repro.launch.mesh import make_host_mesh

    assert dict(make_host_mesh(4, "ens_pp").shape) == {"ens": 1, "pipe": 1}
    assert dict(make_host_mesh(4, "ens_dp_pp").shape) == {
        "ens": 1, "data": 1, "pipe": 1}
    with pytest.raises(ValueError, match="pp_stages"):
        make_host_mesh(4, "ens_pp", pp_stages=2)


def test_make_host_mesh_shape_override_validation():
    from repro.launch.mesh import make_host_mesh

    # wrong arity for the kind
    with pytest.raises(ValueError, match="does not match"):
        make_host_mesh(4, "ens_dp", mesh_shape=(1, 1, 1))
    # needs more devices than the host has
    with pytest.raises(ValueError, match="divide this host's"):
        make_host_mesh(4, "ens_dp", mesh_shape=(2, 2))
    # a valid all-ones override works on any host
    assert dict(make_host_mesh(4, "ens_dp_pp",
                               mesh_shape=(1, 1, 1)).shape) == {
        "ens": 1, "data": 1, "pipe": 1}


@pytest.mark.slow
def test_make_host_mesh_prime_device_count():
    """A 7-device host: nothing divides, so auto-fill falls back to 1s
    where it must, and explicit shapes that fit are honored."""
    _run("""
        import jax
        from repro.launch.mesh import make_host_mesh
        assert len(jax.devices()) == 7
        # population 4: largest divisor of 4 that fits 7 devices is 4,
        # leaving 7//4 -> rest 1
        assert dict(make_host_mesh(4, "ens_dp").shape) == {"ens": 4, "data": 1}
        assert dict(make_host_mesh(7, "ens_pp").shape) == {"ens": 7, "pipe": 1}
        assert dict(make_host_mesh(14, "ens").shape) == {"ens": 7}
        # 7 is prime: an ens=7 pipe=1 explicit shape is the only full fill
        m = make_host_mesh(7, "ens_pp", mesh_shape=(7, 1))
        assert dict(m.shape) == {"ens": 7, "pipe": 1}
        try:
            make_host_mesh(4, "ens_pp", mesh_shape=(2, 2))
            raise SystemExit("4 devices do not divide 7")
        except ValueError as e:
            assert "divide this host's" in str(e)
        print("OK prime mesh")
    """, devices=7)


@pytest.mark.slow
def test_make_host_mesh_auto_fill_eight_devices():
    _run("""
        import jax
        from repro.launch.mesh import make_host_mesh
        assert dict(make_host_mesh(2, "ens_pp", pp_stages=4).shape) == {
            "ens": 2, "pipe": 4}
        assert dict(make_host_mesh(2, "ens_dp_pp", pp_stages=2).shape) == {
            "ens": 2, "data": 2, "pipe": 2}
        # model axis takes the largest divisor of the remainder (was a
        # hard-coded 2-or-1): 8 devices / ens 2 -> model 4, data 1
        assert dict(make_host_mesh(2, "ens_dp_mp").shape) == {
            "ens": 2, "data": 1, "model": 4}
        # population must divide over the explicit ens axis
        try:
            make_host_mesh(3, "ens_pp", mesh_shape=(2, 4))
            raise SystemExit("3 members cannot divide over ens=2")
        except ValueError as e:
            assert "population" in str(e)
        try:
            make_host_mesh(2, "ens_pp", pp_stages=3)
            raise SystemExit("3 does not divide 4")
        except ValueError as e:
            assert "pp_stages" in str(e)
        print("OK auto fill")
    """)


# ---------------------------------------------------------------------------
# multi-device execution (subprocess, forced 8-device host)
# ---------------------------------------------------------------------------

_TOY = """
        import jax, jax.numpy as jnp
        import numpy as np
        from jax import lax
        from repro.configs.base import TrainConfig
        from repro.core.compat import make_mesh
        from repro.core.mixing import MixingConfig
        from repro.train import (
            StageFns, train_population_pipelined, train_population_sharded,
        )

        L, DIN, D, DOUT, B = 4, 16, 8, 4, 8

        def init(k):
            ks = jax.random.split(k, 3)
            return {"embed": {"w": jax.random.normal(ks[0], (DIN, D)) * 0.3},
                    "blocks": {"w1": jax.random.normal(ks[1], (L, D, D)) * 0.3},
                    "head": {"w": jax.random.normal(ks[2], (D, DOUT)) * 0.3}}

        def embed_fn(p, b):
            return b["x"] @ p["embed"]["w"]

        def blocks_fn(p, x):
            def body(h, wl):
                return jnp.tanh(h @ wl) + h, None
            h, _ = lax.scan(body, x, p["blocks"]["w1"])
            return h

        def head_fn(p, x, b):
            return jnp.mean((x @ p["head"]["w"] - b["y"]) ** 2)

        def loss_fn(p, b):
            return head_fn(p, blocks_fn(p, embed_fn(p, b)), b)

        def data_fn(m, step, k):
            kx, ky = jax.random.split(k)
            return {"x": jax.random.normal(kx, (B, DIN)),
                    "y": jax.random.normal(ky, (B, DOUT))}

        FNS = StageFns(embed_fn, blocks_fn, head_fn)
        KEY = jax.random.key(0)
        TCFG = TrainConfig(population=2, optimizer="sgd", lr=0.05,
                           total_steps=6, batch_size=B, seq_len=1, seed=0)
"""


@pytest.mark.slow
def test_pipelined_engine_s1_m1_delegates_bitwise():
    """(E,1,1) pipeline mesh, one microbatch: the pipelined entry point
    composes the stage fns and delegates to the fused engine — params,
    losses, and comm all bitwise-equal."""
    _run(_TOY + """
        for kind, kw in [("none", {}), ("papa", {"papa_every": 2}),
                         ("wash", {"base_p": 0.5})]:
            mcfg = MixingConfig(kind=kind, mode="bucketed", **kw)
            ref = train_population_sharded(
                KEY, init, loss_fn, data_fn, TCFG, mcfg, L, record_every=3,
                mesh=make_mesh((2,), ("ens",)))
            res = train_population_pipelined(
                KEY, init, FNS, data_fn, TCFG, mcfg, L, record_every=3,
                mesh=make_mesh((2, 1, 1), ("ens", "data", "pipe")),
                microbatches=1)
            for a, b in zip(jax.tree_util.tree_leaves(ref.population),
                            jax.tree_util.tree_leaves(res.population)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert ref.history["loss"] == res.history["loss"], kind
            assert ref.comm_scalars == res.comm_scalars, kind
            print("OK delegation", kind)
    """)


@pytest.mark.slow
def test_pipelined_engine_s4_m4_matches_to_tolerance():
    """S=4 stages, M=4 microbatches: the GPipe schedule's mean-of-means
    loss and accumulated grads match the single-shot engine to float32
    tolerance; WASH comm never exceeds the single-stage plan's."""
    _run(_TOY + """
        mesh4 = make_mesh((2, 4), ("ens", "pipe"))
        for kind, kw in [("none", {}), ("wash", {"base_p": 0.5})]:
            mcfg = MixingConfig(kind=kind, mode="bucketed", **kw)
            ref = train_population_sharded(
                KEY, init, loss_fn, data_fn, TCFG, mcfg, L, record_every=3,
                mesh=make_mesh((2,), ("ens",)))
            res = train_population_pipelined(
                KEY, init, FNS, data_fn, TCFG, mcfg, L, record_every=3,
                mesh=mesh4, microbatches=4)
            if kind == "none":
                for a, b in zip(jax.tree_util.tree_leaves(ref.population),
                                jax.tree_util.tree_leaves(res.population)):
                    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                               rtol=2e-5, atol=2e-6)
                np.testing.assert_allclose(ref.history["loss"],
                                           res.history["loss"], rtol=2e-5)
            else:
                # plans differ per stage; the contract is the accounting
                assert 0 < res.comm_scalars <= ref.comm_scalars
                assert all(np.isfinite(v) for v in res.history["loss"])
            print("OK s4m4", kind, ref.comm_scalars, res.comm_scalars)
    """)


@pytest.mark.slow
def test_pipelined_engine_rejects_uneven_split():
    _run(_TOY + """
        mcfg = MixingConfig(kind="none", mode="bucketed")
        mesh = make_mesh((2, 4), ("ens", "pipe"))
        try:
            train_population_pipelined(
                KEY, init, FNS, data_fn, TCFG, mcfg, 4, record_every=3,
                mesh=mesh, microbatches=3)
            raise SystemExit("batch 8 does not split into 3")
        except ValueError as e:
            assert "microbatches" in str(e)
        def init6(k):
            p = init(k)
            w = p["blocks"]["w1"]
            p["blocks"]["w1"] = jnp.concatenate([w, w[:2]], axis=0)
            return p
        try:
            train_population_pipelined(
                KEY, init6, FNS, data_fn, TCFG, mcfg, 6, record_every=3,
                mesh=mesh, microbatches=1)
            raise SystemExit("6 layers over 4 stages must fail")
        except ValueError as e:
            assert "evenly" in str(e)
        print("OK rejections")
    """)


@pytest.mark.slow
def test_staged_decode_bitwise_and_traces():
    """Stage-split decode on a (pipe=4) mesh: tokens bitwise-equal to the
    unstaged engine (greedy and temperature), one decode trace per shape,
    degenerate pipe=1 mesh serves unstaged."""
    _run("""
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs.base import ModelConfig
        from repro.core.compat import make_mesh
        from repro.models import transformer as M
        from repro.serving import engine as E

        cfg = ModelConfig(name="tiny", d_model=32, d_ff=64, num_layers=4,
                          num_heads=4, num_kv_heads=2, vocab_size=64,
                          max_position=128)
        params = M.init_params(jax.random.key(0), cfg)
        batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 6), 0, 64)}
        mesh = make_mesh((4,), ("pipe",))

        ref = E.generate(params, cfg, batch, 8)
        out = E.generate(params, cfg, batch, 8, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))

        rt = E.generate(params, cfg, batch, 8, temperature=0.8,
                        key=jax.random.key(7))
        st = E.generate(params, cfg, batch, 8, temperature=0.8,
                        key=jax.random.key(7), mesh=mesh)
        np.testing.assert_array_equal(np.asarray(rt), np.asarray(st))

        out1 = E.generate(params, cfg, batch, 8,
                          mesh=make_mesh((1,), ("pipe",)))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out1))

        E.reset_trace_counts(); E.clear_executable_cache()
        E.generate(params, cfg, batch, 8, mesh=mesh)
        E.generate(params, cfg, batch, 8, mesh=mesh)
        assert E.decode_trace_count() == 1 and E.prefill_trace_count() == 1
        assert E.executable_cache_size() == 1

        # MLA cache (ckv/krope leaves) stage-splits too
        cfg_mla = ModelConfig(name="tinymla", d_model=32, d_ff=64,
                              num_layers=4, num_heads=4, num_kv_heads=4,
                              vocab_size=64, max_position=128, mla=True,
                              kv_lora_rank=8, qk_rope_dim=4, qk_nope_dim=4,
                              v_head_dim=8)
        pm = M.init_params(jax.random.key(2), cfg_mla)
        r = E.generate(pm, cfg_mla, batch, 6)
        s = E.generate(pm, cfg_mla, batch, 6, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(s))
        print("OK staged decode")
    """)


@pytest.mark.slow
def test_hlo_collectives_stay_in_stage_rings():
    """On an (ens=2, pipe=4) mesh (device id = e*4 + p):

      * the shard-local WASH mixer's collective-permutes are ens-ring
        hops INSIDE a stage ring — src % 4 == tgt % 4 for every pair;
      * the staged decode program's permutes move the activation exactly
        one stage forward — tgt == src + 1, never wrapping.
    """
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.analysis import contracts
        from repro.core.compat import make_mesh
        from repro.core.mixing import MixingConfig
        from repro.core import shardplan

        mesh = make_mesh((2, 4), ("ens", "pipe"))
        L, D = 8, 16
        pop_sds = {"blocks": {"w": jax.ShapeDtypeStruct((2, L, D),
                                                        jnp.float32)}}
        pop_specs = {"blocks": {"w": P("ens", "pipe", None)}}
        opt_sds = {"step": jax.ShapeDtypeStruct((2,), jnp.int32)}
        opt_specs = {"step": P("ens")}
        key_sds = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)
        mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
        mixer = shardplan.make_shardlocal_mixer(mesh, mcfg, L, pop_specs,
                                                opt_specs)
        rep = contracts.lower_and_check(
            jax.jit(mixer), (pop_sds, opt_sds, key_sds),
            contracts.Contract(
                name="wash-mixer-rings",
                require_collectives=("collective-permute",),
                permute_rules=(contracts.stage_ring(4),),
            ))
        print("OK mixer rings", rep.permute_pairs)

        from repro.configs.base import ModelConfig
        from repro.models import transformer as M
        from repro.serving import engine as E
        cfg = ModelConfig(name="tiny", d_model=32, d_ff=64, num_layers=4,
                          num_heads=4, num_kv_heads=2, vocab_size=64,
                          max_position=128)
        params_sds = jax.eval_shape(
            lambda: M.init_params(jax.random.key(0), cfg))
        pmesh = make_mesh((4,), ("pipe",))
        E.clear_executable_cache()
        _, decode = E._programs(cfg, False, 2, 4, 8, 16, True, pmesh,
                                stages=4, params=params_sds)
        cache_sds = jax.eval_shape(lambda: M.init_cache(cfg, 2, 16))
        rep2 = contracts.lower_and_check(
            decode,
            (params_sds,
             jax.ShapeDtypeStruct((2, 4), jnp.int32),
             cache_sds,
             jax.ShapeDtypeStruct((2, 1, 64), jnp.float32),
             jax.ShapeDtypeStruct((2,), jax.random.key(0).dtype),
             jax.ShapeDtypeStruct((), jnp.float32)),
            contracts.Contract(
                name="staged-decode-hops",
                require_collectives=("collective-permute",),
                permute_rules=(contracts.forward_hop(4),),
            ))
        print("OK decode hops", rep2.permute_pairs)
    """)
