import jax
import pytest

# Smoke tests and benches see the single real CPU device; only the dry-run
# (a separate process) forces 512 placeholder devices via XLA_FLAGS.
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
