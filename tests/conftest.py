import jax
import jax.numpy as jnp
import pytest

# Smoke tests and benches see the single real CPU device; only the dry-run
# (a separate process) forces 512 placeholder devices via XLA_FLAGS.
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


# Shared tiny-population problem for the engine parity suites
# (tests/test_engine_parity.py and tests/test_schedule.py assert the fused
# engine against the reference loop on the SAME model/data/loss, so the
# two suites cannot drift apart).


def tiny_init(k):
    ks = jax.random.split(k, 4)
    return {
        "embed": {"w": jax.random.normal(ks[0], (16, 8))},
        "blocks": [
            {"w1": jax.random.normal(ks[1], (8, 8))},
            {"w1": jax.random.normal(ks[2], (8, 8))},
        ],
        "head": {"w": jax.random.normal(ks[3], (8, 4))},
    }


def tiny_data_fn(m, step, k):
    return {
        "x": jax.random.normal(k, (4, 16)),
        "y": jax.random.normal(jax.random.fold_in(k, 1), (4, 4)),
    }


def tiny_loss_fn(p, b):
    h = b["x"] @ p["embed"]["w"]
    for blk in p["blocks"]:
        h = jnp.tanh(h @ blk["w1"])
    return jnp.mean((h @ p["head"]["w"] - b["y"]) ** 2)
