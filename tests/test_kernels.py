"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import shuffle as shf
from repro.core.consensus import sq_distance_to_consensus
from repro.core.compat import resolve_interpret
from repro.kernels import ops, ref
from repro.models import layers as L

KEY = jax.random.key(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# wash_shuffle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,block_d", [(2, 100, 64), (5, 3000, 512), (8, 513, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wash_shuffle_kernel(n, d, block_d, dtype):
    x = jax.random.normal(KEY, (n, d)).astype(dtype)
    u = jax.random.uniform(jax.random.fold_in(KEY, 1), (n, d))
    perm = jnp.argsort(u, axis=0).astype(jnp.int32)
    mask = jax.random.bernoulli(jax.random.fold_in(KEY, 2), 0.4, (d,))
    out = ops.wash_shuffle(x, perm, mask, block_d=block_d)
    expect = ref.wash_shuffle_ref(x, perm, mask)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


# ---------------------------------------------------------------------------
# bucketed_shuffle (TPU-native WASH plan as one fused kernel pass)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,d,p,block_d",
    [(2, 100, 0.5, 64),     # tiny
     (4, 3000, 0.2, 512),   # multi-block grid
     (5, 517, 0.5, 128),    # d not a multiple of block_d (padding path)
     (8, 129, 0.9, 128)],   # n buckets ~ d, one ragged tail lane
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bucketed_shuffle_kernel_matches_stacked(n, d, p, block_d, dtype):
    x = jax.random.normal(KEY, (n, d)).astype(dtype)
    idx = shf.bucketed_plan(jax.random.fold_in(KEY, 1), d, n, p)
    assert idx is not None
    out = ops.bucketed_shuffle(x, idx, block_d=block_d)
    expect = shf.bucketed_apply_stacked(x, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_bucketed_shuffle_kernel_distance_preserving():
    """Eq. 5: the kernel's shuffle is an exact per-coordinate permutation,
    so Σ_n ||θ_n − θ̄||² is bitwise unchanged and every coordinate's
    multiset of values is preserved across members."""
    n, d = 5, 1203
    x = jax.random.normal(KEY, (n, d))
    idx = shf.bucketed_plan(jax.random.fold_in(KEY, 2), d, n, 0.7)
    out = ops.bucketed_shuffle(x, idx, block_d=256)
    np.testing.assert_allclose(
        float(sq_distance_to_consensus(out)),
        float(sq_distance_to_consensus(x)),
        rtol=1e-6,
    )
    np.testing.assert_array_equal(
        np.sort(np.asarray(out), axis=0), np.sort(np.asarray(x), axis=0)
    )


def test_bucketed_shuffle_kernel_bucket0_identity():
    """Bucket 0's coordinates (and every unselected coordinate) must pass
    through untouched — that is the paper's (N-1)/N send-volume saving."""
    n, d = 4, 600
    x = jax.random.normal(KEY, (n, d))
    idx = shf.bucketed_plan(jax.random.fold_in(KEY, 3), d, n, 0.3)
    out = np.asarray(ops.bucketed_shuffle(x, idx, block_d=128))
    moved = set(np.asarray(idx[1:]).ravel().tolist())
    untouched = sorted(set(range(d)) - moved)
    np.testing.assert_array_equal(out[:, untouched], np.asarray(x)[:, untouched])


def test_apply_plan_stacked_pallas_matches_roll_path():
    """The stacked apply path behind --pallas-shuffle: routing bucketed
    applies through the fused kernel is pure data movement, so it must be
    bitwise-equal to the N-1-round roll path — including layered
    (scanned-blocks) leaves and leaves with no plan."""
    from repro.core.layer_index import infer_layer_ids, total_layers

    n = 4
    pop = {
        "embed": {"w": jax.random.normal(KEY, (n, 16, 8))},
        "blocks": {"w1": jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (n, 3, 8, 8))},
        "head": {"w": jax.random.normal(jax.random.fold_in(KEY, 2), (n, 8, 4))},
    }
    member = jax.tree_util.tree_map(lambda x: x[0], pop)
    lids = infer_layer_ids(member, 3)
    plan = shf.make_plan(jax.random.fold_in(KEY, 3), pop, lids,
                         total_layers(3), 0.6, mode="bucketed")
    roll = shf.apply_plan_stacked(plan, pop, mode="bucketed")
    fused = shf.apply_plan_stacked(plan, pop, mode="bucketed", use_pallas=True)
    for a, b in zip(jax.tree_util.tree_leaves(roll),
                    jax.tree_util.tree_leaves(fused)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mix_once_pallas_shuffle_config_parity():
    """MixingConfig(pallas_shuffle=True) (the vmap engine's flag) shuffles
    params AND replayed optimizer moments bitwise-identically to the
    default path."""
    from repro.core.layer_index import infer_layer_ids, total_layers
    from repro.core.mixing import MixingConfig, mix_once

    n = 3
    pop = {"w": jax.random.normal(KEY, (n, 64, 8)),
           "b": jax.random.normal(jax.random.fold_in(KEY, 1), (n, 8))}
    opt = {"mu": jax.tree_util.tree_map(jnp.ones_like, pop),
           "step": jnp.zeros((n,), jnp.int32)}
    member = jax.tree_util.tree_map(lambda x: x[0], pop)
    lids = infer_layer_ids(member, 1)
    key = jax.random.fold_in(KEY, 9)
    base = MixingConfig(kind="wash_opt", base_p=0.5, mode="bucketed")
    import dataclasses
    pall = dataclasses.replace(base, pallas_shuffle=True)
    p0, o0, c0 = mix_once(key, pop, opt, base, lids, total_layers(1))
    p1, o1, c1 = mix_once(key, pop, opt, pall, lids, total_layers(1))
    for a, b in zip(jax.tree_util.tree_leaves((p0, o0)),
                    jax.tree_util.tree_leaves((p1, o1))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(c0) == float(c1)


def test_resolve_interpret_auto_detect():
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    expected = jax.default_backend() != "tpu"
    assert resolve_interpret(None) is expected


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,S,H,KV,hd,bq,bk",
    [(1, 64, 4, 4, 16, 16, 16),   # MHA
     (2, 128, 4, 2, 32, 32, 64),  # GQA, uneven blocks
     (1, 96, 8, 1, 16, 32, 32)],  # MQA
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, S, H, KV, hd, bq, bk, dtype):
    q = jax.random.normal(KEY, (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd)).astype(dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("window", [8, 32])
def test_flash_attention_sliding_window(window):
    B, S, H, KV, hd = 1, 64, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd))
    out = ops.flash_attention(q, k, v, window=window, block_q=16, block_k=16)
    expect = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    B, S, H, KV, hd = 1, 32, 2, 2, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd))
    out = ops.flash_attention(q, k, v, causal=False, block_q=16, block_k=16)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged_attention (continuous-batching decode over a block-pool KV cache)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,KV,hd,P,ps,mp", [
    (3, 4, 2, 16, 8, 4, 3),    # GQA groups of 2, lengths across pages
    (2, 8, 8, 32, 16, 8, 4),   # MHA (g=1)
    (1, 2, 1, 8, 4, 2, 2),     # single slot, single kv head
    (4, 4, 2, 64, 32, 16, 2),  # wider pages
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel(B, H, KV, hd, P, ps, mp, dtype):
    ks = [jax.random.fold_in(KEY, i) for i in range(5)]
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (P, ps, KV, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (P, ps, KV, hd)).astype(dtype)
    pt = jax.random.randint(ks[3], (B, mp), 0, P)
    lengths = jax.random.randint(ks[4], (B,), 1, mp * ps + 1)
    out = ops.paged_attention(q, kp, vp, pt, lengths)
    expect = ref.paged_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **_tol(dtype),
    )


def test_paged_attention_length_edges():
    """length=1 (only the fresh token), page-boundary lengths, and full
    tables all mask correctly; pages past the length don't leak."""
    B, H, KV, hd, P, ps, mp = 3, 2, 2, 8, 6, 4, 3
    ks = [jax.random.fold_in(KEY, i) for i in range(3)]
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, ps, KV, hd))
    vp = jax.random.normal(ks[2], (P, ps, KV, hd))
    pt = jnp.array([[1, 2, 3], [3, 1, 5], [5, 4, 2]], jnp.int32)
    for lengths in ([1, 1, 1], [ps, 2 * ps, 3 * ps], [ps + 1, 1, 2 * ps - 1]):
        lv = jnp.asarray(lengths, jnp.int32)
        out = ops.paged_attention(q, kp, vp, pt, lv)
        expect = ref.paged_attention_ref(q, kp, vp, pt, lv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)
    # garbage in pages past the length must not change the result
    kp2 = kp.at[4].set(1e4)
    vp2 = vp.at[4].set(-1e4)
    lv = jnp.array([ps, ps, ps], jnp.int32)  # page 4 only in masked tails
    out = ops.paged_attention(q, kp2, vp2, pt, lv)
    expect = ref.paged_attention_ref(q, kp, vp, pt, lv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# quantized paged attention (int8 pools + symmetric per-page scales)
# ---------------------------------------------------------------------------


def _quantized_pool(key, P, ps, KV, hd):
    """(fp32 pool, int8 pool, per-page scale) with page-exact scales."""
    pool = jax.random.normal(key, (P, ps, KV, hd))
    scale = jnp.maximum(jnp.max(jnp.abs(pool), axis=(1, 2, 3)) / 127.0,
                        L.KV_SCALE_FLOOR)
    return pool, L.kv_quantize(pool, scale[:, None, None, None]), scale


def _fresh_int8_pool(P, ps, KV, hd):
    """A per-layer int8 pool as paged_pools_init lays one out: zero bits,
    floor scales, page 0 pinned to the scratch scale."""
    scale = jnp.full((P,), L.KV_SCALE_FLOOR, jnp.float32)
    scale = scale.at[0].set(L.KV_SCRATCH_SCALE)
    return {"q": jnp.zeros((P, ps, KV, hd), jnp.int8), "scale": scale}


@pytest.mark.parametrize("B,H,KV,hd,P,ps,mp", [
    (3, 4, 2, 16, 8, 4, 3),    # GQA groups of 2
    (2, 8, 8, 32, 16, 8, 4),   # MHA (g=1)
])
def test_paged_attention_quantized_kernel_matches_ref(B, H, KV, hd, P, ps, mp):
    """Pallas (interpret) and the jnp oracle must agree on the SAME
    quantized pools — the dequant happens inside both attends."""
    ks = [jax.random.fold_in(KEY, 40 + i) for i in range(5)]
    q = jax.random.normal(ks[0], (B, H, hd))
    _, qk, k_scale = _quantized_pool(ks[1], P, ps, KV, hd)
    _, qv, v_scale = _quantized_pool(ks[2], P, ps, KV, hd)
    pt = jax.random.randint(ks[3], (B, mp), 0, P)
    lengths = jax.random.randint(ks[4], (B,), 1, mp * ps + 1)
    out = ops.paged_attention(q, qk, qv, pt, lengths,
                              k_scale=k_scale, v_scale=v_scale)
    expect = ref.paged_attention_ref(q, qk, qv, pt, lengths,
                                     k_scale=k_scale, v_scale=v_scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_quantized_tracks_fp32_within_tolerance():
    """int8 KV vs the fp32 pools it quantized: the pinned serving
    tolerance (per-element quant error is <= scale/2 ~ amax/254, and the
    softmax-weighted attend keeps the output inside a few steps)."""
    B, H, KV, hd, P, ps, mp = 3, 4, 2, 16, 8, 4, 3
    ks = [jax.random.fold_in(KEY, 50 + i) for i in range(5)]
    q = jax.random.normal(ks[0], (B, H, hd))
    kp, qk, k_scale = _quantized_pool(ks[1], P, ps, KV, hd)
    vp, qv, v_scale = _quantized_pool(ks[2], P, ps, KV, hd)
    pt = jax.random.randint(ks[3], (B, mp), 0, P)
    lengths = jax.random.randint(ks[4], (B,), 1, mp * ps + 1)
    exact = ref.paged_attention_ref(q, kp, vp, pt, lengths)
    quant = ops.paged_attention(q, qk, qv, pt, lengths,
                                k_scale=k_scale, v_scale=v_scale)
    np.testing.assert_allclose(np.asarray(quant), np.asarray(exact),
                               rtol=0.0, atol=5e-2)


def test_paged_attention_rejects_half_specified_scales():
    B, H, KV, hd, P, ps, mp = 1, 2, 1, 8, 4, 2, 2
    q = jnp.zeros((B, H, hd))
    pool = jnp.zeros((P, ps, KV, hd))
    pt = jnp.zeros((B, mp), jnp.int32)
    lengths = jnp.ones((B,), jnp.int32)
    with pytest.raises(ValueError, match="scale"):
        ops.paged_attention(q, pool, pool, pt, lengths,
                            k_scale=jnp.ones((P,)))


def test_kv_store_rows_round_trip_error_bound():
    """Decode-step scatter into an int8 pool, read back dequantized: the
    absolute error is bounded by half a quantization step of the final
    page scale."""
    P, ps, KV, hd, B = 6, 4, 2, 8, 5
    pool = _fresh_int8_pool(P, ps, KV, hd)
    rows = jax.random.normal(jax.random.fold_in(KEY, 60), (B, KV, hd)) * 3.0
    page_idx = jnp.array([1, 2, 3, 4, 5], jnp.int32)
    offset = jnp.array([0, 1, 2, 3, 0], jnp.int32)
    pool = L.paged_store_rows(pool, page_idx, offset, rows)
    got = L.kv_dequantize(pool["q"], pool["scale"][:, None, None, None])
    err = jnp.abs(got[page_idx, offset] - rows)
    bound = 0.5 * pool["scale"][page_idx][:, None, None] + 1e-6
    assert bool(jnp.all(err <= bound)), (
        f"round-trip error {float(err.max()):.4f} exceeds half a "
        f"quantization step {float(bound.max()):.4f}")


def test_kv_store_rows_duplicate_pages_keep_every_row():
    """The speculative verify step scatters several rows of one slot —
    often all into ONE page — in a single call; a gather-modify-scatter
    implementation would silently drop all but one duplicate."""
    P, ps, KV, hd = 4, 4, 2, 8
    pool = _fresh_int8_pool(P, ps, KV, hd)
    rows = jax.random.normal(jax.random.fold_in(KEY, 61), (4, KV, hd))
    page_idx = jnp.array([2, 2, 2, 2], jnp.int32)     # one page, 4 rows
    offset = jnp.arange(4, dtype=jnp.int32)
    pool = L.paged_store_rows(pool, page_idx, offset, rows)
    got = L.kv_dequantize(pool["q"], pool["scale"][:, None, None, None])
    err = jnp.abs(got[2, :4] - rows)
    bound = 0.5 * pool["scale"][2] + 1e-6
    assert bool(jnp.all(err <= bound)), (
        f"duplicate-page scatter dropped rows: max err {float(err.max()):.4f}")


def test_kv_scratch_page_scale_never_adapts():
    """Page 0 is the runtime's scratch target for masked/inactive rows;
    its scale must stay pinned at KV_SCRATCH_SCALE however large the
    garbage written to it, while live pages adapt monotonically."""
    P, ps, KV, hd = 4, 4, 2, 8
    pool = _fresh_int8_pool(P, ps, KV, hd)
    huge = jnp.full((2, KV, hd), 1e4, jnp.float32)
    pool = L.paged_store_rows(pool, jnp.array([0, 1], jnp.int32),
                              jnp.array([0, 0], jnp.int32), huge)
    assert float(pool["scale"][0]) == L.KV_SCRATCH_SCALE
    assert float(pool["scale"][1]) == pytest.approx(1e4 / 127.0)
    # growing a page's scale keeps previously-written rows within THEIR
    # original bound (rescale is monotone, error only shrinks relatively)
    small = jnp.full((1, KV, hd), 0.5, jnp.float32)
    pool = L.paged_store_rows(pool, jnp.array([2], jnp.int32),
                              jnp.array([0], jnp.int32), small)
    s_before = float(pool["scale"][2])
    big = jnp.full((1, KV, hd), 40.0, jnp.float32)
    pool = L.paged_store_rows(pool, jnp.array([2], jnp.int32),
                              jnp.array([1], jnp.int32), big)
    assert float(pool["scale"][2]) >= s_before
    got = L.kv_dequantize(pool["q"], pool["scale"][:, None, None, None])
    assert float(jnp.abs(got[2, 0] - 0.5).max()) <= \
        0.5 * float(pool["scale"][2]) + 1e-6


# ---------------------------------------------------------------------------
# rwkv6_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,H,hd,chunk", [(1, 32, 2, 8, 8), (2, 64, 2, 16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_kernel(B, T, H, hd, chunk, dtype):
    ks = [jax.random.fold_in(KEY, i) for i in range(5)]
    r = jax.random.normal(ks[0], (B, T, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, H, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, H, hd)).astype(dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))).astype(dtype)
    u = (jax.random.normal(ks[4], (H, hd)) * 0.1).astype(jnp.float32)
    out = ops.rwkv6_scan(r, k, v, w, u, chunk=chunk)
    expect = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=3e-1 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_rwkv6_kernel_matches_model_time_mix():
    """The kernel computes the same recurrence the model's scan uses."""
    from repro.models import ssm as SSM
    from repro.configs.base import ModelConfig

    cfg = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, block_kind="rwkv6",
                      rwkv_head_dim=16, dtype="float32")
    B, T, H, hd = 2, 24, 2, 16
    ks = [jax.random.fold_in(KEY, i) for i in range(5)]
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.1

    y_kernel = ops.rwkv6_scan(r, k, v, w, u, chunk=8)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    _, ys = jax.lax.scan(step, jnp.zeros((B, H, hd, hd)), xs)
    y_model = jnp.moveaxis(ys, 0, 1)
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_model), rtol=1e-4, atol=1e-4
    )
