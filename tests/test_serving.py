"""Fused scan-based serving engine (repro/serving/engine.py).

Contracts under test:
  * the scan program is token-bitwise-identical to the legacy per-token
    Python loop (greedy AND fixed-key temperature, text and vision-prefix
    configs) — the rewrite changes dispatch structure, not results;
  * decode compiles exactly ONCE per shape, no matter how many tokens are
    generated or how many same-shape requests follow (executable cache);
    the legacy loop's fresh-closure retrace per request is pinned as the
    bug it was;
  * ensemble mode averages member logits (balanced-tree mean, same
    reduction as the weight soup) before sampling;
  * temperature > 0 requires an explicit key (a silent default key made
    every sampled request identical); greedy stays keyless;
  * checkpoint.restore hands back device arrays on ``like``'s sharding.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import averaging
from repro.core import population as pop
from repro.models import transformer as M
from repro.serving import engine as serving
from repro.train import checkpoint

KEY = jax.random.key(0)

TEXT_CFG = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                       d_ff=64, vocab_size=50, dtype="float32")
VLM_CFG = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, frontend="vision",
                      num_patches=3, dtype="float32")


def _setup(cfg, batch_size=2, prompt_len=5):
    params = M.init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (batch_size, prompt_len), 0,
                                          cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            jax.random.fold_in(KEY, 1), (batch_size, cfg.num_patches, cfg.d_model)
        )
    return params, batch


@pytest.fixture(autouse=True)
def _fresh_engine():
    serving.reset_trace_counts()
    serving.clear_executable_cache()
    yield
    serving.clear_executable_cache()


# ---------------------------------------------------------------------------
# scan vs legacy loop parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [TEXT_CFG, VLM_CFG], ids=["text", "vlm"])
@pytest.mark.parametrize("temperature", [0.0, 0.8], ids=["greedy", "temp"])
def test_scan_matches_python_loop(cfg, temperature):
    params, batch = _setup(cfg)
    key = jax.random.key(7) if temperature > 0 else None
    ref = serving.generate_reference(params, cfg, batch, 6,
                                     temperature=temperature, key=key)
    out = serving.generate(params, cfg, batch, 6,
                           temperature=temperature, key=key)
    assert out.shape == ref.shape
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_greedy_matches_teacher_forced_argmax():
    """The scan engine keeps the old KV-cache correctness contract."""
    params, batch = _setup(TEXT_CFG)
    out = serving.generate(params, TEXT_CFG, batch, 6)
    full_logits, _ = M.forward_logits(params, TEXT_CFG, {"tokens": out})
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(full_logits[:, 4:-1], -1)), np.asarray(out[:, 5:])
    )


def test_temperature_streams_are_per_request():
    """Requests in one batch must not share a sample stream: serving the
    same prompt at rows 0 and 1 under temperature draws different tokens
    (per-request split keys), yet the whole batch stays deterministic."""
    params, _ = _setup(TEXT_CFG)
    prompt = jax.random.randint(KEY, (1, 5), 0, TEXT_CFG.vocab_size)
    batch = {"tokens": jnp.tile(prompt, (2, 1))}
    out1 = serving.generate(params, TEXT_CFG, batch, 24, temperature=1.5,
                            key=jax.random.key(3))
    out2 = serving.generate(params, TEXT_CFG, batch, 24, temperature=1.5,
                            key=jax.random.key(3))
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert not np.array_equal(np.asarray(out1[0]), np.asarray(out1[1]))


# ---------------------------------------------------------------------------
# compile-count contract
# ---------------------------------------------------------------------------


def test_decode_compiles_once_for_64_tokens():
    params, batch = _setup(TEXT_CFG)
    serving.generate(params, TEXT_CFG, batch, 64)
    assert serving.decode_trace_count() == 1
    assert serving.prefill_trace_count() == 1
    # same-shape requests reuse the cached executable: still one trace
    for _ in range(3):
        serving.generate(params, TEXT_CFG, batch, 64)
    assert serving.decode_trace_count() == 1
    # a new shape compiles once more
    serving.generate(params, TEXT_CFG, batch, 32)
    assert serving.decode_trace_count() == 2
    assert serving.executable_cache_size() == 2


def test_reference_loop_retraces_every_request():
    """The bug the engine fixes, pinned: the legacy path re-traced decode
    on every generate() call (fresh jit closure per request)."""
    params, batch = _setup(TEXT_CFG)
    for _ in range(3):
        serving.generate_reference(params, TEXT_CFG, batch, 4)
    assert serving.reference_trace_count() == 3


# ---------------------------------------------------------------------------
# key discipline
# ---------------------------------------------------------------------------


def test_temperature_requires_explicit_key():
    params, batch = _setup(TEXT_CFG)
    with pytest.raises(ValueError, match="explicit PRNG key"):
        serving.generate(params, TEXT_CFG, batch, 4, temperature=0.5)
    with pytest.raises(ValueError, match="explicit PRNG key"):
        serving.generate_reference(params, TEXT_CFG, batch, 4, temperature=0.5)
    # greedy stays keyless
    serving.generate(params, TEXT_CFG, batch, 4)


# ---------------------------------------------------------------------------
# serving modes
# ---------------------------------------------------------------------------


def _population(cfg, n=3):
    return jax.vmap(lambda k: M.init_params(k, cfg))(jax.random.split(KEY, n))


def test_ensemble_logits_are_mean_of_member_logits():
    cfg = TEXT_CFG
    popn = _population(cfg)
    _, batch = _setup(cfg)
    out = serving.generate(popn, cfg, batch, 6, mode="ensemble")

    # reference: legacy-style loop over vmapped members + balanced mean
    B, S = batch["tokens"].shape
    capacity = S + 6
    logits, cache = jax.vmap(
        lambda p: M.prefill(p, cfg, batch, capacity=capacity)
    )(popn)
    nxt = jnp.argmax(averaging.balanced_mean(logits)[:, -1], -1).astype(jnp.int32)
    toks = [nxt]
    for i in range(5):
        logits, cache = jax.vmap(
            lambda p, c: M.decode_step(p, cfg, nxt[:, None], c, S + i)
        )(popn, cache)
        nxt = jnp.argmax(
            averaging.balanced_mean(logits)[:, -1], -1
        ).astype(jnp.int32)
        toks.append(nxt)
    expect = jnp.concatenate(
        [batch["tokens"].astype(jnp.int32)] + [t[:, None] for t in toks], axis=1
    )
    np.testing.assert_array_equal(np.asarray(expect), np.asarray(out))
    # and the balanced mean tracks jnp.mean to float tolerance
    np.testing.assert_allclose(
        np.asarray(averaging.balanced_mean(logits)),
        np.asarray(jnp.mean(logits, axis=0)), rtol=1e-6, atol=1e-6,
    )


def test_member_and_soup_modes_route_params():
    cfg = TEXT_CFG
    popn = _population(cfg)
    _, batch = _setup(cfg)
    out_m = serving.generate_from_population(popn, cfg, batch, 5,
                                             mode="member", member=1)
    direct = serving.generate(pop.member(popn, 1), cfg, batch, 5)
    np.testing.assert_array_equal(np.asarray(out_m), np.asarray(direct))

    out_s = serving.generate_from_population(popn, cfg, batch, 5, mode="soup")
    soup = serving.generate(serving.averaged_params(popn), cfg, batch, 5)
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(soup))

    with pytest.raises(ValueError, match="unknown serving mode"):
        serving.generate(popn, cfg, batch, 5, mode="greedy_soup")


def test_data_mesh_serving_matches_unsharded():
    """Batch sharding over a data mesh is a layout change, not a math
    change (degenerate 1-device mesh in the main pytest process)."""
    from repro.launch.mesh import make_host_data_mesh

    params, batch = _setup(TEXT_CFG, batch_size=4)
    mesh = make_host_data_mesh()
    plain = serving.generate(params, TEXT_CFG, batch, 6)
    meshed = serving.generate(params, TEXT_CFG, batch, 6, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(meshed))


# ---------------------------------------------------------------------------
# checkpoint restore sharding
# ---------------------------------------------------------------------------


def test_restore_places_leaves_on_likes_sharding(tmp_path):
    """restore() must hand back committed device arrays in ``like``'s
    layout (host numpy leaves caused implicit per-step transfers when a
    restored population fed the fused engine); numpy ``like`` trees keep
    restoring to numpy."""
    import os

    popn = _population(TEXT_CFG, n=2)
    path = checkpoint.save(os.path.join(tmp_path, "pop"), popn)

    back = checkpoint.restore(path, popn)
    for a, b in zip(jax.tree_util.tree_leaves(popn),
                    jax.tree_util.tree_leaves(back)):
        assert isinstance(b, jax.Array)
        assert b.sharding == a.sharding
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    like_np = jax.tree_util.tree_map(np.asarray, popn)
    back_np = checkpoint.restore(path, like_np)
    assert all(isinstance(leaf, np.ndarray)
               for leaf in jax.tree_util.tree_leaves(back_np))
