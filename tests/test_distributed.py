"""Distributed paths that need >1 device run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count (jax locks the device
count at first init, so the main pytest process must stay at 1 device).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_collective_shuffle_equals_stacked_reference():
    out = _run(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import population as pop
        from repro.core.compat import make_mesh, shard_map
        from repro.core.mixing import MixingConfig, mix_stacked, mix_collective
        from repro.core.layer_index import infer_layer_ids, total_layers

        key = jax.random.key(0)
        def init(k):
            ks = jax.random.split(k, 6)
            return {"embed": {"w": jax.random.normal(ks[0], (64, 32))},
                    "blocks": [{"w1": jax.random.normal(ks[1+i], (32, 32))} for i in range(3)],
                    "head": {"w": jax.random.normal(ks[5], (32, 8))}}
        N = 4
        stacked = pop.init_population(init, key, N, same_init=False)
        lids = infer_layer_ids(pop.member(stacked, 0), 3)
        L = total_layers(3)
        cfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
        ref, _, comm_ref = mix_stacked(1, key, stacked, None, cfg, lids, L)

        mesh = make_mesh((4,), ("ens",))
        def member_fn(params):
            params = jax.tree_util.tree_map(lambda x: x[0], params)
            out, _, comm = mix_collective(1, key, params, None, cfg, lids, L, "ens")
            return jax.tree_util.tree_map(lambda x: x[None], out), comm[None]
        specs = jax.tree_util.tree_map(lambda x: P("ens"), stacked)
        f = shard_map(member_fn, mesh, in_specs=(specs,),
                      out_specs=(specs, P("ens")))
        out, comm = jax.jit(f)(stacked)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(ref)))
        assert err == 0.0, err
        assert float(comm[0]) == float(comm_ref), (comm, comm_ref)
        print("OK collective == stacked, comm", float(comm_ref))
        """
    )
    assert "OK" in out


def test_pjit_sharded_population_wash_step_runs():
    """Stacked population sharded over an ens mesh axis: the bucketed
    shuffle (jnp.roll over the sharded axis) must lower to collective
    permutes and produce the same result as the single-device run."""
    out = _run(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import population as pop
        from repro.core.mixing import MixingConfig, mix_once
        from repro.core.layer_index import infer_layer_ids, total_layers

        key = jax.random.key(0)
        def init(k):
            return {"embed": {"w": jax.random.normal(k, (64, 32))},
                    "blocks": [{"w1": jax.random.normal(k, (32, 32))}],
                    "head": {"w": jax.random.normal(k, (32, 8))}}
        N = 4
        stacked = pop.init_population(init, key, N, same_init=False)
        lids = infer_layer_ids(pop.member(stacked, 0), 1)
        L = total_layers(1)
        cfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
        ref, _, _ = mix_once(key, stacked, None, cfg, lids, L)

        from repro.core.compat import make_mesh
        mesh = make_mesh((4, 2), ("ens", "model"))
        sh = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("ens"))), stacked)
        step = jax.jit(lambda p: mix_once(key, p, None, cfg, lids, L)[0])
        lowered = step.lower(sh)
        txt = lowered.compile().as_text()
        assert ("collective-permute" in txt) or ("all-to-all" in txt), "no permute collective found"
        out = step(sh)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(ref)))
        assert err == 0.0, err
        print("OK pjit wash step, collective-permute present")
        """
    )
    assert "OK" in out


def test_mesh_constructors():
    out = _run(
        """
        from repro.launch.mesh import make_production_mesh, make_ensemble_mesh, data_axes
        m = make_production_mesh()
        assert dict(m.shape) == {"data": 16, "model": 16}, m.shape
        mp = make_production_mesh(multi_pod=True)
        assert dict(mp.shape) == {"pod": 2, "data": 16, "model": 16}
        assert data_axes(mp) == ("pod", "data")
        e = make_ensemble_mesh(4)
        assert dict(e.shape) == {"ens": 4, "data": 4, "model": 16}
        e2 = make_ensemble_mesh(2, multi_pod=True)
        assert dict(e2.shape) == {"ens": 2, "data": 16, "model": 16}
        print("OK meshes")
        """,
        devices=512,
    )
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cli_one_pair():
    """The dry-run CLI end-to-end on the cheapest (arch, shape) pair."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "hymba-1.5b", "--shape", "decode_32k",
         "--out-dir", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "[ok]" in r.stdout


@pytest.mark.slow
def test_fused_engine_multidevice_matches_reference():
    """The fused shard_map engine on a real 4-device ens mesh (one member
    per device → every WASH bucket is a genuine ppermute) must match the
    single-device vmap reference loop: params bitwise for WASH, identical
    comm accounting, and the compiled step must contain collective-permute."""
    out = _run(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import TrainConfig
        from repro.core.compat import make_mesh, shard_map
        from repro.core import shuffle as shf
        from repro.core.mixing import MixingConfig
        from repro.train import train_population
        from repro.train.engine import train_population_sharded

        KEY = jax.random.key(0)
        def init(k):
            ks = jax.random.split(k, 3)
            return {"embed": {"w": jax.random.normal(ks[0], (16, 8))},
                    "blocks": [{"w1": jax.random.normal(ks[1], (8, 8))}],
                    "head": {"w": jax.random.normal(ks[2], (8, 4))}}
        def data_fn(m, step, k):
            return {"x": jax.random.normal(k, (4, 16)),
                    "y": jax.random.normal(jax.random.fold_in(k, 1), (4, 4))}
        def loss_fn(p, b):
            h = jnp.tanh(b["x"] @ p["embed"]["w"] @ p["blocks"][0]["w1"])
            return jnp.mean((h @ p["head"]["w"] - b["y"]) ** 2)

        for kind in ("wash", "wash_opt"):
            tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05,
                               total_steps=11, batch_size=4)
            mcfg = MixingConfig(kind=kind, base_p=0.5, mode="bucketed")
            ref = train_population(KEY, init, loss_fn, data_fn, tcfg, mcfg, 1,
                                   record_every=5)
            fused = train_population_sharded(KEY, init, loss_fn, data_fn,
                                             tcfg, mcfg, 1, record_every=5)
            err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
                jax.tree_util.tree_leaves(ref.population),
                jax.tree_util.tree_leaves(fused.population)))
            assert err == 0.0, (kind, err)
            assert ref.comm_scalars == fused.comm_scalars

        # blocked collective shuffle == stacked roll on every block size
        key = jax.random.key(7)
        n, D = 4, 37
        x = jax.random.normal(key, (n, D))
        idx = shf.bucketed_plan(jax.random.fold_in(key, 1), D, n, 0.8)
        stacked = shf.bucketed_apply_stacked(x, idx)
        for m in (4, 2, 1):
            mesh = make_mesh((m,), ("ens",))
            f = shard_map(
                lambda xb: shf.bucketed_apply_collective_blocked(xb, idx, "ens"),
                mesh, in_specs=(P("ens"),), out_specs=P("ens"), check_vma=False)
            err = float(jnp.max(jnp.abs(jax.jit(f)(x) - stacked)))
            assert err == 0.0, (m, err)
        mesh = make_mesh((4,), ("ens",))
        f = shard_map(
            lambda xb: shf.bucketed_apply_collective_blocked(xb, idx, "ens"),
            mesh, in_specs=(P("ens"),), out_specs=P("ens"), check_vma=False)
        txt = jax.jit(f).lower(x).compile().as_text()
        assert "collective-permute" in txt, "fused shuffle did not lower to ppermute"
        print("OK fused engine multidevice")
        """,
        devices=4,
    )
    assert "OK" in out


def test_shardlocal_mixer_preserves_consensus_distance():
    """§Perf shard-local shuffle: per-shard bucketed plans under shard_map
    must still be exact permutations (Eq. 5) and actually mix."""
    out = _run(
        """
        import os, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig, InputShape
        from repro.core.consensus import sq_distance_to_consensus
        from repro.core.mixing import MixingConfig
        from repro.launch.dryrun import make_shardlocal_mixer
        from repro.core import population as pop

        cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                          d_ff=64, vocab_size=64, dtype="float32")
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 2, 2), ("ens", "data", "model"))
        key = jax.random.key(0)
        def init(k):
            return {"embed": {"w": jax.random.normal(k, (64, 32))},
                    "blocks": {"w1": jax.random.normal(k, (2, 32, 64))},
                    "head": {"w": jax.random.normal(k, (32, 8))}}
        stacked = pop.init_population(init, key, 2, same_init=False)
        pop_specs = jax.tree_util.tree_map(lambda x: P("ens"), stacked)
        opt = {"mu": jax.tree_util.tree_map(jnp.zeros_like, stacked),
               "step": jnp.zeros((2,), jnp.int32)}
        opt_specs = {"mu": pop_specs, "step": P("ens")}
        mcfg = MixingConfig(kind="wash_opt", base_p=0.5, mode="bucketed")
        mixer = make_shardlocal_mixer(cfg, mcfg, mesh, pop_specs, opt_specs)
        sh = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("ens"))), stacked)
        sho = {"mu": jax.tree_util.tree_map(
                   lambda x: jax.device_put(x, NamedSharding(mesh, P("ens"))), opt["mu"]),
               "step": jax.device_put(opt["step"], NamedSharding(mesh, P("ens")))}
        out, opt2, comm = jax.jit(mixer)(sh, sho, key)
        d0 = float(sq_distance_to_consensus(stacked))
        d1 = float(sq_distance_to_consensus(out))
        assert abs(d0 - d1) / d0 < 1e-5, (d0, d1)
        moved = sum(float(jnp.sum(a != b)) for a, b in zip(
            jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(stacked)))
        assert moved > 0, "shuffle was a no-op"
        assert float(comm) > 0
        # per-coordinate multiset preserved (values only move between members)
        import numpy as np
        for a, b in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(stacked)):
            np.testing.assert_allclose(np.sort(np.asarray(a), 0),
                                       np.sort(np.asarray(b), 0), rtol=1e-6)
        print("OK shard-local mixer")
        """
    )
    assert "OK" in out
