"""Distributed paths that need >1 device run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count (jax locks the device
count at first init, so the main pytest process must stay at 1 device).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_collective_shuffle_equals_stacked_reference():
    out = _run(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import population as pop
        from repro.core.mixing import MixingConfig, mix_stacked, mix_collective
        from repro.core.layer_index import infer_layer_ids, total_layers

        key = jax.random.key(0)
        def init(k):
            ks = jax.random.split(k, 6)
            return {"embed": {"w": jax.random.normal(ks[0], (64, 32))},
                    "blocks": [{"w1": jax.random.normal(ks[1+i], (32, 32))} for i in range(3)],
                    "head": {"w": jax.random.normal(ks[5], (32, 8))}}
        N = 4
        stacked = pop.init_population(init, key, N, same_init=False)
        lids = infer_layer_ids(pop.member(stacked, 0), 3)
        L = total_layers(3)
        cfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
        ref, _, comm_ref = mix_stacked(1, key, stacked, None, cfg, lids, L)

        mesh = jax.make_mesh((4,), ("ens",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        def member_fn(params):
            params = jax.tree_util.tree_map(lambda x: x[0], params)
            out, _, comm = mix_collective(1, key, params, None, cfg, lids, L, "ens")
            return jax.tree_util.tree_map(lambda x: x[None], out), comm[None]
        specs = jax.tree_util.tree_map(lambda x: P("ens"), stacked)
        f = jax.shard_map(member_fn, mesh=mesh, in_specs=(specs,),
                          out_specs=(specs, P("ens")))
        out, comm = jax.jit(f)(stacked)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(ref)))
        assert err == 0.0, err
        assert float(comm[0]) == float(comm_ref), (comm, comm_ref)
        print("OK collective == stacked, comm", float(comm_ref))
        """
    )
    assert "OK" in out


def test_pjit_sharded_population_wash_step_runs():
    """Stacked population sharded over an ens mesh axis: the bucketed
    shuffle (jnp.roll over the sharded axis) must lower to collective
    permutes and produce the same result as the single-device run."""
    out = _run(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import population as pop
        from repro.core.mixing import MixingConfig, mix_once
        from repro.core.layer_index import infer_layer_ids, total_layers

        key = jax.random.key(0)
        def init(k):
            return {"embed": {"w": jax.random.normal(k, (64, 32))},
                    "blocks": [{"w1": jax.random.normal(k, (32, 32))}],
                    "head": {"w": jax.random.normal(k, (32, 8))}}
        N = 4
        stacked = pop.init_population(init, key, N, same_init=False)
        lids = infer_layer_ids(pop.member(stacked, 0), 1)
        L = total_layers(1)
        cfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
        ref, _, _ = mix_once(key, stacked, None, cfg, lids, L)

        mesh = jax.make_mesh((4, 2), ("ens", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        sh = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("ens"))), stacked)
        step = jax.jit(lambda p: mix_once(key, p, None, cfg, lids, L)[0])
        lowered = step.lower(sh)
        txt = lowered.compile().as_text()
        assert ("collective-permute" in txt) or ("all-to-all" in txt), "no permute collective found"
        out = step(sh)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(ref)))
        assert err == 0.0, err
        print("OK pjit wash step, collective-permute present")
        """
    )
    assert "OK" in out


def test_mesh_constructors():
    out = _run(
        """
        from repro.launch.mesh import make_production_mesh, make_ensemble_mesh, data_axes
        m = make_production_mesh()
        assert dict(m.shape) == {"data": 16, "model": 16}, m.shape
        mp = make_production_mesh(multi_pod=True)
        assert dict(mp.shape) == {"pod": 2, "data": 16, "model": 16}
        assert data_axes(mp) == ("pod", "data")
        e = make_ensemble_mesh(4)
        assert dict(e.shape) == {"ens": 4, "data": 4, "model": 16}
        e2 = make_ensemble_mesh(2, multi_pod=True)
        assert dict(e2.shape) == {"ens": 2, "data": 16, "model": 16}
        print("OK meshes")
        """,
        devices=512,
    )
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cli_one_pair():
    """The dry-run CLI end-to-end on the cheapest (arch, shape) pair."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "hymba-1.5b", "--shape", "decode_32k",
         "--out-dir", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "[ok]" in r.stdout


def test_shardlocal_mixer_preserves_consensus_distance():
    """§Perf shard-local shuffle: per-shard bucketed plans under shard_map
    must still be exact permutations (Eq. 5) and actually mix."""
    out = _run(
        """
        import os, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig, InputShape
        from repro.core.consensus import sq_distance_to_consensus
        from repro.core.mixing import MixingConfig
        from repro.launch.dryrun import make_shardlocal_mixer
        from repro.core import population as pop

        cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                          d_ff=64, vocab_size=64, dtype="float32")
        mesh = jax.make_mesh((2, 2, 2), ("ens", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
        key = jax.random.key(0)
        def init(k):
            return {"embed": {"w": jax.random.normal(k, (64, 32))},
                    "blocks": {"w1": jax.random.normal(k, (2, 32, 64))},
                    "head": {"w": jax.random.normal(k, (32, 8))}}
        stacked = pop.init_population(init, key, 2, same_init=False)
        pop_specs = jax.tree_util.tree_map(lambda x: P("ens"), stacked)
        opt = {"mu": jax.tree_util.tree_map(jnp.zeros_like, stacked),
               "step": jnp.zeros((2,), jnp.int32)}
        opt_specs = {"mu": pop_specs, "step": P("ens")}
        mcfg = MixingConfig(kind="wash_opt", base_p=0.5, mode="bucketed")
        mixer = make_shardlocal_mixer(cfg, mcfg, mesh, pop_specs, opt_specs)
        sh = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("ens"))), stacked)
        sho = {"mu": jax.tree_util.tree_map(
                   lambda x: jax.device_put(x, NamedSharding(mesh, P("ens"))), opt["mu"]),
               "step": jax.device_put(opt["step"], NamedSharding(mesh, P("ens")))}
        out, opt2, comm = jax.jit(mixer)(sh, sho, key)
        d0 = float(sq_distance_to_consensus(stacked))
        d1 = float(sq_distance_to_consensus(out))
        assert abs(d0 - d1) / d0 < 1e-5, (d0, d1)
        moved = sum(float(jnp.sum(a != b)) for a, b in zip(
            jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(stacked)))
        assert moved > 0, "shuffle was a no-op"
        assert float(comm) > 0
        # per-coordinate multiset preserved (values only move between members)
        import numpy as np
        for a, b in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(stacked)):
            np.testing.assert_allclose(np.sort(np.asarray(a), 0),
                                       np.sort(np.asarray(b), 0), rtol=1e-6)
        print("OK shard-local mixer")
        """
    )
    assert "OK" in out
