"""Continuous-batching runtime (repro/serving/batching.py).

Contracts under test:
  * a request served through a busy continuous batch is token-for-token
    identical to serving it alone via ``engine.generate_reference`` with
    the same key (greedy AND temperature) — staggered admissions and
    retirements change scheduling, never semantics;
  * the decode-step program compiles exactly ONCE for a whole mixed-length
    stream, across every admission/retirement (trace counter — same
    contract as the scan engine's per-shape guarantee, strengthened to one
    compile TOTAL); a second stream on the same server adds zero traces;
  * full prompt pages shared between in-flight requests are deduped via
    the chained prefix hash, refcounted, and freed when the last holder
    retires (pool returns to empty);
  * ensemble mode averages member logits before sampling (oracle: the
    scan engine's ensemble mode, itself parity-tested against the
    explicit vmap loop);
  * the Pallas paged-attention path (interpret on CPU) produces the same
    tokens as the jnp gather oracle path;
  * unsupported cache layouts (MLA, SSM state, sliding window, modality
    prefixes) are rejected loudly, and sampling without a per-request key
    is rejected like in ``engine.generate``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as M
from repro.serving import batching
from repro.serving import engine as serving

KEY = jax.random.key(0)

CFG = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                  d_ff=64, vocab_size=50, dtype="float32")

# (prompt_len, max_new) pairs with staggered finishes: slots retire and
# re-admit mid-stream (max_slots below is smaller than the request count)
MIXED = [(5, 6), (9, 3), (3, 8), (12, 1), (7, 5), (4, 4)]


def _params():
    return M.init_params(KEY, CFG)


def _mixed_requests(temperature=0.0, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (S, mn) in enumerate(MIXED):
        prompt = rng.integers(0, CFG.vocab_size, size=(S,)).astype(np.int32)
        key = jax.random.key(100 + i) if temperature > 0 else None
        reqs.append(batching.Request(i, prompt, mn, key=key))
    return reqs


def _reference(params, req, temperature=0.0):
    return np.asarray(serving.generate_reference(
        params, CFG, {"tokens": jnp.asarray(req.tokens)[None]}, req.max_new,
        temperature=temperature, key=req.key,
    ))[0]


@pytest.fixture(autouse=True)
def _fresh_runtime():
    batching.reset_trace_counts()
    batching.clear_executable_cache()
    yield
    batching.clear_executable_cache()


# ---------------------------------------------------------------------------
# mixed-length stream parity + one-compile contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.8], ids=["greedy", "temp"])
def test_mixed_stream_matches_per_request_reference(temperature):
    """Staggered admissions/retirements (3 slots, 6 requests, budgets from
    1 to 8 tokens) reproduce every request's solo output bitwise, with one
    decode compile for the whole stream."""
    params = _params()
    reqs = _mixed_requests(temperature)
    server = batching.ContinuousServer(
        params, CFG, temperature=temperature, page_size=4, max_slots=3,
        num_pages=32)
    out = server.run(reqs)
    assert set(out) == {r.uid for r in reqs}
    for r in reqs:
        np.testing.assert_array_equal(
            _reference(params, r, temperature), out[r.uid].tokens,
            err_msg=f"request {r.uid} (S={len(r.tokens)}, "
                    f"max_new={r.max_new}) diverged from solo serving")
    assert batching.decode_trace_count() == 1, (
        f"decode must compile once for the whole stream, "
        f"traced {batching.decode_trace_count()}x")
    # prefill compiles per distinct prompt length (shape-dependent)
    assert batching.prefill_trace_count() == len({s for s, _ in MIXED})
    assert server.stats["retired"] == len(reqs)


def test_second_stream_reuses_the_decode_executable():
    params = _params()
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=3, num_pages=32)
    server.run(_mixed_requests(seed=1))
    assert batching.decode_trace_count() == 1
    out = server.run(_mixed_requests(seed=2))
    assert batching.decode_trace_count() == 1, "re-traced on second stream"
    # second stream's requests are all present and still reference-exact
    for r in _mixed_requests(seed=2):
        np.testing.assert_array_equal(
            _reference(params, r), out[r.uid].tokens)


def test_single_step_admission_and_inflight_mix():
    """step() admits what fits and decodes everyone in flight; queue
    drains as slots retire (the continuous part of continuous batching)."""
    params = _params()
    reqs = _mixed_requests()
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32)
    for r in reqs:
        server.submit(r)
    assert server.queue_len == len(reqs)
    seen_active = 0
    finished = []
    for _ in range(100):
        finished += server.step()
        seen_active = max(seen_active, server.active_slots)
        if not server.queue_len and not server.active_slots:
            break
    assert sorted(finished) == [r.uid for r in reqs]
    assert seen_active == 2  # both slots actually ran concurrently


# ---------------------------------------------------------------------------
# paged pool: prefix dedup + refcounted frees
# ---------------------------------------------------------------------------


def test_prefix_pages_are_shared_and_refcount_freed():
    params = _params()
    rng = np.random.default_rng(3)
    shared = rng.integers(0, CFG.vocab_size, size=(8,)).astype(np.int32)
    a = np.concatenate([shared, rng.integers(0, 50, size=(3,)).astype(np.int32)])
    b = np.concatenate([shared, rng.integers(0, 50, size=(5,)).astype(np.int32)])
    c = rng.integers(0, CFG.vocab_size, size=(11,)).astype(np.int32)
    reqs = [batching.Request("a", a, 5), batching.Request("b", b, 4),
            batching.Request("c", c, 3)]
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=3, num_pages=32)
    out = server.run(reqs)
    # the 8-token shared prefix is 2 full pages at page_size=4: request b
    # (admitted while a is in flight) reuses both
    assert server.stats["pages_shared"] == 2, server.stats
    # refcounted frees: the drained pool is completely empty again
    assert server._pool.used_count == 0
    assert not server._pool.refcount and not server._pool.prefix
    # sharing pages never changes tokens
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)


def test_page_pressure_queues_without_deadlock():
    """A pool too small for all requests at once still serves the stream
    (admission reserves worst-case pages; head-of-line waits for frees)."""
    params = _params()
    rng = np.random.default_rng(4)
    reqs = [batching.Request(i, rng.integers(0, 50, (9,)).astype(np.int32), 6)
            for i in range(4)]
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=4, num_pages=8)
    out = server.run(reqs)
    assert len(out) == 4
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)
    assert server.stats["peak_pages_in_use"] <= 7


def test_oversized_request_rejected_at_submit():
    params = _params()
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=8)
    big = np.zeros((40,), np.int32)
    with pytest.raises(ValueError, match="pages"):
        server.submit(batching.Request("big", big, 8))


# ---------------------------------------------------------------------------
# prefill-only retirement, same-step dedup, chunked geometry
# ---------------------------------------------------------------------------


def test_max_new_one_retires_at_prefill():
    """max_new=1: the prefill program's sampled token completes the
    request, so it must retire WITHOUT ever occupying the decode batch
    (a decode dispatch for it would read an uninitialized slot)."""
    params = _params()
    rng = np.random.default_rng(9)
    reqs = [batching.Request(i, rng.integers(0, 50, (s,)).astype(np.int32), 1)
            for i, s in enumerate([7, 4])]
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=16)
    out = server.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)
    assert server.stats["decode_steps"] == 0
    assert server.stats["retired"] == 2
    assert server._pool.used_count == 0


def test_same_step_prefix_dedup():
    """Two requests sharing a prompt prefix, admitted by the SAME step()
    call: the first admission's prefill registers its page digests before
    the second admission runs, so the second must share, not recompute."""
    params = _params()
    rng = np.random.default_rng(10)
    shared = rng.integers(0, 50, (8,)).astype(np.int32)
    a = np.concatenate([shared, rng.integers(0, 50, (3,)).astype(np.int32)])
    b = np.concatenate([shared, rng.integers(0, 50, (5,)).astype(np.int32)])
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32)
    server.submit(batching.Request("a", a, 4))
    server.submit(batching.Request("b", b, 4))
    server.step()  # one step admits BOTH (two free slots)
    assert server.stats["admitted"] == 2
    assert server.stats["pages_shared"] == 2  # the 8-token prefix = 2 pages
    assert server.stats["prefix_tokens_reused"] == 8
    out = server.run()
    for uid, prompt in (("a", a), ("b", b)):
        np.testing.assert_array_equal(
            _reference(params, batching.Request(uid, prompt, 4)),
            out[uid].tokens)


def test_chunked_prefill_same_tokens_fewer_trace_shapes():
    """prefill_chunk splits every admission into fixed-size chunk
    programs: tokens stay bitwise identical and the compiled prefill
    shapes collapse to {chunk, remainders} instead of one per prompt
    length."""
    params = _params()
    reqs = _mixed_requests(seed=11)
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=3, num_pages=32,
                                       prefill_chunk=4)
    out = server.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)
    assert batching.decode_trace_count() == 1
    # chunk lengths are min(4, remaining): {4} plus short remainders —
    # never more shapes than the chunk size
    assert batching.prefill_trace_count() <= 4


# ---------------------------------------------------------------------------
# LRU retention: revival, eviction under pressure, stall recovery
# ---------------------------------------------------------------------------


def test_lru_retention_revives_prefix_pages():
    """retain_pages: a drained request's hashed pages park on the LRU
    list; resubmitting the same prompt revives them and prefills ONLY
    the uncached suffix (token accounting by the server's counters)."""
    params = _params()
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, 50, (11,)).astype(np.int32)
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32,
                                       retain_pages=True)
    out1 = server.run([batching.Request("r1", prompt, 4)])
    assert server._pool.retained_count > 0
    assert not server._pool.refcount
    before = dict(server.stats)
    out2 = server.run([batching.Request("r2", prompt, 4)])
    np.testing.assert_array_equal(out1["r1"].tokens, out2["r2"].tokens)
    assert server.stats["lru_hits"] > 0
    # 11 tokens at page_size=4: 2 full prompt pages (8 tokens) are
    # cacheable; the resubmission prefills only the 3-token suffix
    assert server.stats["prefix_tokens_reused"] - before["prefix_tokens_reused"] == 8
    assert server.stats["prefill_tokens"] - before["prefill_tokens"] == 3
    # three-state invariant: every page is free, parked, or referenced
    pool = server._pool
    assert (pool.free_count + pool.retained_count + len(pool.refcount)
            == server.num_pages - 1)


def test_lru_eviction_recovers_from_full_parked_pool():
    """A pool whose idle pages are all parked must evict LRU-first to
    admit fresh prompts — retention never causes an admission stall."""
    params = _params()
    rng = np.random.default_rng(13)
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=8,
                                       retain_pages=True)
    for i in range(4):  # distinct prompts, enough to cycle the tiny pool
        prompt = rng.integers(0, 50, (9,)).astype(np.int32)
        out = server.run([batching.Request(i, prompt, 3)])
        np.testing.assert_array_equal(
            _reference(params, batching.Request(i, prompt, 3)),
            out[i].tokens)
    assert server.stats["lru_evictions"] > 0
    pool = server._pool
    assert (pool.free_count + pool.retained_count + len(pool.refcount)
            == server.num_pages - 1)
    assert not pool.refcount


def test_cancel_releases_pages_at_every_stage():
    """cancel() drops a request whether queued or decoding; its pages
    return to the pool and the stream's other requests are unaffected."""
    params = _params()
    rng = np.random.default_rng(14)
    keep = batching.Request("keep", rng.integers(0, 50, (6,)).astype(np.int32), 5)
    dec = batching.Request("dec", rng.integers(0, 50, (9,)).astype(np.int32), 8)
    queued = batching.Request("q", rng.integers(0, 50, (5,)).astype(np.int32), 4)
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32)
    server.submit(keep)
    server.submit(dec)
    server.submit(queued)
    server.step()  # admits keep + dec (2 slots); q stays queued
    assert server.cancel("q") and server.cancel("dec")
    assert not server.cancel("nope")
    out = server.run()
    assert set(out) == {"keep"}
    np.testing.assert_array_equal(_reference(params, keep), out["keep"].tokens)
    assert server.stats["cancelled"] == 2
    assert server._pool.used_count == 0


# ---------------------------------------------------------------------------
# modes + kernel routing
# ---------------------------------------------------------------------------


def test_ensemble_mode_matches_scan_engine():
    popn = jax.vmap(lambda k: M.init_params(k, CFG))(jax.random.split(KEY, 3))
    reqs = _mixed_requests(seed=5)[:4]
    server = batching.ContinuousServer.from_trained(
        popn, CFG, mode="ensemble", page_size=4, max_slots=2, num_pages=32)
    out = server.run(reqs)
    for r in reqs:
        expect = np.asarray(serving.generate(
            popn, CFG, {"tokens": jnp.asarray(r.tokens)[None]}, r.max_new,
            mode="ensemble"))[0]
        np.testing.assert_array_equal(expect, out[r.uid].tokens)
    assert batching.decode_trace_count() == 1


def test_member_mode_routes_params():
    from repro.core import population as pop

    popn = jax.vmap(lambda k: M.init_params(k, CFG))(jax.random.split(KEY, 3))
    req = _mixed_requests(seed=6)[0]
    server = batching.ContinuousServer.from_trained(
        popn, CFG, mode="member", member=1, page_size=4, max_slots=2,
        num_pages=32)
    out = server.run([req])
    direct = _reference(pop.member(popn, 1), req)
    np.testing.assert_array_equal(direct, out[req.uid].tokens)


def test_pallas_kernel_path_matches_reference_tokens():
    """use_pallas=True routes the attend through the fused kernel
    (interpret mode here) — same tokens as the jnp oracle path."""
    params = _params()
    reqs = _mixed_requests(seed=7)[:3]
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32,
                                       use_pallas=True)
    out = server.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_unsupported_cache_layouts_rejected():
    mla = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, mla=True, kv_lora_rank=16,
                      qk_nope_dim=8, qk_rope_dim=8, v_head_dim=8,
                      dtype="float32")
    with pytest.raises(NotImplementedError, match="MLA"):
        batching.ContinuousServer(M.init_params(KEY, mla), mla)
    swa = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, window=8, dtype="float32")
    with pytest.raises(NotImplementedError, match="window"):
        batching.ContinuousServer(M.init_params(KEY, swa), swa)
    vlm = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, frontend="vision",
                      num_patches=3, dtype="float32")
    with pytest.raises(NotImplementedError, match="frontend"):
        batching.ContinuousServer(M.init_params(KEY, vlm), vlm)


def test_duplicate_pending_uid_rejected_but_reuse_after_completion_ok():
    """Two pending requests with one uid would silently drop a stream
    (results are keyed by uid); reuse after completion is legitimate."""
    params = _params()
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32)
    req = _mixed_requests(seed=8)[0]
    server.submit(req)
    with pytest.raises(ValueError, match="duplicate request uid"):
        server.submit(req)
    server.run()
    # completed: same uid admits again and produces the same tokens
    out = server.run([req])
    np.testing.assert_array_equal(_reference(params, req), out[req.uid].tokens)


def test_sampling_requires_per_request_key():
    server = batching.ContinuousServer(_params(), CFG, temperature=0.7,
                                       page_size=4, max_slots=2,
                                       num_pages=16)
    with pytest.raises(ValueError, match="per-request PRNG key"):
        server.submit(batching.Request(0, np.zeros((4,), np.int32), 2))
