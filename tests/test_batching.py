"""Continuous-batching runtime (repro/serving/batching.py).

Contracts under test:
  * a request served through a busy continuous batch is token-for-token
    identical to serving it alone via ``engine.generate_reference`` with
    the same key (greedy AND temperature) — staggered admissions and
    retirements change scheduling, never semantics;
  * the decode-step program compiles exactly ONCE for a whole mixed-length
    stream, across every admission/retirement (trace counter — same
    contract as the scan engine's per-shape guarantee, strengthened to one
    compile TOTAL); a second stream on the same server adds zero traces;
  * full prompt pages shared between in-flight requests are deduped via
    the chained prefix hash, refcounted, and freed when the last holder
    retires (pool returns to empty);
  * ensemble mode averages member logits before sampling (oracle: the
    scan engine's ensemble mode, itself parity-tested against the
    explicit vmap loop);
  * the Pallas paged-attention path (interpret on CPU) produces the same
    tokens as the jnp gather oracle path;
  * unsupported cache layouts (MLA, SSM state, sliding window, modality
    prefixes) are rejected loudly, and sampling without a per-request key
    is rejected like in ``engine.generate``;
  * the decode executable is keyed by (geometry, kv_dtype, draft_k):
    every distinct speculative draft length or KV dtype costs exactly
    one trace, and same-key servers share one executable;
  * int8 paged KV tracks the fp32 pools within the pinned logit
    tolerance (program-level), and on the pinned mixed stream emits
    fp32-identical tokens — speculative + int8 compose.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as M
from repro.serving import batching
from repro.serving import engine as serving

KEY = jax.random.key(0)

CFG = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                  d_ff=64, vocab_size=50, dtype="float32")

# (prompt_len, max_new) pairs with staggered finishes: slots retire and
# re-admit mid-stream (max_slots below is smaller than the request count)
MIXED = [(5, 6), (9, 3), (3, 8), (12, 1), (7, 5), (4, 4)]


def _params():
    return M.init_params(KEY, CFG)


def _mixed_requests(temperature=0.0, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (S, mn) in enumerate(MIXED):
        prompt = rng.integers(0, CFG.vocab_size, size=(S,)).astype(np.int32)
        key = jax.random.key(100 + i) if temperature > 0 else None
        reqs.append(batching.Request(i, prompt, mn, key=key))
    return reqs


def _reference(params, req, temperature=0.0):
    return np.asarray(serving.generate_reference(
        params, CFG, {"tokens": jnp.asarray(req.tokens)[None]}, req.max_new,
        temperature=temperature, key=req.key,
    ))[0]


@pytest.fixture(autouse=True)
def _fresh_runtime():
    batching.reset_trace_counts()
    batching.clear_executable_cache()
    yield
    batching.clear_executable_cache()


# ---------------------------------------------------------------------------
# mixed-length stream parity + one-compile contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.8], ids=["greedy", "temp"])
def test_mixed_stream_matches_per_request_reference(temperature):
    """Staggered admissions/retirements (3 slots, 6 requests, budgets from
    1 to 8 tokens) reproduce every request's solo output bitwise, with one
    decode compile for the whole stream."""
    params = _params()
    reqs = _mixed_requests(temperature)
    server = batching.ContinuousServer(
        params, CFG, temperature=temperature, page_size=4, max_slots=3,
        num_pages=32)
    out = server.run(reqs)
    assert set(out) == {r.uid for r in reqs}
    for r in reqs:
        np.testing.assert_array_equal(
            _reference(params, r, temperature), out[r.uid].tokens,
            err_msg=f"request {r.uid} (S={len(r.tokens)}, "
                    f"max_new={r.max_new}) diverged from solo serving")
    assert batching.decode_trace_count() == 1, (
        f"decode must compile once for the whole stream, "
        f"traced {batching.decode_trace_count()}x")
    # prefill compiles per distinct prompt length (shape-dependent)
    assert batching.prefill_trace_count() == len({s for s, _ in MIXED})
    assert server.stats["retired"] == len(reqs)


def test_second_stream_reuses_the_decode_executable():
    params = _params()
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=3, num_pages=32)
    server.run(_mixed_requests(seed=1))
    assert batching.decode_trace_count() == 1
    out = server.run(_mixed_requests(seed=2))
    assert batching.decode_trace_count() == 1, "re-traced on second stream"
    # second stream's requests are all present and still reference-exact
    for r in _mixed_requests(seed=2):
        np.testing.assert_array_equal(
            _reference(params, r), out[r.uid].tokens)


def test_single_step_admission_and_inflight_mix():
    """step() admits what fits and decodes everyone in flight; queue
    drains as slots retire (the continuous part of continuous batching)."""
    params = _params()
    reqs = _mixed_requests()
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32)
    for r in reqs:
        server.submit(r)
    assert server.queue_len == len(reqs)
    seen_active = 0
    finished = []
    for _ in range(100):
        finished += server.step()
        seen_active = max(seen_active, server.active_slots)
        if not server.queue_len and not server.active_slots:
            break
    assert sorted(finished) == [r.uid for r in reqs]
    assert seen_active == 2  # both slots actually ran concurrently


# ---------------------------------------------------------------------------
# paged pool: prefix dedup + refcounted frees
# ---------------------------------------------------------------------------


def test_prefix_pages_are_shared_and_refcount_freed():
    params = _params()
    rng = np.random.default_rng(3)
    shared = rng.integers(0, CFG.vocab_size, size=(8,)).astype(np.int32)
    a = np.concatenate([shared, rng.integers(0, 50, size=(3,)).astype(np.int32)])
    b = np.concatenate([shared, rng.integers(0, 50, size=(5,)).astype(np.int32)])
    c = rng.integers(0, CFG.vocab_size, size=(11,)).astype(np.int32)
    reqs = [batching.Request("a", a, 5), batching.Request("b", b, 4),
            batching.Request("c", c, 3)]
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=3, num_pages=32)
    out = server.run(reqs)
    # the 8-token shared prefix is 2 full pages at page_size=4: request b
    # (admitted while a is in flight) reuses both
    assert server.stats["pages_shared"] == 2, server.stats
    # refcounted frees: the drained pool is completely empty again
    assert server._pool.used_count == 0
    assert not server._pool.refcount and not server._pool.prefix
    # sharing pages never changes tokens
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)


def test_page_pressure_queues_without_deadlock():
    """A pool too small for all requests at once still serves the stream
    (admission reserves worst-case pages; head-of-line waits for frees)."""
    params = _params()
    rng = np.random.default_rng(4)
    reqs = [batching.Request(i, rng.integers(0, 50, (9,)).astype(np.int32), 6)
            for i in range(4)]
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=4, num_pages=8)
    out = server.run(reqs)
    assert len(out) == 4
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)
    assert server.stats["peak_pages_in_use"] <= 7


def test_oversized_request_rejected_at_submit():
    params = _params()
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=8)
    big = np.zeros((40,), np.int32)
    with pytest.raises(ValueError, match="pages"):
        server.submit(batching.Request("big", big, 8))


# ---------------------------------------------------------------------------
# prefill-only retirement, same-step dedup, chunked geometry
# ---------------------------------------------------------------------------


def test_max_new_one_retires_at_prefill():
    """max_new=1: the prefill program's sampled token completes the
    request, so it must retire WITHOUT ever occupying the decode batch
    (a decode dispatch for it would read an uninitialized slot)."""
    params = _params()
    rng = np.random.default_rng(9)
    reqs = [batching.Request(i, rng.integers(0, 50, (s,)).astype(np.int32), 1)
            for i, s in enumerate([7, 4])]
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=16)
    out = server.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)
    assert server.stats["decode_steps"] == 0
    assert server.stats["retired"] == 2
    assert server._pool.used_count == 0


def test_same_step_prefix_dedup():
    """Two requests sharing a prompt prefix, admitted by the SAME step()
    call: the first admission's prefill registers its page digests before
    the second admission runs, so the second must share, not recompute."""
    params = _params()
    rng = np.random.default_rng(10)
    shared = rng.integers(0, 50, (8,)).astype(np.int32)
    a = np.concatenate([shared, rng.integers(0, 50, (3,)).astype(np.int32)])
    b = np.concatenate([shared, rng.integers(0, 50, (5,)).astype(np.int32)])
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32)
    server.submit(batching.Request("a", a, 4))
    server.submit(batching.Request("b", b, 4))
    server.step()  # one step admits BOTH (two free slots)
    assert server.stats["admitted"] == 2
    assert server.stats["pages_shared"] == 2  # the 8-token prefix = 2 pages
    assert server.stats["prefix_tokens_reused"] == 8
    out = server.run()
    for uid, prompt in (("a", a), ("b", b)):
        np.testing.assert_array_equal(
            _reference(params, batching.Request(uid, prompt, 4)),
            out[uid].tokens)


def test_chunked_prefill_same_tokens_fewer_trace_shapes():
    """prefill_chunk splits every admission into fixed-size chunk
    programs: tokens stay bitwise identical and the compiled prefill
    shapes collapse to {chunk, remainders} instead of one per prompt
    length."""
    params = _params()
    reqs = _mixed_requests(seed=11)
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=3, num_pages=32,
                                       prefill_chunk=4)
    out = server.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)
    assert batching.decode_trace_count() == 1
    # chunk lengths are min(4, remaining): {4} plus short remainders —
    # never more shapes than the chunk size
    assert batching.prefill_trace_count() <= 4


# ---------------------------------------------------------------------------
# LRU retention: revival, eviction under pressure, stall recovery
# ---------------------------------------------------------------------------


def test_lru_retention_revives_prefix_pages():
    """retain_pages: a drained request's hashed pages park on the LRU
    list; resubmitting the same prompt revives them and prefills ONLY
    the uncached suffix (token accounting by the server's counters)."""
    params = _params()
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, 50, (11,)).astype(np.int32)
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32,
                                       retain_pages=True)
    out1 = server.run([batching.Request("r1", prompt, 4)])
    assert server._pool.retained_count > 0
    assert not server._pool.refcount
    before = dict(server.stats)
    out2 = server.run([batching.Request("r2", prompt, 4)])
    np.testing.assert_array_equal(out1["r1"].tokens, out2["r2"].tokens)
    assert server.stats["lru_hits"] > 0
    # 11 tokens at page_size=4: 2 full prompt pages (8 tokens) are
    # cacheable; the resubmission prefills only the 3-token suffix
    assert server.stats["prefix_tokens_reused"] - before["prefix_tokens_reused"] == 8
    assert server.stats["prefill_tokens"] - before["prefill_tokens"] == 3
    # three-state invariant: every page is free, parked, or referenced
    pool = server._pool
    assert (pool.free_count + pool.retained_count + len(pool.refcount)
            == server.num_pages - 1)


def test_lru_eviction_recovers_from_full_parked_pool():
    """A pool whose idle pages are all parked must evict LRU-first to
    admit fresh prompts — retention never causes an admission stall."""
    params = _params()
    rng = np.random.default_rng(13)
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=8,
                                       retain_pages=True)
    for i in range(4):  # distinct prompts, enough to cycle the tiny pool
        prompt = rng.integers(0, 50, (9,)).astype(np.int32)
        out = server.run([batching.Request(i, prompt, 3)])
        np.testing.assert_array_equal(
            _reference(params, batching.Request(i, prompt, 3)),
            out[i].tokens)
    assert server.stats["lru_evictions"] > 0
    pool = server._pool
    assert (pool.free_count + pool.retained_count + len(pool.refcount)
            == server.num_pages - 1)
    assert not pool.refcount


def test_cancel_releases_pages_at_every_stage():
    """cancel() drops a request whether queued or decoding; its pages
    return to the pool and the stream's other requests are unaffected."""
    params = _params()
    rng = np.random.default_rng(14)
    keep = batching.Request("keep", rng.integers(0, 50, (6,)).astype(np.int32), 5)
    dec = batching.Request("dec", rng.integers(0, 50, (9,)).astype(np.int32), 8)
    queued = batching.Request("q", rng.integers(0, 50, (5,)).astype(np.int32), 4)
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32)
    server.submit(keep)
    server.submit(dec)
    server.submit(queued)
    server.step()  # admits keep + dec (2 slots); q stays queued
    assert server.cancel("q") and server.cancel("dec")
    assert not server.cancel("nope")
    out = server.run()
    assert set(out) == {"keep"}
    np.testing.assert_array_equal(_reference(params, keep), out["keep"].tokens)
    assert server.stats["cancelled"] == 2
    assert server._pool.used_count == 0


# ---------------------------------------------------------------------------
# modes + kernel routing
# ---------------------------------------------------------------------------


def test_ensemble_mode_matches_scan_engine():
    popn = jax.vmap(lambda k: M.init_params(k, CFG))(jax.random.split(KEY, 3))
    reqs = _mixed_requests(seed=5)[:4]
    server = batching.ContinuousServer.from_trained(
        popn, CFG, mode="ensemble", page_size=4, max_slots=2, num_pages=32)
    out = server.run(reqs)
    for r in reqs:
        expect = np.asarray(serving.generate(
            popn, CFG, {"tokens": jnp.asarray(r.tokens)[None]}, r.max_new,
            mode="ensemble"))[0]
        np.testing.assert_array_equal(expect, out[r.uid].tokens)
    assert batching.decode_trace_count() == 1


def test_member_mode_routes_params():
    from repro.core import population as pop

    popn = jax.vmap(lambda k: M.init_params(k, CFG))(jax.random.split(KEY, 3))
    req = _mixed_requests(seed=6)[0]
    server = batching.ContinuousServer.from_trained(
        popn, CFG, mode="member", member=1, page_size=4, max_slots=2,
        num_pages=32)
    out = server.run([req])
    direct = _reference(pop.member(popn, 1), req)
    np.testing.assert_array_equal(direct, out[req.uid].tokens)


def test_pallas_kernel_path_matches_reference_tokens():
    """use_pallas=True routes the attend through the fused kernel
    (interpret mode here) — same tokens as the jnp oracle path."""
    params = _params()
    reqs = _mixed_requests(seed=7)[:3]
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32,
                                       use_pallas=True)
    out = server.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(_reference(params, r), out[r.uid].tokens)


# ---------------------------------------------------------------------------
# speculative decode + quantized KV: executable-cache keys and tolerance
# ---------------------------------------------------------------------------


def test_one_decode_program_per_geometry_draft_k_kv_dtype():
    """The decode executable is keyed by (geometry, ..., kv_dtype,
    draft_k): same-key servers share one trace; changing draft_k or
    kv_dtype adds EXACTLY one."""
    params = _params()

    def serve(**kw):
        server = batching.ContinuousServer(params, CFG, page_size=4,
                                           max_slots=3, num_pages=32, **kw)
        server.run(_mixed_requests(seed=21))

    serve(speculative=True, draft_k=3)
    assert batching.decode_trace_count() == 1
    serve(speculative=True, draft_k=3)             # same key: pure reuse
    assert batching.decode_trace_count() == 1
    serve(speculative=True, draft_k=5)             # new draft_k: one more
    assert batching.decode_trace_count() == 2
    serve()                                        # plain (draft_k=None)
    assert batching.decode_trace_count() == 3
    serve(kv_dtype="int8")                         # plain int8
    assert batching.decode_trace_count() == 4
    serve(speculative=True, draft_k=3, kv_dtype="int8")
    assert batching.decode_trace_count() == 5, (
        "every distinct (draft_k, kv_dtype) must cost exactly one trace")


def test_int8_decode_logits_track_fp32_within_tolerance():
    """The quantized-KV numeric contract at program level: prefill a
    prompt into fp32 and int8 pools, run one paged decode step against
    each, and the logits agree within the pinned tolerance (per-element
    KV error is at most half a quantization step)."""
    from repro.models import layers as L

    params = _params()
    rng = np.random.default_rng(30)
    prompt = jnp.asarray(rng.integers(0, CFG.vocab_size, (10,)), jnp.int32)
    table = jnp.arange(1, 6, dtype=jnp.int32)          # pages 1..5
    outs = {}
    for kv_dtype in (None, "int8"):
        pools = L.paged_pools_init(CFG, num_pages=8, page_size=4,
                                   num_layers=CFG.num_layers,
                                   kv_dtype=kv_dtype)
        lg, pools = M.prefill_paged(params, CFG, prompt, 0, pools, table)
        step_logits, _ = M.decode_step_paged(
            params, CFG, jnp.argmax(lg[0, -1])[None].astype(jnp.int32),
            jnp.array([10], jnp.int32), pools, table[None])
        outs[kv_dtype] = (np.asarray(lg), np.asarray(step_logits))
    for a, b in zip(outs[None], outs["int8"]):
        np.testing.assert_allclose(a, b, rtol=0.0, atol=0.1)
    assert np.argmax(outs[None][1]) == np.argmax(outs["int8"][1])


def test_int8_kv_stream_matches_fp32_tokens_on_pinned_stream():
    """End-to-end int8 serving on the pinned mixed stream: this tiny
    config's logit margins dominate the bounded KV quantization error, so
    the emitted tokens match fp32 exactly (a logit-level tolerance is the
    contract — the program-level test above — but pinning the stream
    catches any silent blow-up in quant error), and the runtime
    invariants (one trace, drained pool) hold untouched."""
    params = _params()
    fp = batching.ContinuousServer(params, CFG, page_size=4, max_slots=3,
                                   num_pages=32)
    out_fp = fp.run(_mixed_requests(seed=0))
    batching.reset_trace_counts()
    q = batching.ContinuousServer(params, CFG, page_size=4, max_slots=3,
                                  num_pages=32, kv_dtype="int8")
    out_q = q.run(_mixed_requests(seed=0))
    assert set(out_q) == set(out_fp)
    for uid in out_fp:
        np.testing.assert_array_equal(out_fp[uid].tokens, out_q[uid].tokens)
    assert batching.decode_trace_count() == 1
    assert q._pool.used_count == 0
    assert q.stats["retired"] == len(MIXED)


def test_speculative_int8_composes_and_stays_within_stream_tolerance():
    """Speculative + int8 together: the bitwise claim relaxes (a page's
    scale couples every row written to it), but the stream still serves
    completely, rolls back cleanly, and matches the plain int8 server on
    this pinned stream."""
    params = _params()
    plain = batching.ContinuousServer(params, CFG, page_size=4, max_slots=3,
                                      num_pages=32, kv_dtype="int8")
    out_plain = plain.run(_mixed_requests(seed=0))
    spec = batching.ContinuousServer(params, CFG, page_size=4, max_slots=3,
                                     num_pages=32, kv_dtype="int8",
                                     speculative=True, draft_k=4)
    out_spec = spec.run(_mixed_requests(seed=0))
    assert set(out_spec) == set(out_plain)
    for uid in out_plain:
        np.testing.assert_array_equal(out_plain[uid].tokens,
                                      out_spec[uid].tokens)
    assert spec._pool.used_count == 0
    assert spec.stats["spec_drafted"] >= spec.stats["spec_accepted"] >= 0


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_unsupported_cache_layouts_rejected():
    mla = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, mla=True, kv_lora_rank=16,
                      qk_nope_dim=8, qk_rope_dim=8, v_head_dim=8,
                      dtype="float32")
    with pytest.raises(NotImplementedError, match="MLA"):
        batching.ContinuousServer(M.init_params(KEY, mla), mla)
    swa = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, window=8, dtype="float32")
    with pytest.raises(NotImplementedError, match="window"):
        batching.ContinuousServer(M.init_params(KEY, swa), swa)
    vlm = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, frontend="vision",
                      num_patches=3, dtype="float32")
    with pytest.raises(NotImplementedError, match="frontend"):
        batching.ContinuousServer(M.init_params(KEY, vlm), vlm)


def test_duplicate_pending_uid_rejected_but_reuse_after_completion_ok():
    """Two pending requests with one uid would silently drop a stream
    (results are keyed by uid); reuse after completion is legitimate."""
    params = _params()
    server = batching.ContinuousServer(params, CFG, page_size=4,
                                       max_slots=2, num_pages=32)
    req = _mixed_requests(seed=8)[0]
    server.submit(req)
    with pytest.raises(ValueError, match="duplicate request uid"):
        server.submit(req)
    server.run()
    # completed: same uid admits again and produces the same tokens
    out = server.run([req])
    np.testing.assert_array_equal(_reference(params, req), out[req.uid].tokens)


def test_sampling_requires_per_request_key():
    server = batching.ContinuousServer(_params(), CFG, temperature=0.7,
                                       page_size=4, max_slots=2,
                                       num_pages=16)
    with pytest.raises(ValueError, match="per-request PRNG key"):
        server.submit(batching.Request(0, np.zeros((4,), np.int32), 2))
