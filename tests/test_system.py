"""End-to-end behaviour: the paper's central claim at CPU scale.

Trains a small heterogeneous population with Baseline / WASH / PAPA and
checks the qualitative pattern of Tables 2–3: WASH's uniform soup must work
(close to its ensemble) at a fraction of PAPA's communication, and WASH's
consensus distance must stay below the independently-trained baseline's
(Fig. 2).
"""

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import averaging as avg
from repro.core.mixing import MixingConfig
from repro.data import (
    apply_policy,
    eval_images,
    make_image_task,
    member_policies,
    sample_images,
    soft_cross_entropy,
)
from repro.models.cnn import ClassifierConfig, apply_classifier, init_classifier
from repro.train import train_population

KEY = jax.random.key(42)


def _setup(noise=1.4):
    task = make_image_task(KEY, num_classes=10, hw=10, noise=noise)
    ccfg = ClassifierConfig(kind="mlp", width=48, depth=2, num_classes=10, image_hw=10)
    pols = member_policies(jax.random.fold_in(KEY, 7), 3, heterogeneous=True)

    def data_fn(m, step, k):
        imgs, labels = sample_images(task, k, 48)
        x, y = apply_policy(jax.random.fold_in(k, 1), imgs, labels, 10, pols[m])
        return {"x": x, "y": y}

    def loss_fn(params, batch):
        return soft_cross_entropy(apply_classifier(params, ccfg, batch["x"]),
                                  batch["y"])

    ex, ey = eval_images(task, jax.random.fold_in(KEY, 99), 256)
    return task, ccfg, data_fn, loss_fn, ex, ey


def _train(mcfg, ccfg, data_fn, loss_fn, steps=150):
    tcfg = TrainConfig(population=3, optimizer="sgd", lr=0.08, total_steps=steps,
                       batch_size=48)
    return train_population(
        KEY, lambda k: init_classifier(k, ccfg), loss_fn, data_fn,
        tcfg, mcfg, ccfg.num_blocks, record_every=50,
    )


def test_wash_average_close_to_ensemble_and_cheaper_than_papa():
    task, ccfg, data_fn, loss_fn, ex, ey = _setup()
    apply_fn = lambda p, x: apply_classifier(p, ccfg, x)

    wash = _train(MixingConfig(kind="wash", base_p=0.05, mode="dense"),
                  ccfg, data_fn, loss_fn)
    papa = _train(MixingConfig(kind="papa", papa_every=10, papa_alpha=0.99),
                  ccfg, data_fn, loss_fn)

    ens = float(avg.ensemble_accuracy(apply_fn, wash.population, ex, ey))
    soup = float(avg.model_accuracy(apply_fn, avg.uniform_soup(wash.population), ex, ey))
    assert ens > 0.5, "population failed to learn"
    # central claim: weight averaging works under WASH (≈ ensemble accuracy)
    assert soup > ens - 0.08, (soup, ens)
    # communication: WASH ≪ PAPA (paper Table 1)
    assert wash.comm_scalars < 0.5 * papa.comm_scalars, (
        wash.comm_scalars, papa.comm_scalars)


def test_wash_consensus_distance_below_baseline():
    task, ccfg, data_fn, loss_fn, ex, ey = _setup()
    base = _train(MixingConfig(kind="none"), ccfg, data_fn, loss_fn, steps=120)
    wash = _train(MixingConfig(kind="wash", base_p=0.05, mode="dense"),
                  ccfg, data_fn, loss_fn, steps=120)
    assert wash.history["consensus"][-1] < base.history["consensus"][-1]
