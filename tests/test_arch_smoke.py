"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 architectures instantiates a REDUCED variant of the same
family (2 layers, d_model<=256, <=4 experts) and runs one forward + one
SGD train step on CPU, asserting output shapes and the absence of NaNs.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.launch.specs import concrete_batch
from repro.models import transformer as M
from repro.optim import make_optimizer

KEY = jax.random.key(0)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_forward_and_train_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    B, S = 2, 16
    params = M.init_params(KEY, cfg)
    batch = concrete_batch(cfg, KEY, B, S)

    logits, aux = M.forward_logits(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch_id}: NaN logits"

    opt_init, opt_update = make_optimizer("sgd")
    opt = opt_init(params)

    def lf(p):
        loss, _ = M.loss_fn(p, cfg, batch)
        return loss

    loss0, grads = jax.value_and_grad(lf)(params)
    params2, opt = opt_update(params, grads, opt, 0.1)
    loss1, _ = jax.value_and_grad(lf)(params2)
    assert jnp.isfinite(loss0) and jnp.isfinite(loss1), arch_id
    # one SGD step on the same batch should not increase loss much
    assert float(loss1) < float(loss0) + 0.5, (arch_id, float(loss0), float(loss1))
    for leaf in jax.tree_util.tree_leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch_id}: NaN params"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_decode_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    B, S = 2, 8
    params = M.init_params(KEY, cfg)
    batch = concrete_batch(cfg, KEY, B, S)
    prefix = cfg.num_patches if cfg.frontend == "vision" else 0
    logits, cache = M.prefill(params, cfg, batch, capacity=prefix + S + 2)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache2 = M.decode_step(params, cfg, tok, cache, prefix + S)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch_id


def test_exact_assigned_configs():
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    spec = {
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
    }
    for aid, (L, D, H, KV, F, V) in spec.items():
        c = get_arch(aid)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, D, H, KV, F, V), aid
    # family-specific invariants
    ds = get_arch("deepseek-v2-lite-16b")
    assert ds.mla and ds.kv_lora_rank == 512 and ds.top_k == 6
    assert ds.n_routed_experts == 64 and ds.n_shared_experts == 2
    kimi = get_arch("kimi-k2-1t-a32b")
    assert kimi.n_routed_experts == 384 and kimi.top_k == 8
    assert get_arch("qwen3-4b").qk_norm
    assert get_arch("qwen1.5-4b").qkv_bias
    assert get_arch("rwkv6-3b").block_kind == "rwkv6"
    assert get_arch("hymba-1.5b").block_kind == "hybrid"
    assert get_arch("hymba-1.5b").ssm_state == 16
    assert get_arch("whisper-medium").encoder_layers == 24
    assert get_arch("internvl2-76b").frontend == "vision"
