"""Fused shard_map engine vs the vmap reference loop.

The fused engine (repro.train.engine) must be a drop-in replacement: same
populations (bitwise on the 1-device CPU mesh — the collective blocked
shuffle degenerates to exactly the stacked roll), identical comm
accounting, identical history schedule, for every mixing mode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core.compat import make_mesh
from repro.core.mixing import MixingConfig
from repro.serving import averaged_params
from repro.train import train_population
from repro.train import engine as engine_mod
from repro.train.engine import (
    build_schedule,
    chunk_ranges,
    train_population_sharded,
)

from conftest import tiny_data_fn as _data_fn
from conftest import tiny_init as _init
from conftest import tiny_loss_fn as _loss_fn

KEY = jax.random.key(0)


def _run_pair(kind, optimizer="sgd", steps=13, population=4, record_every=5,
              **mix_kw):
    tcfg = TrainConfig(
        population=population, optimizer=optimizer,
        lr=0.05 if optimizer == "sgd" else 1e-3,
        total_steps=steps, batch_size=4,
    )
    mcfg = MixingConfig(kind=kind, mode="bucketed", **mix_kw)
    ref = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=record_every
    )
    engine_mod.reset_chunk_trace_count()
    fused = train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=record_every
    )
    # the compile-count contract holds for EVERY pair the parity suite runs:
    # one trace per schedule variant, never more than two
    traces = engine_mod.chunk_trace_count()
    variants = build_schedule(steps, record_every, mcfg).variants()
    assert traces == len(variants) <= 2, (kind, steps, record_every, traces)
    return ref, fused


@pytest.mark.parametrize(
    "kind,kw",
    [
        ("wash", dict(base_p=0.5)),
        ("wash_opt", dict(base_p=0.5)),
        ("papa", dict(papa_every=5, papa_alpha=0.9)),
        ("papa_all", dict(papa_all_every=4)),
        ("none", dict()),
    ],
)
def test_engines_match_all_mixing_modes(kind, kw):
    ref, fused = _run_pair(kind, **kw)
    for a, b in zip(
        jax.tree_util.tree_leaves(ref.population),
        jax.tree_util.tree_leaves(fused.population),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.comm_scalars == fused.comm_scalars
    assert ref.history["step"] == fused.history["step"]
    np.testing.assert_allclose(
        ref.history["loss"], fused.history["loss"], rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        ref.history["comm"], fused.history["comm"], rtol=0, atol=0
    )
    np.testing.assert_allclose(
        ref.history["consensus"], fused.history["consensus"], rtol=1e-5, atol=1e-6
    )


def test_wash_opt_replays_plan_on_adamw_moments():
    """WASH+Opt inside the fused step must shuffle mu AND nu with the same
    plan as the reference (comm triples, moments match bitwise)."""
    ref, fused = _run_pair("wash_opt", optimizer="adamw", base_p=0.5)
    for mk in ("mu", "nu"):
        for a, b in zip(
            jax.tree_util.tree_leaves(ref.opt_state[mk]),
            jax.tree_util.tree_leaves(fused.opt_state[mk]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    wash_ref, wash_fused = _run_pair("wash", optimizer="adamw", base_p=0.5)
    assert fused.comm_scalars == 3 * wash_fused.comm_scalars
    assert ref.comm_scalars == fused.comm_scalars


def test_engine_dispatch_via_train_population():
    """train_population(engine="shard_map") routes to the fused engine."""
    tcfg = TrainConfig(population=3, optimizer="sgd", lr=0.05, total_steps=6,
                       batch_size=4)
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    ref = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3
    )
    fused = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3,
        engine="shard_map",
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(ref.population),
        jax.tree_util.tree_leaves(fused.population),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        train_population(
            KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, engine="nope"
        )


def test_shard_map_engine_rejects_dense_plans():
    """Dense-mode WASH has no collective lowering: the fused engine must
    refuse it loudly instead of silently training a different algorithm."""
    tcfg = TrainConfig(population=2, optimizer="sgd", lr=0.05, total_steps=2,
                       batch_size=4)
    dense = MixingConfig(kind="wash", base_p=0.5, mode="dense")
    with pytest.raises(ValueError, match="bucketed"):
        train_population_sharded(
            KEY, _init, _loss_fn, _data_fn, tcfg, dense, 2
        )
    # non-WASH kinds don't read mode — dense config is fine there
    papa = MixingConfig(kind="papa", mode="dense", papa_every=2)
    train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, papa, 2, record_every=2
    )


def test_serving_consumes_either_engine():
    """averaged_params must produce the identical soup from both engines'
    results (TrainResult or bare population)."""
    ref, fused = _run_pair("wash", base_p=0.5, steps=6)
    soup_ref = averaged_params(ref)
    soup_fused = averaged_params(fused.population)
    for a, b in zip(
        jax.tree_util.tree_leaves(soup_ref),
        jax.tree_util.tree_leaves(soup_fused),
    ):
        assert a.shape == b.shape  # ens axis averaged away
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_ranges_cover_and_align():
    for total, every in [(1, 25), (13, 5), (60, 20), (7, 7), (100, 1)]:
        chunks = chunk_ranges(total, every)
        flat = [s for a, b in chunks for s in range(a, b)]
        assert flat == list(range(total))
        # every chunk ends on a reference-loop record boundary
        for _, stop in chunks:
            s = stop - 1
            assert s % every == 0 or s == total - 1


def test_explicit_mesh_roundtrips_through_train_population():
    """A caller-supplied 1-device ens mesh must reach the fused engine
    through the public API (PR 1 silently dropped it) and reproduce the
    default-mesh run bitwise; the vmap engine must reject a mesh loudly
    rather than ignore it."""
    tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05, total_steps=6,
                       batch_size=4)
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    mesh = make_mesh((1,), ("ens",))
    explicit = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3,
        engine="shard_map", mesh=mesh,
    )
    default = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3,
        engine="shard_map",
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(explicit.population),
        jax.tree_util.tree_leaves(default.population),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="mesh"):
        train_population(
            KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, mesh=mesh
        )
    with pytest.raises(ValueError, match="engine_opts"):
        train_population(
            KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2,
            engine_opts={"async_staging": False},
        )


def test_async_staging_matches_sync():
    """The double-buffered staging thread must not change data order,
    key derivation, or results — bitwise-equal to synchronous staging."""
    tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05, total_steps=9,
                       batch_size=4)
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    kw = dict(record_every=4)
    a = train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2,
        async_staging=True, **kw,
    )
    b = train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2,
        async_staging=False, **kw,
    )
    for x, y in zip(
        jax.tree_util.tree_leaves(a.population),
        jax.tree_util.tree_leaves(b.population),
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert a.history["loss"] == b.history["loss"]
    assert a.comm_scalars == b.comm_scalars


def test_comm_accounting_exact_on_synthetic_past_2pow24_plan():
    """A synthetic bucketed plan selecting > 2^24 scalars per step: the
    host-side accounting both engines share must stay integer-exact where
    a float32-carried scalar (the pre-fix scan carry) truncates."""
    from repro.core import shuffle as shf
    from repro.core.layer_index import infer_layer_ids, total_layers
    from repro.core.mixing import static_mix_comm

    n = 2
    sent = 2 ** 24 + 1          # odd -> not representable in float32
    d = n * sent
    # synthetic (n, k_per) bucketed plan: only its shape enters accounting
    plan = {"w": jax.ShapeDtypeStruct((n, sent), jnp.int32)}
    exact = float(shf.plan_sent_scalars(plan, n, mode="bucketed"))
    assert exact == sent
    assert float(jnp.float32(exact)) != exact  # the old carry truncated this

    # static_mix_comm reproduces the same count from shapes alone (no
    # device compute: eval_shape), for the config the slow e2e test runs
    member = {"w": jax.ShapeDtypeStruct((d,), jnp.float32)}
    lids = infer_layer_ids(member, 1)
    mcfg = MixingConfig(kind="wash", base_p=1.0, schedule="constant",
                        mode="bucketed")
    got = static_mix_comm(member, mcfg, lids, total_layers(1), n)
    assert got == exact


@pytest.mark.slow
def test_comm_parity_exact_past_2pow24_end_to_end():
    """Regression for the float32 comm carry: one real fused-vs-reference
    step whose plan sends 2^24+1 scalars per member.  Pre-fix, both
    engines reported 2^24 (the nearest f32); the host-side accounting must
    report the exact odd count, identically in both."""
    sent = 2 ** 24 + 1
    d = 2 * sent

    def init(k):
        return {"w": jax.random.normal(k, (d,), jnp.float32) * 0.01}

    def data_fn(m, step, k):
        return {"t": jnp.zeros((1, 1), jnp.float32)}

    def loss_fn(p, b):
        return jnp.mean(p["w"] ** 2) + 0.0 * jnp.sum(b["t"])

    tcfg = TrainConfig(population=2, optimizer="sgd", lr=0.1, total_steps=1,
                       batch_size=1)
    mcfg = MixingConfig(kind="wash", base_p=1.0, schedule="constant",
                        mode="bucketed")
    ref = train_population(
        KEY, init, loss_fn, data_fn, tcfg, mcfg, 1, record_every=1
    )
    fused = train_population_sharded(
        KEY, init, loss_fn, data_fn, tcfg, mcfg, 1, record_every=1
    )
    assert ref.comm_scalars == fused.comm_scalars == float(sent)
    assert ref.history["comm"] == fused.history["comm"] == [float(sent)]


def test_engine_opts_pallas_shuffle_parity():
    """engine_opts["pallas_shuffle"]: the fused engine's shuffle applies
    through the fused Pallas kernel (chip-local exchanges, i.e. the
    1-device mesh here).  The kernel output itself is bitwise-equal to the
    roll path (tests/test_kernels.py asserts that), but swapping it into
    the donated fori_loop changes how XLA fuses the SURROUNDING optimizer
    arithmetic — the same ~1ulp fusion sensitivity the engine docs note
    for select-masking — so the end-to-end contract here is near-exact,
    with identical comm accounting and history schedule."""
    tcfg = TrainConfig(population=4, optimizer="adamw", lr=1e-3,
                       total_steps=6, batch_size=4)
    mcfg = MixingConfig(kind="wash_opt", base_p=0.5, mode="bucketed")
    ref = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3
    )
    fused = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3,
        engine="shard_map", engine_opts={"pallas_shuffle": True},
    )
    for a, b in zip(
        jax.tree_util.tree_leaves((ref.population, ref.opt_state["mu"])),
        jax.tree_util.tree_leaves((fused.population, fused.opt_state["mu"])),
    ):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7
        )
    assert ref.comm_scalars == fused.comm_scalars
    assert ref.history["step"] == fused.history["step"]


def test_record_fn_runs_at_boundaries():
    tcfg = TrainConfig(population=2, optimizer="sgd", lr=0.05, total_steps=7,
                       batch_size=4)
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    seen = []
    res = train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3,
        record_fn=lambda step, pop_: seen.append(step) or {"probe": float(step)},
    )
    assert seen == [0, 3, 6] == res.history["step"]
    assert res.history["probe"] == [0.0, 3.0, 6.0]
