"""Fused shard_map engine vs the vmap reference loop.

The fused engine (repro.train.engine) must be a drop-in replacement: same
populations (bitwise on the 1-device CPU mesh — the collective blocked
shuffle degenerates to exactly the stacked roll), identical comm
accounting, identical history schedule, for every mixing mode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core.mixing import MixingConfig
from repro.serving import averaged_params
from repro.train import train_population
from repro.train.engine import chunk_ranges, train_population_sharded

KEY = jax.random.key(0)


def _init(k):
    ks = jax.random.split(k, 4)
    return {
        "embed": {"w": jax.random.normal(ks[0], (16, 8))},
        "blocks": [
            {"w1": jax.random.normal(ks[1], (8, 8))},
            {"w1": jax.random.normal(ks[2], (8, 8))},
        ],
        "head": {"w": jax.random.normal(ks[3], (8, 4))},
    }


def _data_fn(m, step, k):
    return {
        "x": jax.random.normal(k, (4, 16)),
        "y": jax.random.normal(jax.random.fold_in(k, 1), (4, 4)),
    }


def _loss_fn(p, b):
    h = b["x"] @ p["embed"]["w"]
    for blk in p["blocks"]:
        h = jnp.tanh(h @ blk["w1"])
    return jnp.mean((h @ p["head"]["w"] - b["y"]) ** 2)


def _run_pair(kind, optimizer="sgd", steps=13, population=4, **mix_kw):
    tcfg = TrainConfig(
        population=population, optimizer=optimizer,
        lr=0.05 if optimizer == "sgd" else 1e-3,
        total_steps=steps, batch_size=4,
    )
    mcfg = MixingConfig(kind=kind, mode="bucketed", **mix_kw)
    ref = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=5
    )
    fused = train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=5
    )
    return ref, fused


@pytest.mark.parametrize(
    "kind,kw",
    [
        ("wash", dict(base_p=0.5)),
        ("wash_opt", dict(base_p=0.5)),
        ("papa", dict(papa_every=5, papa_alpha=0.9)),
        ("none", dict()),
    ],
)
def test_engines_match_all_mixing_modes(kind, kw):
    ref, fused = _run_pair(kind, **kw)
    for a, b in zip(
        jax.tree_util.tree_leaves(ref.population),
        jax.tree_util.tree_leaves(fused.population),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.comm_scalars == fused.comm_scalars
    assert ref.history["step"] == fused.history["step"]
    np.testing.assert_allclose(
        ref.history["loss"], fused.history["loss"], rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        ref.history["comm"], fused.history["comm"], rtol=0, atol=0
    )
    np.testing.assert_allclose(
        ref.history["consensus"], fused.history["consensus"], rtol=1e-5, atol=1e-6
    )


def test_wash_opt_replays_plan_on_adamw_moments():
    """WASH+Opt inside the fused step must shuffle mu AND nu with the same
    plan as the reference (comm triples, moments match bitwise)."""
    ref, fused = _run_pair("wash_opt", optimizer="adamw", base_p=0.5)
    for mk in ("mu", "nu"):
        for a, b in zip(
            jax.tree_util.tree_leaves(ref.opt_state[mk]),
            jax.tree_util.tree_leaves(fused.opt_state[mk]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    wash_ref, wash_fused = _run_pair("wash", optimizer="adamw", base_p=0.5)
    assert fused.comm_scalars == 3 * wash_fused.comm_scalars
    assert ref.comm_scalars == fused.comm_scalars


def test_engine_dispatch_via_train_population():
    """train_population(engine="shard_map") routes to the fused engine."""
    tcfg = TrainConfig(population=3, optimizer="sgd", lr=0.05, total_steps=6,
                       batch_size=4)
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    ref = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3
    )
    fused = train_population(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3,
        engine="shard_map",
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(ref.population),
        jax.tree_util.tree_leaves(fused.population),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        train_population(
            KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, engine="nope"
        )


def test_shard_map_engine_rejects_dense_plans():
    """Dense-mode WASH has no collective lowering: the fused engine must
    refuse it loudly instead of silently training a different algorithm."""
    tcfg = TrainConfig(population=2, optimizer="sgd", lr=0.05, total_steps=2,
                       batch_size=4)
    dense = MixingConfig(kind="wash", base_p=0.5, mode="dense")
    with pytest.raises(ValueError, match="bucketed"):
        train_population_sharded(
            KEY, _init, _loss_fn, _data_fn, tcfg, dense, 2
        )
    # non-WASH kinds don't read mode — dense config is fine there
    papa = MixingConfig(kind="papa", mode="dense", papa_every=2)
    train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, papa, 2, record_every=2
    )


def test_serving_consumes_either_engine():
    """averaged_params must produce the identical soup from both engines'
    results (TrainResult or bare population)."""
    ref, fused = _run_pair("wash", base_p=0.5, steps=6)
    soup_ref = averaged_params(ref)
    soup_fused = averaged_params(fused.population)
    for a, b in zip(
        jax.tree_util.tree_leaves(soup_ref),
        jax.tree_util.tree_leaves(soup_fused),
    ):
        assert a.shape == b.shape  # ens axis averaged away
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_ranges_cover_and_align():
    for total, every in [(1, 25), (13, 5), (60, 20), (7, 7), (100, 1)]:
        chunks = chunk_ranges(total, every)
        flat = [s for a, b in chunks for s in range(a, b)]
        assert flat == list(range(total))
        # every chunk ends on a reference-loop record boundary
        for _, stop in chunks:
            s = stop - 1
            assert s % every == 0 or s == total - 1


def test_record_fn_runs_at_boundaries():
    tcfg = TrainConfig(population=2, optimizer="sgd", lr=0.05, total_steps=7,
                       batch_size=4)
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    seen = []
    res = train_population_sharded(
        KEY, _init, _loss_fn, _data_fn, tcfg, mcfg, 2, record_every=3,
        record_fn=lambda step, pop_: seen.append(step) or {"probe": float(step)},
    )
    assert seen == [0, 3, 6] == res.history["step"]
    assert res.history["probe"] == [0.0, 3.0, 6.0]
