"""Property tests over the whole serving stack (driver + continuous
runtime + paged pool).

One invariant harness (``_run_and_check``) drives randomized request
streams — arrival order, prompt/output lengths, shared-prefix families,
chunk geometry, cancellations — and asserts, for every stream:

  * **bitwise parity**: every completed request's tokens equal its solo
    ``engine.generate_reference`` output;
  * **streaming completeness**: per-token callbacks deliver exactly the
    generated suffix, in order;
  * **no page leak at drain**: free + LRU-parked + refcounted pages sum
    to the pool size, and no page is still referenced;
  * **FIFO-fair admission**: requests enter slots in submission order,
    however long the head of the line prefills;
  * **trace discipline**: one decode compile per pool geometry (shared
    by the whole module — later tests must add ZERO), and never more
    prefill-chunk shapes than the chunk size allows.

The hypothesis layer (skipped when hypothesis isn't installed — it is a
dev-only dependency) explores the stream space; the fixed-seed tests
below it pin the same invariants on handcrafted worst cases so CI
without hypothesis still exercises every branch.  ``HYPOTHESIS_PROFILE=ci``
selects a derandomized fixed-budget profile for reproducible CI runs.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as M
from repro.serving import batching
from repro.serving import engine as serving
from repro.serving.driver import QueueFull, RequestDriver

CFG = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                  d_ff=64, vocab_size=50, dtype="float32")
PARAMS = M.init_params(jax.random.key(0), CFG)
# ONE pool geometry for the whole module: the decode program compiles at
# most once across every test here (asserted by the harness)
PAGE_SIZE, MAX_SLOTS, NUM_PAGES = 4, 3, 64

_REF_CACHE = {}


def _reference(prompt, max_new):
    k = (prompt.tobytes(), max_new)
    if k not in _REF_CACHE:
        _REF_CACHE[k] = np.asarray(serving.generate_reference(
            PARAMS, CFG, {"tokens": jnp.asarray(prompt)[None]}, max_new))[0]
    return _REF_CACHE[k]


@pytest.fixture(scope="module", autouse=True)
def _fresh_module_cache():
    batching.clear_executable_cache()
    batching.reset_trace_counts()
    yield
    batching.clear_executable_cache()


def _make_prompts(spec_seed, n, prefix_family):
    """n prompts; when ``prefix_family`` is set, odd-indexed requests
    share one common prefix long enough to span whole pages."""
    rng = np.random.default_rng(spec_seed)
    common = rng.integers(0, CFG.vocab_size, (2 * PAGE_SIZE,)).astype(np.int32)
    prompts = []
    for i in range(n):
        S = int(rng.integers(1, 21))
        body = rng.integers(0, CFG.vocab_size, (S,)).astype(np.int32)
        if prefix_family and i % 2 == 1:
            body = np.concatenate([common, body])
        prompts.append(body)
    return prompts


def _run_and_check(prompts, max_news, chunk, cancels=(), retain=True):
    """The shared invariant harness.  ``cancels`` maps uid -> tick index
    at which to cancel it; every other request must still be bitwise
    exact.  Returns (driver, server) for extra per-test asserts."""
    prefill_traces_before = batching.prefill_trace_count()
    server = batching.ContinuousServer(
        PARAMS, CFG, page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
        num_pages=NUM_PAGES, retain_pages=retain)
    driver = RequestDriver(server, prefill_chunk=chunk)
    streamed = {}
    for uid, (p, mn) in enumerate(zip(prompts, max_news)):
        toks = []
        driver.submit(batching.Request(uid, p, mn),
                      on_token=lambda u, t, acc=toks: acc.append(t))
        streamed[uid] = toks
    cancels = dict(cancels)
    ticks = 0
    while driver.has_work:
        for uid, at in list(cancels.items()):
            if ticks >= at:
                driver.cancel(uid)
                del cancels[uid]
        driver.tick()
        ticks += 1
        assert ticks < 10_000, "driver failed to drain"

    for uid, (p, mn) in enumerate(zip(prompts, max_news)):
        m = driver.metrics[uid]
        if m.cancelled:
            continue
        expect = _reference(p, mn)
        np.testing.assert_array_equal(
            expect, m.tokens,
            err_msg=f"uid {uid} (S={len(p)}, max_new={mn}, chunk={chunk}) "
                    f"diverged from solo serving")
        np.testing.assert_array_equal(
            expect[len(p):], np.asarray(streamed[uid], np.int32),
            err_msg=f"uid {uid}: streamed tokens != generated suffix")

    # no page leak: every page is free, LRU-parked, or refcounted — and
    # at drain nothing may still hold a reference
    pool = server._pool
    assert not pool.refcount, f"leaked refcounts at drain: {pool.refcount}"
    assert (pool.free_count + pool.retained_count + len(pool.refcount)
            == NUM_PAGES - 1), "pool three-state invariant broken"

    # FIFO fairness: slots are granted in submission (= uid) order
    admitted = driver.admitted_order
    assert admitted == sorted(admitted), (
        f"admission reordered the queue: {admitted}")

    # trace discipline: one decode program for the module's geometry
    # (the counter is cumulative across the module — every later stream
    # must add ZERO decode traces); chunk programs are shaped by length
    # min(chunk, remaining), so one run adds at most ``chunk`` shapes
    assert batching.decode_trace_count() <= 1
    if chunk is not None:
        assert (batching.prefill_trace_count() - prefill_traces_before
                <= chunk), "more prefill shapes than chunking allows"
    return driver, server


# ---------------------------------------------------------------------------
# hypothesis layer (dev-only dependency; fixed-seed tests below cover CI)
# ---------------------------------------------------------------------------

# NOT pytest.importorskip: that would skip the WHOLE module, including
# the fixed-seed fallback tests that must run on the base image
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    settings.register_profile(
        "ci", settings(max_examples=8, deadline=None, derandomize=True))
    if os.environ.get("HYPOTHESIS_PROFILE"):
        settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])

    SETTINGS = dict(max_examples=10, deadline=None)

    @st.composite
    def stream_cases(draw):
        n = draw(st.integers(1, 5))
        seed = draw(st.integers(0, 2**31 - 1))
        prefix_family = draw(st.booleans())
        chunk = draw(st.sampled_from([None, 2, 4, 7]))
        max_news = [draw(st.integers(1, 6)) for _ in range(n)]
        return n, seed, prefix_family, chunk, max_news

    @given(stream_cases())
    @settings(**SETTINGS)
    def test_random_streams_hold_all_invariants(case):
        n, seed, prefix_family, chunk, max_news = case
        prompts = _make_prompts(seed, n, prefix_family)
        _run_and_check(prompts, max_news, chunk)

    @given(stream_cases(), st.data())
    @settings(**SETTINGS)
    def test_random_cancellations_never_leak_or_corrupt(case, data):
        n, seed, prefix_family, chunk, max_news = case
        prompts = _make_prompts(seed, n, prefix_family)
        uids = data.draw(st.sets(st.integers(0, n - 1), max_size=n))
        cancels = {u: data.draw(st.integers(0, 6)) for u in uids}
        _run_and_check(prompts, max_news, chunk,
                       cancels=tuple(cancels.items()))


# ---------------------------------------------------------------------------
# fixed-seed fallbacks: same harness, handcrafted worst cases, no
# hypothesis needed (these DO run on the base CI image)
# ---------------------------------------------------------------------------


def test_fixed_mixed_stream_with_prefix_family():
    prompts = _make_prompts(100, 5, prefix_family=True)
    _run_and_check(prompts, [6, 3, 1, 8, 4], chunk=4)


def test_fixed_whole_prompt_stream():
    prompts = _make_prompts(101, 4, prefix_family=False)
    _run_and_check(prompts, [5, 1, 4, 2], chunk=None)


def test_fixed_cancellations_at_every_stage():
    prompts = _make_prompts(102, 5, prefix_family=True)
    # uid 1 cancelled before any tick (still queued/prefilling), uid 3
    # cancelled mid-decode
    drv, server = _run_and_check(
        prompts, [4, 6, 3, 8, 2], chunk=2,
        cancels=((1, 0), (3, 4)))
    assert drv.metrics[1].cancelled
    assert server.stats["cancelled"] >= 1


def test_fixed_fifo_under_slot_pressure():
    """More requests than slots AND a long head-of-line prompt: later
    short requests may NOT overtake it in admission order."""
    rng = np.random.default_rng(103)
    prompts = [rng.integers(0, 50, (s,)).astype(np.int32)
               for s in (20, 3, 3, 3, 3, 3)]
    drv, _ = _run_and_check(prompts, [4] * 6, chunk=2)
    assert drv.admitted_order == [0, 1, 2, 3, 4, 5]


def test_fixed_backpressure_is_fifo_and_recoverable():
    """QueueFull rejects the overflow request only; the held queue still
    drains in order and stays bitwise exact."""
    server = batching.ContinuousServer(
        PARAMS, CFG, page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
        num_pages=NUM_PAGES, retain_pages=True)
    driver = RequestDriver(server, prefill_chunk=3, max_queued_tokens=40)
    rng = np.random.default_rng(104)
    prompts = [rng.integers(0, 50, (12,)).astype(np.int32) for _ in range(3)]
    driver.submit(batching.Request(0, prompts[0], 6))
    driver.submit(batching.Request(1, prompts[1], 6))
    with pytest.raises(QueueFull):
        driver.submit(batching.Request(2, prompts[2], 6))
    metrics = driver.drain()
    assert sorted(metrics) == [0, 1]
    for uid in (0, 1):
        np.testing.assert_array_equal(
            _reference(prompts[uid], 6), metrics[uid].tokens)
    assert driver.admitted_order == [0, 1]
    # the rejected request was never registered anywhere
    assert 2 not in driver.metrics and batching.decode_trace_count() <= 1
