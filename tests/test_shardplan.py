"""Shard-local mixing planner (repro.core.shardplan) + the fused engine
on ens×data×model meshes.

Host-side planner logic (axis classification, per-shard budgets, comm
volumes) runs in-process; everything that needs >1 device runs in a
subprocess with a forced 8-device CPU host (jax locks the device count at
first init), following tests/test_distributed.py.

Contracts asserted here:
  * the fused engine on an (ens=2, data=2, model=2) mesh is bitwise-equal
    to the ens-only engine for a replicated-model config, for all four
    mixing modes, with identical exact comm accounting and ≤ 2 chunk
    traces per run;
  * shard-local plans draw independent permutations per (data, model)
    shard coordinate (the plan-key fold), while unsharded leaves reproduce
    the global plan bitwise;
  * per-shard static comm volumes sum to ≤ the global-plan volume
    (equality when nothing is sharded);
  * launch/dryrun's --shard-local path is a delegator to core/shardplan
    (identical HLO collective footprint).
"""

import os
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import shardplan, shuffle as shf
from repro.core.layer_index import infer_layer_ids, total_layers
from repro.core.mixing import MixingConfig, static_mix_comm

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def fake_mesh(**shape):
    """The planner only reads axis names + sizes; no devices needed."""
    return types.SimpleNamespace(axis_names=tuple(shape), shape=shape)


MEMBER = {
    "embed": {"w": jax.ShapeDtypeStruct((32, 16), jnp.float32)},
    "blocks": {"w1": jax.ShapeDtypeStruct((2, 16, 64), jnp.float32)},
    "head": {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32)},
}
SPECS = {
    "embed": {"w": P(None, "model")},
    "blocks": {"w1": P(None, None, "model")},
    "head": {"w": P(None, "model")},
}
REPL = jax.tree_util.tree_map(
    lambda _: P(), MEMBER, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
)


def _plan(mesh, specs, n=4, kind="wash", base_p=0.5, **kw):
    mcfg = MixingConfig(kind=kind, base_p=base_p, mode="bucketed", **kw)
    lids = infer_layer_ids(MEMBER, 2)
    return shardplan.plan_population_mixing(
        mesh, MEMBER, specs, mcfg, lids, total_layers(2), n
    )


# ---------------------------------------------------------------------------
# host-side planner logic (fast, 1 device)
# ---------------------------------------------------------------------------


def test_classify_axes():
    cl = shardplan.classify_axes
    # population divides over ens×data -> data absorbed into the population
    assert cl(fake_mesh(ens=2, data=2, model=2), 4) == (("ens", "data"), ())
    assert cl(fake_mesh(ens=2, pod=2, data=2, model=4), 8) == (
        ("ens", "pod", "data"), ())
    # otherwise data splits each member's batch
    assert cl(fake_mesh(ens=2, data=2, model=2), 2) == (("ens",), ("data",))
    assert cl(fake_mesh(ens=4, data=4, model=16), 4) == (("ens",), ("data",))
    # degenerate axes drop out entirely
    assert cl(fake_mesh(ens=1, data=1, model=1), 4) == (("ens",), ())
    assert cl(fake_mesh(ens=4), 4) == (("ens",), ())
    with pytest.raises(ValueError, match="ens"):
        cl(fake_mesh(data=2), 2)
    with pytest.raises(ValueError, match="divide"):
        cl(fake_mesh(ens=3), 4)


def test_local_shard_shapes_via_spec_slicing():
    pplan = _plan(fake_mesh(ens=2, data=2, model=2), SPECS, n=4)
    by_index = {i.index: i for i in pplan.infos}
    flat, _ = jax.tree_util.tree_flatten_with_path(MEMBER)
    for idx, (path, leaf) in enumerate(flat):
        info = by_index[idx]
        assert info.member_shape == leaf.shape
        if info.sharded_dims:
            (dim, axis, lsz), = info.sharded_dims
            assert axis == "model" and lsz == leaf.shape[dim] // 2
            assert info.local_shape[dim] == lsz
            assert info.num_shards == 2
        else:
            assert info.local_shape == leaf.shape
    # the scanned layer axis is never sharded
    blocks = [i for i in pplan.infos if i.layered]
    assert len(blocks) == 1 and blocks[0].local_shape[0] == 2


def test_planner_rejects_population_axes_in_member_specs():
    bad = {**SPECS, "head": {"w": P(None, "ens")}}
    with pytest.raises(ValueError, match="population"):
        _plan(fake_mesh(ens=2, data=2, model=2), bad, n=4)


def test_shard_volumes_sum_at_most_global():
    """Per-shard exact volumes sum to ≤ the global-plan volume — by
    construction (each shard draws floor(global_budget / num_shards)), and
    exactly equal when nothing is sharded."""
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    lids = infer_layer_ids(MEMBER, 2)
    tl = total_layers(2)
    global_comm = static_mix_comm(MEMBER, mcfg, lids, tl, 4)

    sharded = _plan(fake_mesh(ens=2, data=2, model=2), SPECS, n=4)
    assert shardplan.static_shard_mix_comm(sharded) <= global_comm
    assert shardplan.static_shard_mix_comm(sharded) > 0
    # per-leaf: num_shards * per-shard sent <= the unsharded leaf's sent
    repl = _plan(fake_mesh(ens=2, data=2, model=2), REPL, n=4)
    vol_sharded = shardplan.shard_leaf_volumes(sharded)
    vol_global = shardplan.shard_leaf_volumes(repl)
    for idx, (sent, num) in vol_sharded.items():
        g_sent, g_num = vol_global[idx]
        assert g_num == 1
        assert sent * num <= g_sent

    # unsharded plan reproduces the global accounting exactly
    assert shardplan.static_shard_mix_comm(repl) == global_comm
    # PAPA moves the full member either way
    papa_s = _plan(fake_mesh(ens=2, data=2, model=2), SPECS, n=4, kind="papa")
    papa_r = _plan(fake_mesh(ens=2, data=2, model=2), REPL, n=4, kind="papa")
    d = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(MEMBER))
    assert shardplan.static_shard_mix_comm(papa_s) == d
    assert shardplan.static_shard_mix_comm(papa_r) == d


def test_stage_volumes_sum_to_the_plan_total():
    """Pipeline accounting: per-stage exact volumes are a PARTITION of the
    pipe-plan's global volume — ``static_shard_mix_comm`` reports their
    literal float64 sum, so equality holds to the last ulp."""
    from repro.sharding import rules

    lids = infer_layer_ids(MEMBER, 2)
    staged = rules.stage_member_specs(REPL, lids, "pipe")
    mesh = fake_mesh(ens=2, data=1, pipe=2)
    for kind in ("wash", "papa"):
        pplan = _plan(mesh, staged, n=4, kind=kind)
        assert pplan.num_stages == 2
        per_stage = [shardplan.static_stage_mix_comm(pplan, s)
                     for s in range(2)]
        total = shardplan.static_shard_mix_comm(pplan)
        assert sum(per_stage) == total, (kind, per_stage, total)
        assert all(v >= 0 for v in per_stage) and total > 0
        # never more than the single-stage plan moves
        single = _plan(fake_mesh(ens=2), REPL, n=4, kind=kind)
        assert total <= shardplan.static_shard_mix_comm(single) + 1e-6
    with pytest.raises(ValueError, match="stage"):
        shardplan.static_stage_mix_comm(pplan, 2)
    # a single-stage plan: stage 0 IS the whole plan
    single = _plan(fake_mesh(ens=2), REPL, n=4)
    assert single.num_stages == 1
    assert shardplan.static_stage_mix_comm(single, 0) == \
        shardplan.static_shard_mix_comm(single)


def test_unsharded_plans_match_global_plan_bitwise():
    """With no sharded leaf the builder must reproduce shf.make_plan
    exactly (same per-leaf key folds, same budgets) — this is what makes
    the multi-axis engine bitwise-recover the ens-only path."""
    pplan = _plan(fake_mesh(ens=2, data=2, model=2), REPL, n=4)
    key = jax.random.key(7)
    lids = infer_layer_ids(MEMBER, 2)
    ref = shf.make_plan(key, MEMBER, lids, total_layers(2), 0.5,
                        "decreasing", mode="bucketed", n=4)
    got = shardplan.build_local_plans(key, pplan)
    ref_l = jax.tree_util.tree_leaves(ref, is_leaf=lambda x: x is None)
    got_l = jax.tree_util.tree_leaves(got, is_leaf=lambda x: x is None)
    assert len(ref_l) == len(got_l)
    for a, b in zip(ref_l, got_l):
        assert (a is None) == (b is None)
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_make_host_mesh_clamps_to_device_count():
    from repro.launch.mesh import make_host_mesh

    # 1-device main pytest process: every kind degenerates
    assert dict(make_host_mesh(4, "ens").shape) == {"ens": 1}
    assert dict(make_host_mesh(4, "ens_dp").shape) == {"ens": 1, "data": 1}
    assert dict(make_host_mesh(4, "ens_dp_mp").shape) == {
        "ens": 1, "data": 1, "model": 1}
    with pytest.raises(ValueError, match="kind"):
        make_host_mesh(4, "nope")


def test_engine_accepts_degenerate_3d_mesh_bitwise():
    """The multi-axis engine body on a (1,1,1) mesh must reproduce the
    default 1-device ens-only run bitwise (the shardplan mixing path vs
    mix_collective_blocked)."""
    from conftest import tiny_data_fn, tiny_init, tiny_loss_fn
    from repro.configs.base import TrainConfig
    from repro.core.compat import make_mesh
    from repro.train.engine import train_population_sharded

    key = jax.random.key(0)
    tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05, total_steps=7,
                       batch_size=4)
    for kind, kw in [("wash_opt", dict(base_p=0.5)),
                     ("papa", dict(papa_every=3))]:
        mcfg = MixingConfig(kind=kind, mode="bucketed", **kw)
        ref = train_population_sharded(
            key, tiny_init, tiny_loss_fn, tiny_data_fn, tcfg, mcfg, 2,
            record_every=3,
        )
        got = train_population_sharded(
            key, tiny_init, tiny_loss_fn, tiny_data_fn, tcfg, mcfg, 2,
            record_every=3, mesh=make_mesh((1, 1, 1), ("ens", "data", "model")),
        )
        for a, b in zip(jax.tree_util.tree_leaves(ref.population),
                        jax.tree_util.tree_leaves(got.population)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ref.comm_scalars == got.comm_scalars
        assert ref.history["loss"] == got.history["loss"]


def test_param_specs_rejected_on_ens_only_mesh():
    from conftest import tiny_data_fn, tiny_init, tiny_loss_fn
    from repro.configs.base import TrainConfig
    from repro.train.engine import train_population_sharded

    tcfg = TrainConfig(population=2, optimizer="sgd", lr=0.05, total_steps=2,
                       batch_size=4)
    mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
    with pytest.raises(ValueError, match="multi-axis"):
        train_population_sharded(
            jax.random.key(0), tiny_init, tiny_loss_fn, tiny_data_fn,
            tcfg, mcfg, 2, param_specs={"anything": P()},
        )


# ---------------------------------------------------------------------------
# multi-device execution (subprocess, forced 8-device host)
# ---------------------------------------------------------------------------

_COMMON = """
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import TrainConfig
        from repro.core.compat import make_mesh
        from repro.core.mixing import MixingConfig
        from repro.train import engine as engine_mod
        from repro.train.engine import train_population_sharded

        KEY = jax.random.key(0)

        def init(k):
            ks = jax.random.split(k, 4)
            return {"embed": {"w": jax.random.normal(ks[0], (16, 8))},
                    "blocks": [{"w1": jax.random.normal(ks[1], (8, 8))},
                               {"w1": jax.random.normal(ks[2], (8, 8))}],
                    "head": {"w": jax.random.normal(ks[3], (8, 4))}}

        def data_fn(m, step, k):
            return {"x": jax.random.normal(k, (4, 16)),
                    "y": jax.random.normal(jax.random.fold_in(k, 1), (4, 4))}

        def loss_fn(p, b):
            h = b["x"] @ p["embed"]["w"]
            for blk in p["blocks"]:
                h = jnp.tanh(h @ blk["w1"])
            return jnp.mean((h @ p["head"]["w"] - b["y"]) ** 2)

        SPECS = {"embed": {"w": P(None, "model")},
                 "blocks": [{"w1": P(None, "model")}, {"w1": P(None, "model")}],
                 "head": {"w": P(None, "model")}}

        def leaves_np(tree):
            return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]

        MESH3 = make_mesh((2, 2, 2), ("ens", "data", "model"))
"""


@pytest.mark.slow
def test_3d_mesh_bitwise_parity_all_mixing_modes():
    """The acceptance contract: fused engine on (ens=2, data=2, model=2)
    == the ens-only engine bitwise for a replicated-model config, for all
    4 mixing modes, with identical exact comm accounting and ≤ 2 chunk
    traces per run."""
    out = _run(_COMMON + """
        mesh1 = make_mesh((4,), ("ens",))
        for kind, kw in [("wash", dict(base_p=0.5)),
                         ("wash_opt", dict(base_p=0.5)),
                         ("papa", dict(papa_every=3, papa_alpha=0.9)),
                         ("none", dict())]:
            tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05,
                               total_steps=7, batch_size=4)
            mcfg = MixingConfig(kind=kind, mode="bucketed", **kw)
            ref = train_population_sharded(KEY, init, loss_fn, data_fn,
                                           tcfg, mcfg, 2, record_every=3,
                                           mesh=mesh1)
            engine_mod.reset_chunk_trace_count()
            got = train_population_sharded(KEY, init, loss_fn, data_fn,
                                           tcfg, mcfg, 2, record_every=3,
                                           mesh=MESH3)
            traces = engine_mod.chunk_trace_count()
            assert traces <= 2, (kind, traces)
            for a, b in zip(leaves_np(ref.population),
                            leaves_np(got.population)):
                np.testing.assert_array_equal(a, b)
            assert ref.comm_scalars == got.comm_scalars, kind
            assert ref.history["loss"] == got.history["loss"], kind
            assert ref.history["comm"] == got.history["comm"], kind
            print(f"OK {kind} traces={traces}")
        print("OK all modes")
        """)
    assert "OK all modes" in out


@pytest.mark.slow
def test_3d_mesh_sharded_members():
    """Model-sharded members: elementwise mixing kinds stay bitwise-equal
    to the ens-only engine (gather → grad → slice is exact); WASH draws
    different (shard-local) plans but remains an exact permutation — the
    per-coordinate multiset across members is preserved.  With the
    population not dividing ens×data, batches split over the data axis and
    parity is numeric (mean-of-means), not bitwise."""
    out = _run(_COMMON + """
        mesh1 = make_mesh((4,), ("ens",))
        for kind, kw in [("none", dict()),
                         ("papa", dict(papa_every=3, papa_alpha=0.9))]:
            tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05,
                               total_steps=7, batch_size=4)
            mcfg = MixingConfig(kind=kind, mode="bucketed", **kw)
            ref = train_population_sharded(KEY, init, loss_fn, data_fn,
                                           tcfg, mcfg, 2, record_every=3,
                                           mesh=mesh1)
            got = train_population_sharded(KEY, init, loss_fn, data_fn,
                                           tcfg, mcfg, 2, record_every=3,
                                           mesh=MESH3, param_specs=SPECS)
            for a, b in zip(leaves_np(ref.population),
                            leaves_np(got.population)):
                np.testing.assert_array_equal(a, b)
            print("OK sharded", kind)

        # sharded WASH: exact permutation per shard
        tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05,
                           total_steps=1, batch_size=4)
        mcfg = MixingConfig(kind="wash", mode="bucketed", base_p=0.9)
        ref = train_population_sharded(KEY, init, loss_fn, data_fn, tcfg,
                                       mcfg, 2, record_every=1, mesh=mesh1)
        got = train_population_sharded(KEY, init, loss_fn, data_fn, tcfg,
                                       mcfg, 2, record_every=1, mesh=MESH3,
                                       param_specs=SPECS)
        moved = 0.0
        for a, b in zip(leaves_np(ref.population), leaves_np(got.population)):
            np.testing.assert_allclose(np.sort(a, 0), np.sort(b, 0), rtol=1e-6)
            moved += float(np.sum(a != b))
        assert moved > 0, "shard-local plans identical to global plans?"
        print("OK sharded wash multiset")

        # dp mode: population 2 on the same mesh -> batches split over data
        tcfg = TrainConfig(population=2, optimizer="sgd", lr=0.05,
                           total_steps=5, batch_size=4)
        mcfg = MixingConfig(kind="wash", mode="bucketed", base_p=0.5)
        ref = train_population_sharded(KEY, init, loss_fn, data_fn, tcfg,
                                       mcfg, 2, record_every=2,
                                       mesh=make_mesh((2,), ("ens",)))
        got = train_population_sharded(KEY, init, loss_fn, data_fn, tcfg,
                                       mcfg, 2, record_every=2, mesh=MESH3)
        for a, b in zip(leaves_np(ref.population), leaves_np(got.population)):
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)
        print("OK dp mode")
        """)
    assert "OK dp mode" in out


@pytest.mark.slow
def test_shard_plans_fold_position_per_shard():
    """The plan-key fold: a model-sharded leaf draws a different plan on
    each model coordinate (fold_in(leaf_key, shard_pos)), reproducible
    host-side; unsharded leaves fold nothing and agree across chips."""
    out = _run("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import shardplan, shuffle as shf
        from repro.core.compat import make_mesh, shard_map
        from repro.core.layer_index import infer_layer_ids, total_layers
        from repro.core.mixing import MixingConfig

        mesh = make_mesh((2, 2, 2), ("ens", "data", "model"))
        member = {"a": jax.ShapeDtypeStruct((32, 16), jnp.float32),
                  "b": jax.ShapeDtypeStruct((16, 8), jnp.float32)}
        specs = {"a": P(None, "model"), "b": P()}
        mcfg = MixingConfig(kind="wash", base_p=0.8, schedule="constant",
                            mode="bucketed")
        lids = infer_layer_ids(member, 1)
        tl = total_layers(1)
        pplan = shardplan.plan_population_mixing(
            mesh, member, specs, mcfg, lids, tl, 4)
        key = jax.random.key(3)

        def probe():
            plans = shardplan.build_local_plans(key, pplan)
            return {k: v[None] for k, v in plans.items() if v is not None}

        f = shard_map(probe, mesh, in_specs=(),
                      out_specs={"a": P("model"), "b": P("model")},
                      check_vma=False)
        per_shard = jax.jit(f)()
        a0, a1 = np.asarray(per_shard["a"][0]), np.asarray(per_shard["a"][1])
        assert not np.array_equal(a0, a1), "sharded leaf plans must differ"

        # host-side reproduction of each shard's plan from the key fold
        infos = {i.index: i for i in pplan.infos}
        flat_keys = list(member)  # dict order == flatten order
        ia = infos[flat_keys.index("a")]
        for pos, got in ((0, a0), (1, a1)):
            k = jax.random.fold_in(key, ia.index)
            k = jax.random.fold_in(k, jnp.asarray(pos, jnp.int32))
            exp = shf.bucketed_plan(k, ia.d_local, 4, 0.0, k_per=ia.k_per_local)
            np.testing.assert_array_equal(np.asarray(exp), got)
        # unsharded leaf: all chips drew the identical (global) plan
        b0, b1 = np.asarray(per_shard["b"][0]), np.asarray(per_shard["b"][1])
        np.testing.assert_array_equal(b0, b1)
        ref = shf.make_plan(key, member, lids, tl, 0.8, "constant",
                            mode="bucketed", n=4)
        np.testing.assert_array_equal(np.asarray(ref["b"]), b0)
        print("OK plan-key fold")
        """)
    assert "OK plan-key fold" in out


@pytest.mark.slow
def test_checkpoint_roundtrip_sharded_population():
    """checkpoint.save on the fused engine's multi-device sharded output:
    leaves are explicitly gathered (no error, no silent implicit
    transfer), and restore round-trips bitwise."""
    out = _run(_COMMON + """
        import os, tempfile
        from repro.train import checkpoint

        mesh = make_mesh((4,), ("ens",))
        tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05,
                           total_steps=4, batch_size=4)
        mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
        res = train_population_sharded(KEY, init, loss_fn, data_fn, tcfg,
                                       mcfg, 2, record_every=2, mesh=mesh)
        for leaf in jax.tree_util.tree_leaves(res.population):
            assert len(leaf.sharding.device_set) > 1, "not actually sharded"
        path = os.path.join(tempfile.mkdtemp(), "pop")
        written = checkpoint.save(path, res.population)
        like = jax.tree_util.tree_map(np.asarray, res.population)
        back = checkpoint.restore(written, like)
        for a, b in zip(leaves_np(res.population), leaves_np(back)):
            np.testing.assert_array_equal(a, b)
        # restore with the sharded population as `like`: leaves come back
        # as committed device arrays on the ORIGINAL multi-device sharding
        # (not host numpy), so re-feeding the fused engine costs no
        # per-step implicit transfer.
        back_dev = checkpoint.restore(written, res.population)
        for a, b in zip(jax.tree_util.tree_leaves(res.population),
                        jax.tree_util.tree_leaves(back_dev)):
            assert isinstance(b, jax.Array)
            assert b.sharding == a.sharding
            assert len(b.sharding.device_set) > 1
        for a, b in zip(leaves_np(res.population), leaves_np(back_dev)):
            np.testing.assert_array_equal(a, b)
        print("OK checkpoint roundtrip")
        """)
    assert "OK checkpoint roundtrip" in out


@pytest.mark.slow
def test_averaged_params_runs_mean_before_gather():
    """serving.averaged_params on the fused engine's sharded population:
    the ens-mean runs on the sharded arrays first (the gathered result is
    one member's size, not N×), and the soup equals the vmap engine's
    bitwise."""
    out = _run(_COMMON + """
        from repro.core import averaging
        from repro.serving import averaged_params
        from repro.train import train_population

        tcfg = TrainConfig(population=4, optimizer="sgd", lr=0.05,
                           total_steps=6, batch_size=4)
        mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
        ref = train_population(KEY, init, loss_fn, data_fn, tcfg, mcfg, 2,
                               record_every=3)
        fused = train_population_sharded(KEY, init, loss_fn, data_fn, tcfg,
                                         mcfg, 2, record_every=3,
                                         mesh=make_mesh((4,), ("ens",)))
        for leaf in jax.tree_util.tree_leaves(fused.population):
            assert len(leaf.sharding.device_set) > 1

        # the mean itself executes on the sharded population: its output
        # exists before any host gather and is member-sized (1x moved)
        soup_dev = averaging.uniform_soup(fused.population)
        for leaf, m in zip(jax.tree_util.tree_leaves(soup_dev),
                           jax.tree_util.tree_leaves(ref.population)):
            assert isinstance(leaf, jax.Array)
            assert leaf.shape == m.shape[1:], "ens axis must be averaged out"

        soup = averaged_params(fused)
        soup_ref = averaged_params(ref)
        for a, b in zip(leaves_np(soup), leaves_np(soup_ref)):
            np.testing.assert_array_equal(a, b)
        print("OK serving soup")
        """)
    assert "OK serving soup" in out


@pytest.mark.slow
def test_dryrun_shardlocal_delegates_with_identical_hlo_collectives():
    """launch/dryrun's --shard-local mixer is a thin delegator to
    core/shardplan: both construction paths lower to byte-identical
    collective footprints (analysis.contracts accounting), and the
    shuffle exchanges appear as collective-permute."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis import contracts
        from repro.configs.base import ModelConfig
        from repro.core import population as pop, shardplan
        from repro.core.compat import make_mesh
        from repro.core.layer_index import infer_layer_ids, total_layers
        from repro.core.mixing import MixingConfig
        from repro.launch.dryrun import make_shardlocal_mixer, params_shapes
        from repro.sharding import rules

        cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2,
                          num_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32")
        mesh = make_mesh((2, 2, 2), ("ens", "data", "model"))
        params_sds = params_shapes(cfg)
        pspecs = rules.param_pspecs(params_sds, cfg, mesh)
        add_ens = lambda tree: jax.tree_util.tree_map(
            lambda s: P(*(("ens",) + tuple(s))), tree,
            is_leaf=lambda x: isinstance(x, P))
        pop_specs = add_ens(pspecs)
        opt_specs = {"mu": pop_specs, "step": P("ens")}
        mcfg = MixingConfig(kind="wash_opt", base_p=0.5, mode="bucketed")

        stack = lambda t: jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct((2,) + x.shape, x.dtype), t)
        pop_sds = stack(params_sds)
        opt_sds = {"mu": pop_sds,
                   "step": jax.ShapeDtypeStruct((2,), jnp.int32)}
        key_sds = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)

        def lower(mixer):
            return jax.jit(mixer).lower(pop_sds, opt_sds, key_sds).compile()

        via_dryrun = lower(make_shardlocal_mixer(cfg, mcfg, mesh, pop_specs,
                                                 opt_specs))
        via_core = lower(shardplan.make_shardlocal_mixer(
            mesh, mcfg, cfg.num_layers, pop_specs, opt_specs))
        f1 = contracts.collective_footprint(via_dryrun)
        f2 = contracts.collective_footprint(via_core)
        assert f1 == f2, (f1, f2)
        assert f1["counts"]["collective-permute"] > 0, f1
        print("OK delegation, collectives:", f1["bytes"])
        """)
    assert "OK delegation" in out
