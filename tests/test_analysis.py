"""Contract checker + repo lints (repro.analysis).

Four layers:

  * hlo_stats parsing regressions: async -start/-done collective pairs
    count ONCE (bytes, counts, crosspod attribution), permute pairs come
    off the -start/sync line only, and the ``input_output_alias`` header
    block parses into flat parameter numbers;
  * contracts unit surface: pair rules, flat donation offsets, clause
    evaluation against hand-written HLO, host-f64 comm checks, compile
    counters — no multi-device host needed;
  * lint rules: paired good/bad fixtures under tests/analysis_fixtures/
    per rule (the bad thread fixture models the exact unguarded
    cross-thread read repro.serving.driver shipped with), the checked
    baseline workflow, and the repo-is-clean gate over src/repro;
  * the tools/run_analysis.py entry point: green on this repo, nonzero
    on a seeded violation and on a stale waiver, and (slow) the decode
    rows of the contract matrix end to end in a forced-8-device
    subprocess.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, lint
from repro.launch import hlo_stats

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"


# ---------------------------------------------------------------------------
# hlo_stats parsing regressions
# ---------------------------------------------------------------------------

# CPU lowers collectives synchronously, so the async split is pinned with
# a hand-written module in real HLO syntax: the -start op's result is an
# (operand, result) tuple — summing its shape tokens double counts.
ASYNC_HLO = textwrap.dedent("""\
    HloModule async_pair

    ENTRY main {
      %p0 = f32[8,128]{1,0} parameter(0)
      %p1 = f32[4]{0} parameter(1)
      %ag-start = (f32[8,128]{1,0}, f32[16,128]{1,0}) all-gather-start(%p0), replica_groups={{0,1}}, dimensions={0}
      %ag-done = f32[16,128]{1,0} all-gather-done(%ag-start)
      %cp-start = (f32[4]{0}, f32[4]{0}) collective-permute-start(%p1), source_target_pairs={{0,1},{1,0}}
      %cp-done = f32[4]{0} collective-permute-done(%cp-start)
      %sync = f32[8,128]{1,0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
      ROOT %out = (f32[16,128]{1,0}, f32[4]{0}, f32[8,128]{1,0}) tuple(%ag-done, %cp-done, %sync)
    }
    """)


def test_async_pairs_count_once():
    b = hlo_stats.collective_bytes(ASYNC_HLO)
    assert b["all-gather"] == 16 * 128 * 4  # -done result only, not the
    assert b["collective-permute"] == 4 * 4  # (operand, result) tuple
    assert b["all-reduce"] == 8 * 128 * 4
    c = hlo_stats.collective_counts(ASYNC_HLO)
    assert c["all-gather"] == 1
    assert c["collective-permute"] == 1
    assert c["all-reduce"] == 1
    assert c["all-to-all"] == 0 and c["reduce-scatter"] == 0


def test_async_crosspod_attributed_from_start_line():
    # group metadata lives on -start, bytes on -done: the pairing must
    # attribute the -done bytes to the -start line's groups
    b = hlo_stats.collective_bytes(ASYNC_HLO, pod_boundary=1)
    assert b["crosspod"] == 16 * 128 * 4 + 4 * 4 + 8 * 128 * 4


def test_permute_pairs_come_from_start_not_done():
    pairs = hlo_stats.collective_permute_pairs(ASYNC_HLO)
    assert pairs == [[(0, 1), (1, 0)]]


def test_collective_result_dtypes():
    dts = hlo_stats.collective_result_dtypes(ASYNC_HLO)
    assert dts == {"all-gather": {"f32"}, "collective-permute": {"f32"},
                   "all-reduce": {"f32"}}


def test_input_output_alias_parsing():
    hlo = ('HloModule m, input_output_alias={ {0}: (0, {}, may-alias), '
           '{1}: (2, {}, must-alias) }, entry_computation_layout={()}\n')
    assert hlo_stats.input_output_aliased_params(hlo) == {0, 2}
    assert hlo_stats.input_output_aliased_params("HloModule m\n") == set()


# ---------------------------------------------------------------------------
# contracts: rules, donation offsets, clause evaluation
# ---------------------------------------------------------------------------


def test_pair_rules():
    ring = contracts.stage_ring(4)
    assert ring.ok(0, 4) and ring.ok(5, 1) and not ring.ok(0, 1)
    fwd = contracts.forward_hop(4)
    assert fwd.ok(0, 1) and fwd.ok(2, 3)
    assert not fwd.ok(3, 4)  # never wraps past the last stage
    assert not fwd.ok(1, 0)
    bwd = contracts.backward_hop(4)
    assert bwd.ok(1, 0) and bwd.ok(3, 2)
    assert not bwd.ok(0, -1) and not bwd.ok(4, 3)  # stage 0 never sends back
    with pytest.raises(ValueError):
        contracts.PairRule("sideways", 4)
    for r in (ring, fwd, bwd):
        assert r.describe()


def test_flat_donated_params_offsets():
    args = ({"a": jnp.zeros(1), "b": jnp.zeros(1)},  # leaves 0-1
            jnp.zeros(1),                            # leaf 2
            [jnp.zeros(1), jnp.zeros(1)])            # leaves 3-4
    assert contracts.flat_donated_params(args, (0,)) == (0, 1)
    assert contracts.flat_donated_params(args, (1,)) == (2,)
    assert contracts.flat_donated_params(args, (0, 2)) == (0, 1, 3, 4)
    with pytest.raises(ValueError):
        contracts.flat_donated_params(args, (3,))


def test_check_hlo_clauses():
    contract = contracts.Contract(
        name="toy",
        require_collectives=("all-gather",),
        forbid_collectives=("all-to-all",),
        counts={"all-reduce": (1, 2), "collective-permute": 1},
        permute_rules=(contracts.stage_ring(2),),
        collective_dtypes={"all-gather": ("f32",)},
    )
    rep = contracts.check_hlo(ASYNC_HLO, contract, donated_params=(0,),
                              raise_on_violation=False)
    # {0,1} pairs are ens hops on a 2-stage ring view: 0%2 != 1%2
    assert not rep.ok
    assert any("permute pair (0 -> 1)" in p for p in rep.problems)
    assert any("donated parameters [0]" in p for p in rep.problems)
    with pytest.raises(contracts.ContractViolation) as ei:
        contracts.check_hlo(ASYNC_HLO, contract, donated_params=(0,))
    assert "toy" in str(ei.value)

    ok = contracts.Contract(
        name="toy-ok",
        require_collectives=("all-gather", "collective-permute"),
        forbid_collectives=("all-to-all", "reduce-scatter"),
        counts={"all-reduce": (1, 2), "collective-permute": 1},
        permute_rules=(contracts.stage_ring(1),),
        collective_dtypes={"all-gather": ("f32",)},
    )
    assert contracts.check_hlo(ASYNC_HLO, ok).ok


def test_check_hlo_flags_wrong_dtype_and_count():
    bad_dtype = contracts.Contract(
        name="dtype", collective_dtypes={"all-gather": ("bf16",)})
    rep = contracts.check_hlo(ASYNC_HLO, bad_dtype, raise_on_violation=False)
    assert any("moves dtypes ['f32']" in p for p in rep.problems)
    bad_count = contracts.Contract(name="count", counts={"all-reduce": 3})
    rep = contracts.check_hlo(ASYNC_HLO, bad_count, raise_on_violation=False)
    assert rep.problems == ["all-reduce: 1 ops, expected 3"]


def test_lower_and_check_donation_roundtrip():
    # a jit with honored donation passes; stating donation the program
    # cannot honor (no matching output) fails — the silent-drop detector
    def inplace(x, y):
        return x + jnp.sum(y)

    c = contracts.Contract(name="donate", donate_argnums=(0,))
    args = (jnp.zeros((8,), jnp.float32), jnp.ones((4,), jnp.float32))
    assert contracts.lower_and_check(inplace, args, c).ok

    def consumes(x, y):
        # no output matches x's (8,) shape: nothing to alias into
        return jnp.sum(x) + y

    rep = contracts.lower_and_check(consumes, args, c,
                                    raise_on_violation=False)
    assert not rep.ok and "donation was dropped" in rep.problems[0]


def test_host_comm_f64_contract():
    contracts.check_host_comm_f64({"comm": 1.5, "total": 0.0})
    with pytest.raises(contracts.ContractViolation, match="not builtin"):
        contracts.check_host_comm_f64({"comm": np.float64(1.5)})
    with pytest.raises(contracts.ContractViolation, match="not builtin"):
        contracts.check_host_comm_f64({"comm": jnp.float32(1.5)})
    with pytest.raises(contracts.ContractViolation, match="not finite"):
        contracts.check_host_comm_f64({"comm": float("inf")})


def test_replay_comm_is_bit_exact():
    per = 0.1  # not exactly representable: order and width must match
    gates = [True, False, True, True, False, True]
    expect = 0.0
    for g in gates:
        if g:
            expect += per
    assert contracts.replay_comm(per, gates) == expect
    assert contracts.replay_comm(per, []) == 0.0


def test_check_compile_count():
    contracts.check_compile_count("x", 1, 1)
    contracts.check_compile_count("x", 2, (1, 2))
    with pytest.raises(contracts.ContractViolation, match="allows 1"):
        contracts.check_compile_count("x", 2, 1)
    with pytest.raises(contracts.ContractViolation, match=r"allows \[1, 2\]"):
        contracts.check_compile_count("x", 3, (1, 2))


# ---------------------------------------------------------------------------
# lint rules: paired fixtures
# ---------------------------------------------------------------------------


def _fixture(name):
    return lint.lint_file(FIXTURES / name, root=REPO)


def test_tracer_hazard_fixture_pair():
    bad = _fixture("tracer_bad.py")
    assert all(v.rule == "tracer-hazard" for v in bad)
    assert {(v.func, v.detail) for v in bad} == {
        ("bad_step", "float()"), ("bad_step", "np.mean"),
        ("bad_step", "time.time"), ("bad_step", "random.random"),
        ("bad_scan.body", ".item()"),
    }
    assert _fixture("tracer_good.py") == []


def test_f32_accumulator_fixture_pair():
    bad = _fixture("accumulator_bad.py")
    assert all(v.rule == "f32-accumulator" for v in bad)
    assert {(v.func, v.detail) for v in bad} == {
        ("<module>", "comm_total:float32"),
        ("track", "bytes_total:float32"),
        ("Meter.__init__", "comm_scalars:float32"),
    }
    assert _fixture("accumulator_good.py") == []


def test_thread_discipline_fixture_pair():
    """The bad fixture reproduces the driver defect the lint caught in
    this repo: a pump thread mutates under the lock while the caller
    thread polls the same attrs unguarded."""
    bad = _fixture("threads_bad.py")
    assert {(v.func, v.detail) for v in bad} == {
        ("BadDriver.has_work", "attr:_pending"),
        ("BadDriver.snapshot", "attr:metrics"),
    }
    assert all(v.rule == "thread-discipline" for v in bad)
    assert _fixture("threads_good.py") == []


def test_violation_keys_are_line_free():
    v = _fixture("threads_bad.py")[0]
    assert str(v.line) not in v.key.split(":")
    assert v.key == f"thread-discipline:{v.path}:{v.func}:{v.detail}"


def test_baseline_workflow(tmp_path):
    base = tmp_path / "baseline.txt"
    viols = _fixture("threads_bad.py")
    base.write_text("# header comment\n" +
                    f"{viols[0].key}  # known-benign poll, bounded staleness\n")
    loaded = lint.load_baseline(base)
    assert loaded == {viols[0].key: "known-benign poll, bounded staleness"}
    remaining, stale = lint.apply_baseline(viols, loaded)
    assert viols[0] not in remaining and len(remaining) == len(viols) - 1
    assert stale == []
    # a waiver for vanished code is itself an error
    remaining, stale = lint.apply_baseline([], loaded)
    assert stale == [viols[0].key]
    # unexplained waivers are a parse error, not a style nit
    base.write_text(f"{viols[0].key}\n")
    with pytest.raises(ValueError, match="justification"):
        lint.load_baseline(base)


def test_repo_is_clean():
    """src/repro passes all three lint rules modulo the checked baseline
    (currently empty) — the same gate tools/run_analysis.py enforces."""
    violations = lint.lint_tree(REPO)
    baseline = lint.load_baseline(REPO / "tools" / "analysis_baseline.txt")
    remaining, stale = lint.apply_baseline(violations, baseline)
    assert remaining == [], "\n".join(str(v) for v in remaining)
    assert stale == [], f"stale baseline entries: {stale}"


# ---------------------------------------------------------------------------
# tools/run_analysis.py entry point
# ---------------------------------------------------------------------------


def _run_analysis(*args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "run_analysis.py"), *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)


def test_run_analysis_lint_lane_green():
    r = _run_analysis("--skip-contracts")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "lint OK" in r.stdout


def test_run_analysis_exits_nonzero_on_seeded_violation(tmp_path):
    bad = tmp_path / "src" / "repro"
    bad.mkdir(parents=True)
    (bad / "seeded.py").write_text(
        "import numpy as np\ncomm_total = np.float32(0.0)\n")
    r = _run_analysis("--skip-contracts", "--root", str(tmp_path))
    assert r.returncode == 1
    assert "f32-accumulator" in r.stderr and "comm_total" in r.stderr


def test_run_analysis_flags_stale_baseline(tmp_path):
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "ok.py").write_text("x = 1\n")
    base = tmp_path / "base.txt"
    base.write_text("f32-accumulator:gone.py:f:comm_total:float32  # old\n")
    r = _run_analysis("--skip-contracts", "--root", str(tmp_path),
                      "--baseline", str(base))
    assert r.returncode == 1
    assert "stale baseline entry" in r.stderr


def test_run_analysis_entries_stay_in_sync():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "run_analysis_mod", REPO / "tools" / "run_analysis.py")
    saved = os.environ.get("XLA_FLAGS")
    try:
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
    finally:  # the tool injects device-forcing XLA_FLAGS at import
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved
    from repro.analysis import matrix

    assert mod.MATRIX_ENTRIES == matrix.ENTRIES
    assert set(mod.build_parser().parse_args([]).__dict__) >= {
        "root", "baseline", "rules", "entries", "skip_lint",
        "skip_contracts"}


@pytest.mark.slow
def test_run_analysis_contract_lane_decode_rows():
    """The decode rows of the contract matrix, end to end through the CI
    entry point (forced-8-device subprocess; the train rows run in the
    CI multidevice lane and repro.analysis.matrix's own run)."""
    r = _run_analysis("--skip-lint", "--entries", "scan_decode",
                      "continuous_decode")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "contract scan_decode OK" in r.stdout
    assert "contract continuous_decode OK" in r.stdout


@pytest.mark.slow
def test_matrix_catches_seeded_contract_violation():
    """A program whose HLO breaks its stated contract makes
    lower_and_check raise — driven through the real serving program with
    a deliberately wrong contract (donation on the token buffer, which
    is freshly allocated and can never alias)."""
    from repro.configs.base import ModelConfig
    from repro.models import transformer as M
    from repro.serving import engine as E

    cfg = ModelConfig(name="tiny", d_model=32, d_ff=64, num_layers=2,
                      num_heads=4, num_kv_heads=2, vocab_size=64,
                      max_position=128)
    params_sds = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    cache_sds = jax.eval_shape(lambda: M.init_cache(cfg, 2, 12))
    key_dtype = jax.eval_shape(lambda: jax.random.key(0)).dtype
    args = (params_sds, jax.ShapeDtypeStruct((2, 4), jnp.int32), cache_sds,
            jax.ShapeDtypeStruct((2, 1, cfg.vocab_size), jnp.float32),
            jax.ShapeDtypeStruct((2,), key_dtype),
            jax.ShapeDtypeStruct((), jnp.float32))
    program = E._decode_program(cfg, False, 4, 8, True)
    wrong = contracts.Contract(name="seeded", donate_argnums=(1,))
    with pytest.raises(contracts.ContractViolation,
                       match="donation was dropped"):
        contracts.lower_and_check(program, args, wrong)
