"""Integration: population training loop + serving engine."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.mixing import MixingConfig
from repro.data import make_image_task, sample_images
from repro.data.augment import soft_cross_entropy
from repro.models import transformer as M
from repro.models.cnn import ClassifierConfig, apply_classifier, init_classifier
from repro.serving import generate
from repro.train import train_population

KEY = jax.random.key(0)


def _image_setup():
    task = make_image_task(KEY, num_classes=5, hw=8)
    ccfg = ClassifierConfig(kind="mlp", width=32, depth=2, num_classes=5, image_hw=8)

    def data_fn(m, step, k):
        imgs, labels = sample_images(task, k, 32)
        return {"x": imgs, "y": jax.nn.one_hot(labels, 5)}

    def loss_fn(params, batch):
        return soft_cross_entropy(apply_classifier(params, ccfg, batch["x"]), batch["y"])

    return ccfg, data_fn, loss_fn


def test_wash_population_trains_and_communicates():
    ccfg, data_fn, loss_fn = _image_setup()
    tcfg = TrainConfig(population=3, optimizer="sgd", lr=0.05, total_steps=60,
                       batch_size=32)
    mcfg = MixingConfig(kind="wash", base_p=0.1, mode="dense")
    res = train_population(
        KEY, lambda k: init_classifier(k, ccfg), loss_fn, data_fn,
        tcfg, mcfg, ccfg.num_blocks, record_every=20,
    )
    assert res.history["loss"][-1] < res.history["loss"][0]
    assert res.comm_scalars > 0
    for leaf in jax.tree_util.tree_leaves(res.population):
        assert leaf.shape[0] == 3
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_papa_communicates_on_period_only():
    ccfg, data_fn, loss_fn = _image_setup()
    tcfg = TrainConfig(population=2, optimizer="sgd", lr=0.05, total_steps=21,
                       batch_size=32)
    mcfg = MixingConfig(kind="papa", papa_every=10, papa_alpha=0.9)
    res = train_population(
        KEY, lambda k: init_classifier(k, ccfg), loss_fn, data_fn,
        tcfg, mcfg, ccfg.num_blocks, record_every=20,
    )
    d = sum(x.size // 2 for x in jax.tree_util.tree_leaves(res.population))
    assert res.comm_scalars == 2 * d  # steps 10 and 20


def test_wash_opt_trains_with_adamw():
    ccfg, data_fn, loss_fn = _image_setup()
    tcfg = TrainConfig(population=2, optimizer="adamw", lr=1e-3, total_steps=30,
                       batch_size=32)
    mcfg = MixingConfig(kind="wash_opt", base_p=0.05, mode="bucketed")
    res = train_population(
        KEY, lambda k: init_classifier(k, ccfg), loss_fn, data_fn,
        tcfg, mcfg, ccfg.num_blocks, record_every=10,
    )
    assert res.history["loss"][-1] < res.history["loss"][0]


def test_generate_shapes_and_determinism():
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, dtype="float32")
    params = M.init_params(KEY, cfg)
    prompt = jax.random.randint(KEY, (2, 5), 0, 50)
    out1 = generate(params, cfg, {"tokens": prompt}, max_new_tokens=6)
    out2 = generate(params, cfg, {"tokens": prompt}, max_new_tokens=6)
    assert out1.shape == (2, 11)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # greedy continuation must match teacher-forced argmax on the full seq
    full_logits, _ = M.forward_logits(params, cfg, {"tokens": out1})
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(full_logits[:, 4:-1], -1)), np.asarray(out1[:, 5:])
    )


def test_checkpoint_restore_mid_stream_keeps_page_tables():
    """checkpoint round-trip while a driver holds a paged population
    mid-stream: swapping in the restored params must not disturb the
    in-flight page tables or the tokens — the KV pool and slot state are
    serving-runtime state, fully independent of the checkpointed
    weights."""
    import numpy as np

    from repro.serving import batching
    from repro.serving import engine as serving_engine
    from repro.serving.driver import RequestDriver
    from repro.train import checkpoint

    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, dtype="float32")
    popn = jax.vmap(lambda k: M.init_params(k, cfg))(jax.random.split(KEY, 3))
    server = batching.ContinuousServer.from_trained(
        popn, cfg, mode="ensemble", page_size=4, max_slots=2, num_pages=32,
        retain_pages=True)
    driver = RequestDriver(server, prefill_chunk=3)
    rng = np.random.default_rng(21)
    reqs = [batching.Request(i, rng.integers(0, 50, (s,)).astype(np.int32), 6)
            for i, s in enumerate([11, 7])]
    for r in reqs:
        driver.submit(r)
    for _ in range(6):  # mid-stream: chunked prefills and decode under way
        driver.tick()

    def _page_tables():
        return ([(pf.uid, list(pf.pages)) for pf in server._prefills]
                + [(slot.uid, list(slot.pages))
                   for slot in server._slots if slot is not None])

    tables_before = _page_tables()
    assert tables_before, "stream must still be in flight for this test"

    import tempfile

    with tempfile.TemporaryDirectory() as d:
        path = checkpoint.save(f"{d}/popn", popn)
        like = jax.eval_shape(lambda: jax.vmap(
            lambda k: M.init_params(k, cfg))(jax.random.split(KEY, 3)))
        restored = checkpoint.restore(path, like)
    # the restored stack replaces the served params mid-stream
    server.params = serving_engine.serving_params(restored, "ensemble")

    assert _page_tables() == tables_before, (
        "restore disturbed in-flight page tables")

    metrics = driver.drain()
    for r in reqs:
        expect = np.asarray(serving_engine.generate(
            popn, cfg, {"tokens": jnp.asarray(r.tokens)[None]}, r.max_new,
            mode="ensemble"))[0]
        np.testing.assert_array_equal(
            expect, metrics[r.uid].tokens,
            err_msg=f"uid {r.uid} diverged across the checkpoint swap")
    pool = server._pool
    assert not pool.refcount
    assert (pool.free_count + pool.retained_count + len(pool.refcount)
            == server.num_pages - 1)


def test_generate_vlm_position_offset():
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=50, frontend="vision", num_patches=3,
                      dtype="float32")
    params = M.init_params(KEY, cfg)
    prompt = jax.random.randint(KEY, (1, 4), 0, 50)
    patches = jax.random.normal(KEY, (1, 3, 32))
    out = generate(params, cfg, {"tokens": prompt, "patches": patches}, 5)
    assert out.shape == (1, 9)
    full_logits, _ = M.forward_logits(
        params, cfg, {"tokens": out, "patches": patches}
    )
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(full_logits[:, 3:-1], -1)), np.asarray(out[:, 4:])
    )
