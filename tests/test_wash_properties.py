"""Property tests for the WASH shuffle — the paper's Eq. (3), (4), (5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.core import shuffle as shf
from repro.core.consensus import sq_distance_to_consensus
from repro.core.schedules import layer_probability, layer_probability_array

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def leaf_cases(draw):
    n = draw(st.integers(2, 8))
    d = draw(st.integers(1, 300))
    p = draw(st.floats(0.01, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    return n, d, p, seed


# ---------------------------------------------------------------------------
# Eq. (5): the shuffle exactly preserves Σ_n ||θ_n − θ̄||²
# ---------------------------------------------------------------------------


@given(leaf_cases())
@settings(**SETTINGS)
def test_dense_preserves_consensus_distance(case):
    n, d, p, seed = case
    key = jax.random.key(seed)
    x = jax.random.normal(key, (n, d))
    perm, mask = shf.dense_plan(key, (d,), n, p)
    out = shf.dense_apply(x, perm, mask)
    d0 = sq_distance_to_consensus({"x": x})
    d1 = sq_distance_to_consensus({"x": out})
    np.testing.assert_allclose(float(d0), float(d1), rtol=1e-5)


@given(leaf_cases())
@settings(**SETTINGS)
def test_bucketed_preserves_consensus_distance(case):
    n, d, p, seed = case
    key = jax.random.key(seed)
    x = jax.random.normal(key, (n, d))
    plan = shf.bucketed_plan(key, d, n, p)
    if plan is None:
        return
    out = shf.bucketed_apply_stacked(x, plan)
    np.testing.assert_allclose(
        float(sq_distance_to_consensus({"x": x})),
        float(sq_distance_to_consensus({"x": out})),
        rtol=1e-5,
    )


# ---------------------------------------------------------------------------
# per-coordinate multiset invariance: a shuffle only *moves* values
# ---------------------------------------------------------------------------


@given(leaf_cases())
@settings(**SETTINGS)
def test_dense_is_coordinatewise_permutation(case):
    n, d, p, seed = case
    key = jax.random.key(seed)
    x = jax.random.normal(key, (n, d))
    perm, mask = shf.dense_plan(key, (d,), n, p)
    out = shf.dense_apply(x, perm, mask)
    np.testing.assert_allclose(
        np.sort(np.asarray(x), axis=0), np.sort(np.asarray(out), axis=0), rtol=1e-6
    )


@given(leaf_cases())
@settings(**SETTINGS)
def test_bucketed_is_coordinatewise_permutation(case):
    n, d, p, seed = case
    key = jax.random.key(seed)
    x = jax.random.normal(key, (n, d))
    plan = shf.bucketed_plan(key, d, n, p)
    if plan is None:
        return
    out = shf.bucketed_apply_stacked(x, plan)
    np.testing.assert_allclose(
        np.sort(np.asarray(x), axis=0), np.sort(np.asarray(out), axis=0), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# Eq. (4): E[θ̂_n] = (1-p)·θ_n + p·θ̄   (statistical, fixed tolerance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["dense", "bucketed"])
def test_expectation_matches_papa_ema(mode):
    n, d, p, reps = 4, 2000, 0.3, 400
    key = jax.random.key(0)
    x = jax.random.normal(key, (n, d))
    acc = jnp.zeros_like(x)
    for i in range(reps):
        k = jax.random.fold_in(key, i)
        if mode == "dense":
            perm, mask = shf.dense_plan(k, (d,), n, p)
            acc = acc + shf.dense_apply(x, perm, mask)
        else:
            plan = shf.bucketed_plan(k, d, n, p)
            acc = acc + shf.bucketed_apply_stacked(x, plan)
    emp = acc / reps
    if mode == "bucketed":
        # exactly-k selection: realized per-coordinate rate is k*n/d
        k_per = shf.bucket_count(d, n, p)
        p_eff = k_per * n / d
    else:
        p_eff = p
    expected = (1 - p_eff) * x + p_eff * jnp.mean(x, axis=0, keepdims=True)
    # CLT tolerances: per-coordinate estimator std ≈ sqrt(p)·spread/sqrt(reps)
    # ≈ 0.05 here; the mean |err| over 8000 coords is a tight statistic,
    # the max is a loose 5-sigma guard.
    mean_err = float(jnp.mean(jnp.abs(emp - expected)))
    max_err = float(jnp.max(jnp.abs(emp - expected)))
    assert mean_err < 0.05, mean_err
    assert max_err < 0.5, max_err


# ---------------------------------------------------------------------------
# plan determinism + communication accounting (paper Table 1)
# ---------------------------------------------------------------------------


def test_plans_are_deterministic_given_key():
    key = jax.random.key(7)
    n, d, p = 4, 500, 0.2
    p1 = shf.bucketed_plan(key, d, n, p)
    p2 = shf.bucketed_plan(key, d, n, p)
    assert jnp.array_equal(p1, p2)
    d1 = shf.dense_plan(key, (d,), n, p)
    d2 = shf.dense_plan(key, (d,), n, p)
    assert jnp.array_equal(d1[0], d2[0]) and jnp.array_equal(d1[1], d2[1])


def test_bucketed_comm_volume_is_p_d():
    """Each member sends ~p·d·(N-1)/N scalars per step — Table 1."""
    n, d, p = 4, 10000, 0.05
    plan = shf.bucketed_plan(jax.random.key(0), d, n, p)
    sent = float(shf.plan_sent_scalars(plan, n, "bucketed"))
    expect = p * d * (n - 1) / n
    assert abs(sent - expect) / expect < 0.05


def test_bucketed_indices_unique():
    plan = shf.bucketed_plan(jax.random.key(3), 4096, 4, 0.25)
    idx = np.asarray(plan).ravel()
    assert len(np.unique(idx)) == len(idx)
    assert idx.min() >= 0 and idx.max() < 4096


# ---------------------------------------------------------------------------
# Eq. (6): layer-wise schedule
# ---------------------------------------------------------------------------


def test_layer_schedule_decreasing():
    L = 10
    probs = [layer_probability(0.1, l, L, "decreasing") for l in range(L)]
    assert probs[0] == pytest.approx(0.1)
    assert probs[-1] == pytest.approx(0.0)
    assert all(a >= b for a, b in zip(probs, probs[1:]))


def test_layer_schedule_variants():
    L = 6
    inc = layer_probability_array(0.2, np.arange(L), L, "increasing")
    const = layer_probability_array(0.2, np.arange(L), L, "constant")
    assert inc[0] == 0.0 and inc[-1] == pytest.approx(0.2)
    assert np.allclose(const, 0.2)


def test_layered_bucketed_depth_profile():
    """Stacked-block leaves keep the per-layer selection profile."""
    L, d_rest, n = 8, 512, 4
    p_vec = layer_probability_array(0.5, np.arange(1, L + 1), L + 2, "decreasing")
    plan = shf.bucketed_plan_layered(jax.random.key(0), L, d_rest, n, p_vec)
    counts = np.bincount(np.asarray(plan).ravel() // d_rest, minlength=L)
    # monotone-ish decrease (allow small trim noise)
    assert counts[0] > counts[-1]
    assert counts[0] >= counts[L // 2] >= counts[-1] - 2
