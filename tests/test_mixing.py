"""Mixing strategies: WASH vs PAPA vs PAPA-all contraction behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import population as pop
from repro.core.consensus import consensus, sq_distance_to_consensus
from repro.core.layer_index import infer_layer_ids, total_layers
from repro.core.mixing import MixingConfig, mix_once, mix_stacked, mixing_due


def _population(n=4, seed=0):
    key = jax.random.key(seed)

    def init(k):
        ks = jax.random.split(k, 4)
        return {
            "embed": {"w": jax.random.normal(ks[0], (20, 8))},
            "blocks": [{"w": jax.random.normal(ks[1 + i], (8, 8))} for i in range(2)],
            "head": {"w": jax.random.normal(ks[3], (8, 4))},
        }

    p = pop.init_population(init, key, n, same_init=False)
    lids = infer_layer_ids(pop.member(p, 0), 2)
    return p, lids, total_layers(2)


def test_papa_contracts_distance_eq2():
    p, lids, tl = _population()
    cfg = MixingConfig(kind="papa", papa_alpha=0.9)
    out, _, _ = mix_once(jax.random.key(1), p, None, cfg, lids, tl)
    d0, d1 = sq_distance_to_consensus(p), sq_distance_to_consensus(out)
    np.testing.assert_allclose(float(d1), (0.9 ** 2) * float(d0), rtol=1e-5)


def test_papa_all_collapses_to_consensus():
    p, lids, tl = _population()
    cfg = MixingConfig(kind="papa_all")
    out, _, _ = mix_once(jax.random.key(1), p, None, cfg, lids, tl)
    assert float(sq_distance_to_consensus(out)) < 1e-8
    c = consensus(p)
    m0 = pop.member(out, 0)
    for a, b in zip(jax.tree_util.tree_leaves(c), jax.tree_util.tree_leaves(m0)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("mode", ["dense", "bucketed"])
def test_wash_preserves_distance(mode):
    p, lids, tl = _population()
    cfg = MixingConfig(kind="wash", base_p=0.7, mode=mode)
    out, _, comm = mix_once(jax.random.key(1), p, None, cfg, lids, tl)
    np.testing.assert_allclose(
        float(sq_distance_to_consensus(out)),
        float(sq_distance_to_consensus(p)),
        rtol=1e-4,
    )
    assert float(comm) > 0


def test_wash_opt_shuffles_momentum_with_same_plan():
    """Where a parameter moved n->m, its momentum must move identically."""
    p, lids, tl = _population()
    mu = jax.tree_util.tree_map(lambda x: x * 10.0, p)  # recognizable copy
    opt = {"mu": mu, "step": jnp.zeros((4,), jnp.int32)}
    cfg = MixingConfig(kind="wash_opt", base_p=0.9, mode="dense")
    out_p, out_o, comm = mix_once(jax.random.key(2), p, opt, cfg, lids, tl)
    for a, b in zip(
        jax.tree_util.tree_leaves(out_p), jax.tree_util.tree_leaves(out_o["mu"])
    ):
        np.testing.assert_allclose(np.asarray(a) * 10.0, np.asarray(b), rtol=1e-5)
    # double communication vs plain wash
    _, _, comm_plain = mix_once(
        jax.random.key(2), p, opt, MixingConfig(kind="wash", base_p=0.9, mode="dense"),
        lids, tl,
    )
    np.testing.assert_allclose(float(comm), 2 * float(comm_plain), rtol=1e-6)


def test_last_layer_never_shuffled_with_decreasing_schedule():
    p, lids, tl = _population()
    cfg = MixingConfig(kind="wash", base_p=1.0, mode="dense", schedule="decreasing")
    out, _, _ = mix_once(jax.random.key(3), p, None, cfg, lids, tl)
    np.testing.assert_allclose(
        np.asarray(out["head"]["w"]), np.asarray(p["head"]["w"])
    )
    # ... and the first layer IS shuffled at p=1
    assert not np.allclose(np.asarray(out["embed"]["w"]), np.asarray(p["embed"]["w"]))


def test_mixing_due_periods():
    wash = MixingConfig(kind="wash")
    papa = MixingConfig(kind="papa", papa_every=10)
    none = MixingConfig(kind="none")
    assert mixing_due(1, wash) and mixing_due(999, wash)
    assert mixing_due(10, papa) and not mixing_due(11, papa) and not mixing_due(0, papa)
    assert not mixing_due(5, none)
    windowed = MixingConfig(kind="wash", start_step=10, stop_step=20)
    assert not mixing_due(5, windowed)
    assert mixing_due(15, windowed)
    assert not mixing_due(25, windowed)


def test_mix_stacked_step_dispatch():
    p, lids, tl = _population()
    cfg = MixingConfig(kind="papa", papa_every=10, papa_alpha=0.5)
    out, _, comm = mix_stacked(7, jax.random.key(0), p, None, cfg, lids, tl)
    assert float(comm) == 0.0  # not due
    out, _, comm = mix_stacked(10, jax.random.key(0), p, None, cfg, lids, tl)
    assert float(comm) > 0.0
