"""Fixture: every tracer-hazard class, inside genuinely traced functions.

Linted by tests/test_analysis.py — never imported, never executed."""

import random
import time

import jax
import numpy as np


@jax.jit
def bad_step(x):
    scale = float(x[0])
    noise = np.mean(x)
    t0 = time.time()
    jitter = random.random()
    return x * scale + noise + t0 + jitter


def bad_scan(xs):
    def body(c, x):
        return c + x.item(), None

    return jax.lax.scan(body, 0.0, xs)
