"""Fixture: the disciplined version of threads_bad — every cross-thread
attribute access holds the lock, and a helper whose call sites all hold
it inherits lock-held status through the fixpoint."""

import threading


class GoodDriver:
    def __init__(self):
        self._lock = threading.Lock()
        self._pending = []
        self.metrics = {}
        self._thread = None

    def start(self):
        self._thread = threading.Thread(target=self._pump, daemon=True)
        self._thread.start()

    def _pump(self):
        with self._lock:
            self._pending.append(1)
            self._update()

    def _update(self):
        # called only with the lock held
        self.metrics["steps"] = len(self._pending)

    @property
    def has_work(self):
        with self._lock:
            return bool(self._pending)

    def snapshot(self):
        with self._lock:
            return dict(self.metrics)
