"""Fixture: the unguarded-cross-thread-read defect class.

Models the exact bug repro.serving.driver shipped with (and the lint
caught): a pump thread mutates state under the lock, while the caller
thread polls the same attributes with no lock at all."""

import threading


class BadDriver:
    def __init__(self):
        self._lock = threading.Lock()
        self._pending = []
        self.metrics = {}
        self._thread = None

    def start(self):
        self._thread = threading.Thread(target=self._pump, daemon=True)
        self._thread.start()

    def _pump(self):
        with self._lock:
            self._pending.append(1)
            self.metrics["steps"] = len(self._pending)

    @property
    def has_work(self):
        return bool(self._pending)

    def snapshot(self):
        return dict(self.metrics)
