"""Fixture: the exempt look-alikes of every tracer-hazard rule.

Shape/ndim/size/len metadata through int()/float(), numpy dtype
constructors and iinfo/finfo, host-side float() outside any traced
function, and jax.random draws keyed per step."""

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def good_step(x, key):
    b = int(x.shape[0])
    rank = float(x.ndim)
    lim = np.iinfo(np.int32).max
    dt = np.dtype("float32")
    noise = jax.random.normal(key, x.shape, dt)
    return x * rank + noise + jnp.full((b,), lim, jnp.int32).sum()


def host_side(x):
    # not traced: host conversions are the POINT here
    return float(np.mean(x))


def good_scan(xs):
    def body(c, x):
        return c + jnp.sum(x), None

    return jax.lax.scan(body, jnp.float32(0.0), xs)
