"""Fixture: comm/metrics accounting truncated through narrow floats."""

import jax.numpy as jnp
import numpy as np

comm_total = np.float32(0.0)


def track(batches):
    bytes_total = jnp.zeros((), jnp.float32)
    for b in batches:
        bytes_total += np.float32(b)
    return bytes_total


class Meter:
    def __init__(self):
        self.comm_scalars = np.array(0.0, dtype="float32")
