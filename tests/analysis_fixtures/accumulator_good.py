"""Fixture: exact host-float accounting, plus the deliberate device-side
f32 metric that must NOT be flagged (bare 'total' is not accounting)."""

import jax.numpy as jnp

comm_total = 0.0


def track(batches):
    bytes_total = 0.0
    for b in batches:
        bytes_total += float(b)
    return bytes_total


def device_metric(x):
    # on-device f32 reduction: a metric value, not accounting state
    total = jnp.zeros((), jnp.float32)
    return total + jnp.sum(x)
