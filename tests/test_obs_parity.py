"""Instrumentation inertness: telemetry-on vs telemetry-off runs are
bitwise identical in outputs and identical in compile counts.

Every obs hook in the engines is a host-side Python effect (a registry
write, a sink append) — nothing is traced, no device sync is added.
This suite is the contract: for the fused train engine (ens mesh and the
pipelined S=1 delegation path), the scan serving engine, and the
continuous-batching driver, a run with EVERY sink enabled must produce
the same bits and the same executable counts as a run with telemetry
hard-disabled.  It also pins the comm-volume events to the exact
``static_mix_comm`` accounting, bit-for-bit.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.layer_index import infer_layer_ids, total_layers
from repro.core.mixing import MixingConfig, static_mix_comm
from repro.core import population as pop
from repro.models import transformer as M
from repro.serving import batching
from repro.serving import engine as serving
from repro.serving.driver import RequestDriver

from tests.conftest import tiny_data_fn, tiny_init, tiny_loss_fn

TCFG = TrainConfig(population=2, optimizer="sgd", lr=0.05, total_steps=6,
                   batch_size=4, seq_len=16, seed=0)
MCFG = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
SERVE_CFG = ModelConfig(num_layers=2, d_model=32, num_heads=4,
                        num_kv_heads=2, d_ff=64, vocab_size=50,
                        dtype="float32")


@pytest.fixture(autouse=True)
def _clean_telemetry():
    obs.reset()
    yield
    obs.reset()


def _all_sinks(tmp_path):
    """Every sink the subsystem has, all attached at once."""
    return obs.configure(jsonl=str(tmp_path / "events.jsonl"),
                        memory=True, console=True)


def _assert_trees_bitwise(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# fused train engine (ens mesh + the pipelined delegation path)
# ---------------------------------------------------------------------------


def _train_sharded(record_every=3):
    from repro.train.engine import train_population_sharded

    return train_population_sharded(
        jax.random.key(0), tiny_init, tiny_loss_fn, tiny_data_fn,
        TCFG, MCFG, num_blocks=2, record_every=record_every,
    )


def test_train_engine_inert(tmp_path):
    from repro.train import engine

    tel = obs.get()
    tel.enabled = False
    engine.reset_chunk_trace_count()
    off = _train_sharded()
    traces_off = engine.chunk_trace_count()

    _all_sinks(tmp_path)
    engine.reset_chunk_trace_count()
    on = _train_sharded()
    traces_on = engine.chunk_trace_count()

    assert traces_on == traces_off <= 2
    _assert_trees_bitwise(off.population, on.population)
    _assert_trees_bitwise(off.opt_state, on.opt_state)
    assert off.comm_scalars == on.comm_scalars  # bitwise float equality
    for k in ("step", "loss", "consensus", "comm"):
        assert off.history[k] == on.history[k]


@pytest.mark.slow
def test_pipelined_engine_inert(tmp_path):
    """Same contract on the pipelined engine with real stages (S=2), which
    needs a forced multi-device CPU host, hence the subprocess (jax locks
    the device count at first init — see tests/test_pipeline.py)."""
    import os
    import subprocess
    import sys
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    src = textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax import lax
        from repro import obs
        from repro.configs.base import TrainConfig
        from repro.core.compat import make_mesh
        from repro.core.mixing import MixingConfig
        from repro.train import StageFns, engine
        from repro.train.engine import train_population_pipelined

        def init(k):
            ks = jax.random.split(k, 3)
            return {{"embed": {{"w": jax.random.normal(ks[0], (16, 8)) * .3}},
                    "blocks": {{"w1": jax.random.normal(ks[1], (4, 8, 8)) * .3}},
                    "head": {{"w": jax.random.normal(ks[2], (8, 4)) * .3}}}}

        def data_fn(m, step, k):
            return {{"x": jax.random.normal(k, (4, 16)),
                    "y": jax.random.normal(jax.random.fold_in(k, 1), (4, 4))}}

        def blocks(p, x):
            h, _ = lax.scan(lambda h, wl: (jnp.tanh(h @ wl) + h, None),
                            x, p["blocks"]["w1"])
            return h

        fns = StageFns(lambda p, b: b["x"] @ p["embed"]["w"], blocks,
                       lambda p, x, b: jnp.mean((x @ p["head"]["w"]
                                                 - b["y"]) ** 2))
        tcfg = TrainConfig(population=2, optimizer="sgd", lr=0.05,
                           total_steps=6, batch_size=4, seq_len=16, seed=0)
        mcfg = MixingConfig(kind="wash", base_p=0.5, mode="bucketed")
        mesh = make_mesh((2, 1, 2), ("ens", "data", "pipe"))

        def run():
            engine.reset_chunk_trace_count()
            res = train_population_pipelined(
                jax.random.key(0), init, fns, data_fn, tcfg, mcfg,
                num_blocks=4, record_every=3, mesh=mesh, microbatches=2)
            return res, engine.chunk_trace_count()

        obs.get().enabled = False
        off, t_off = run()
        obs.configure(jsonl={str(tmp_path / 'pipe.jsonl')!r}, memory=True)
        on, t_on = run()
        assert t_on == t_off <= 2, (t_on, t_off)
        for a, b in zip(jax.tree_util.tree_leaves(off.population),
                        jax.tree_util.tree_leaves(on.population)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert off.comm_scalars == on.comm_scalars
        assert off.history["loss"] == on.history["loss"]
        print("pipelined-inert-ok")
    """)
    r = subprocess.run([sys.executable, "-c", src], capture_output=True,
                       text=True, timeout=420, env=env, cwd=repo)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "pipelined-inert-ok" in r.stdout
    # the stream the instrumented subprocess run produced validates
    from tools.check_metrics_schema import check_stream
    assert check_stream(str(tmp_path / "pipe.jsonl")) == []


def test_train_comm_events_match_static_accounting(tmp_path):
    """The emitted comm-volume events ARE the exact static accounting:
    per-mix-step scalars equal static_mix_comm, and the cumulative totals
    replay bit-for-bit (the schema checker re-verifies this in CI)."""
    tel = _all_sinks(tmp_path)
    mem = None
    for s in tel._sinks:
        if isinstance(s, obs.MemorySink):
            mem = s
    res = _train_sharded()

    population = pop.init_population(tiny_init, jax.random.key(0),
                                     TCFG.population,
                                     same_init=TCFG.same_init)
    member_tpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), population)
    lids = infer_layer_ids(pop.member(population, 0), 2)
    expected_per = static_mix_comm(member_tpl, MCFG, lids, total_layers(2),
                                   TCFG.population)
    events = mem.named("train.comm_volume")
    assert events, "instrumented train run must emit comm-volume events"
    replay = 0.0
    for ev in events:
        assert ev["comm_per_mix_step"] == expected_per  # exact, not approx
        for _ in range(ev["mix_steps"]):
            replay += ev["comm_per_mix_step"]
        assert replay == ev["comm_total"]
    assert replay == res.comm_scalars
    # the registry counter mirrored the same adds
    assert tel.registry.counter("train.comm_scalars").value == res.comm_scalars

    # and the JSONL stream passes the schema checker with --require-comm
    tel.finalize()
    from tools.check_metrics_schema import check_stream
    assert check_stream(str(tmp_path / "events.jsonl"),
                        require_comm=True) == []


def test_vmap_loop_inert(tmp_path):
    from repro.train.loop import train_population

    def run():
        return train_population(
            jax.random.key(0), tiny_init, tiny_loss_fn, tiny_data_fn,
            TCFG, MCFG, num_blocks=2, record_every=3,
            record_fn=lambda step, p: {"probe": float(step)},
        )

    obs.get().enabled = False
    off = run()
    _all_sinks(tmp_path)
    on = run()
    _assert_trees_bitwise(off.population, on.population)
    assert off.history["loss"] == on.history["loss"]
    assert off.history["probe"] == on.history["probe"]
    assert off.comm_scalars == on.comm_scalars
    # record_fn results became metric samples
    assert (obs.get().registry.gauge("train.record.probe").value
            == on.history["probe"][-1])


# ---------------------------------------------------------------------------
# scan serving engine
# ---------------------------------------------------------------------------


def test_scan_engine_inert(tmp_path):
    params = M.init_params(jax.random.key(0), SERVE_CFG)
    req = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                        SERVE_CFG.vocab_size)}

    def run():
        serving.reset_trace_counts()
        serving.clear_executable_cache()
        out = np.asarray(serving.generate(params, SERVE_CFG, req, 8))
        return out, serving.decode_trace_count(), serving.prefill_trace_count()

    obs.get().enabled = False
    out_off, dec_off, pre_off = run()
    _all_sinks(tmp_path)
    out_on, dec_on, pre_on = run()

    np.testing.assert_array_equal(out_off, out_on)
    assert (dec_on, pre_on) == (dec_off, pre_off) == (1, 1)
    # compile counters mirror the trace counters exactly
    assert obs.get().registry.counter("compile.serve_decode").value == 1
    assert obs.get().registry.counter("compile.serve_prefill").value == 1


# ---------------------------------------------------------------------------
# continuous-batching driver
# ---------------------------------------------------------------------------


def _driver_workload():
    rng = np.random.default_rng(3)
    reqs = []
    common = rng.integers(0, SERVE_CFG.vocab_size, (8,)).astype(np.int32)
    for i in range(5):
        S = int(rng.integers(2, 14))
        body = rng.integers(0, SERVE_CFG.vocab_size, (S,)).astype(np.int32)
        if i % 2:
            body = np.concatenate([common, body])
        reqs.append(batching.Request(f"r{i}", body, 4 + i % 3))
    return reqs


def test_continuous_driver_inert(tmp_path):
    params = serving.averaged_params(
        jax.vmap(lambda k: M.init_params(k, SERVE_CFG))(
            jax.random.split(jax.random.key(0), 2)))

    def run():
        batching.clear_executable_cache()
        batching.reset_trace_counts()
        server = batching.ContinuousServer(
            params, SERVE_CFG, page_size=4, max_slots=3, num_pages=64,
            retain_pages=True)
        driver = RequestDriver(server, prefill_chunk=4)
        metrics = driver.run(_driver_workload())
        toks = {uid: np.asarray(m.tokens) for uid, m in metrics.items()}
        return (toks, batching.decode_trace_count(),
                batching.prefill_trace_count())

    obs.get().enabled = False
    toks_off, dec_off, pre_off = run()
    _all_sinks(tmp_path)
    toks_on, dec_on, pre_on = run()

    assert toks_on.keys() == toks_off.keys()
    for uid in toks_off:
        np.testing.assert_array_equal(toks_off[uid], toks_on[uid])
    assert dec_on == dec_off == 1
    assert pre_on == pre_off
    reg = obs.get().registry
    assert reg.counter("compile.cont_decode").value == dec_on
    assert reg.histogram("serve.ttft_s").count == len(toks_on)
    # the JSONL stream the run produced validates
    obs.get().finalize()
    from tools.check_metrics_schema import check_stream
    assert check_stream(str(tmp_path / "events.jsonl")) == []
