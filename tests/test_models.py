"""Model zoo behaviour: decode == forward, ring caches, MoE dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe as MOE
from repro.models import transformer as M

KEY = jax.random.key(1)

FAMILIES = {
    "dense": ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                         d_ff=128, vocab_size=100, dtype="float32"),
    "qknorm_bias": ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                               d_ff=128, vocab_size=100, qk_norm=True, qkv_bias=True,
                               dtype="float32"),
    "window": ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=100, window=8, dtype="float32"),
    "mla_moe": ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                           d_ff=128, vocab_size=100, mla=True, kv_lora_rank=32,
                           qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, moe=True,
                           n_routed_experts=4, n_shared_experts=1, top_k=2,
                           moe_d_ff=32, capacity_factor=8.0, dtype="float32"),
    "rwkv6": ModelConfig(num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                         d_ff=128, vocab_size=100, block_kind="rwkv6",
                         rwkv_head_dim=32, dtype="float32"),
    "hybrid": ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=100, block_kind="hybrid", window=8,
                          ssm_state=8, dtype="float32"),
    "whisper": ModelConfig(num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
                           num_kv_heads=4, d_ff=128, vocab_size=100,
                           pos_kind="learned", max_position=64, num_frames=8,
                           frontend="audio", dtype="float32"),
    "vlm": ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=100, frontend="vision", num_patches=4,
                       dtype="float32"),
}


def _batches(cfg, S, key=KEY):
    toks = jax.random.randint(key, (2, S + 1), 0, cfg.vocab_size)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, :S]}
    if cfg.frontend == "vision":
        pat = jax.random.normal(key, (2, cfg.num_patches, cfg.d_model))
        full["patches"] = pat
        pre["patches"] = pat
    if cfg.is_encdec:
        fr = jax.random.normal(key, (2, cfg.num_frames, cfg.d_model))
        full["frames"] = fr
        pre["frames"] = fr
    return toks, full, pre


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_decode_matches_forward(name):
    cfg = FAMILIES[name]
    S = 12
    toks, full, pre = _batches(cfg, S)
    params = M.init_params(KEY, cfg)
    logits_full, _ = M.forward_logits(params, cfg, full)
    prefix = cfg.num_patches if cfg.frontend == "vision" else 0
    _, cache = M.prefill(params, cfg, pre, capacity=prefix + S + 2)
    dec, _ = M.decode_step(params, cfg, toks[:, S : S + 1], cache, prefix + S)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, S]), np.asarray(dec[:, 0]), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_train_loss_finite_and_shapes(name):
    cfg = FAMILIES[name]
    _, full, _ = _batches(cfg, 12)
    params = M.init_params(KEY, cfg)
    loss, metrics = M.loss_fn(params, cfg, full)
    assert jnp.isfinite(loss)
    logits, _ = M.forward_logits(params, cfg, full)
    assert logits.shape == (2, 13, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))


def test_sliding_window_ring_cache_wraps():
    """Decode far beyond the window: ring cache must stay exact."""
    cfg = FAMILIES["window"]  # window=8
    S_total = 30
    toks = jax.random.randint(KEY, (1, S_total), 0, cfg.vocab_size)
    params = M.init_params(KEY, cfg)
    full, _ = M.forward_logits(params, cfg, {"tokens": toks})

    # prefill 4 tokens, then decode one-by-one to the end
    _, cache = M.prefill(params, cfg, {"tokens": toks[:, :4]}, capacity=S_total)
    outs = []
    for pos in range(4, S_total):
        lg, cache = M.decode_step(params, cfg, toks[:, pos : pos + 1], cache, pos)
        outs.append(lg[:, 0])
    # compare the last decode logits (prediction after consuming token S-1)
    np.testing.assert_allclose(
        np.asarray(full[:, -1]), np.asarray(outs[-1]), rtol=2e-3, atol=2e-3
    )


def test_moe_dispatch_matches_dense_reference():
    cfg = ModelConfig(d_model=16, moe=True, n_routed_experts=4, n_shared_experts=0,
                      top_k=2, moe_d_ff=8, capacity_factor=8.0, dtype="float32")
    p = MOE.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 7, 16))
    out, aux = MOE.moe_apply(p, cfg, x)
    xf = x.reshape(-1, 16)
    probs = jax.nn.softmax(xf @ p["router"], -1)
    tw, ti = jax.lax.top_k(probs, 2)
    tw = tw / tw.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for e in range(4):
        h = jax.nn.silu(xf @ p["experts"]["w1"][e]) * (xf @ p["experts"]["w3"][e])
        oe = h @ p["experts"]["w2"][e]
        w_e = jnp.where(ti == e, tw, 0.0).sum(-1)
        ref = ref + oe * w_e[:, None]
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, 16)), np.asarray(ref), rtol=1e-4, atol=1e-5
    )
    assert float(aux) > 0.0


def test_moe_capacity_drops_overflow():
    cfg = ModelConfig(d_model=16, moe=True, n_routed_experts=4, n_shared_experts=0,
                      top_k=2, moe_d_ff=8, capacity_factor=0.01, dtype="float32")
    p = MOE.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (4, 64, 16))
    out, _ = MOE.moe_apply(p, cfg, x)  # almost everything dropped
    assert jnp.all(jnp.isfinite(out))
    # with capacity ~0 most outputs are zero (residual-only)
    frac_zero = float(jnp.mean(jnp.all(out == 0.0, axis=-1)))
    assert frac_zero > 0.5
