"""Optimizers, schedules, synthetic data, augmentations, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (
    apply_policy,
    draw_policy,
    make_image_task,
    make_lm_task,
    member_policies,
    sample_images,
    sample_tokens,
    soft_cross_entropy,
)
from repro.data.augment import AugmentPolicy
from repro.optim import adamw_init, adamw_update, cosine_lr, sgd_init, sgd_update
from repro.train import checkpoint

KEY = jax.random.key(0)


def test_sgd_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = sgd_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = sgd_update(params, grads, state, lr=0.05, momentum=0.9,
                                   weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-3


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(params, grads, state, lr=0.05, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_cosine_schedule_endpoints():
    assert float(cosine_lr(0, 100, 0.1, 1e-4, warmup=0)) == np.float32(0.1)
    assert float(cosine_lr(100, 100, 0.1, 1e-4, warmup=0)) == np.float32(1e-4)
    # warmup ramps from 0
    assert float(cosine_lr(0, 100, 0.1, 1e-4, warmup=10)) == 0.0
    assert float(cosine_lr(5, 100, 0.1, 1e-4, warmup=10)) < 0.1


def test_image_task_deterministic_and_learnable():
    t1 = make_image_task(KEY, 10, 12)
    t2 = make_image_task(KEY, 10, 12)
    np.testing.assert_array_equal(np.asarray(t1.prototypes), np.asarray(t2.prototypes))
    imgs, labels = sample_images(t1, jax.random.fold_in(KEY, 1), 64)
    assert imgs.shape == (64, 12, 12, 3) and labels.shape == (64,)
    # nearest-prototype classifies well above chance (task is learnable)
    d = jnp.sum((imgs[:, None] - t1.prototypes[None]) ** 2, axis=(2, 3, 4))
    acc = float(jnp.mean(jnp.argmin(d, axis=1) == labels))
    assert acc > 0.8


def test_lm_task_has_markov_structure():
    task = make_lm_task(KEY, vocab=64)
    toks = sample_tokens(task, jax.random.fold_in(KEY, 1), 8, 256)
    assert toks.shape == (8, 256)
    # the empirical next-token distribution should follow the table's argmax
    pred = jnp.argmax(task.table, axis=-1)
    hits = jnp.mean(toks[:, 1:] == pred[toks[:, :-1]])
    assert float(hits) > 0.2  # ≫ 1/64 chance


def test_augment_policies_and_soft_labels():
    pols = member_policies(KEY, 4, heterogeneous=True)
    assert len(pols) == 4
    imgs, labels = sample_images(make_image_task(KEY, 10, 12), KEY, 32)
    pol = AugmentPolicy(mixup=0.5, smooth=0.1, cutmix=0.5, erase=0.15)
    out, y = apply_policy(jax.random.fold_in(KEY, 2), imgs, labels, 10, pol)
    assert out.shape == imgs.shape and y.shape == (32, 10)
    np.testing.assert_allclose(np.asarray(jnp.sum(y, axis=-1)), 1.0, rtol=1e-5)
    loss = soft_cross_entropy(jax.random.normal(KEY, (32, 10)), y)
    assert jnp.isfinite(loss)
    # homogeneous: all identity policies
    for p in member_policies(KEY, 3, heterogeneous=False):
        assert p == AugmentPolicy()


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "embed": {"w": jax.random.normal(KEY, (4, 3))},
        "blocks": [{"w": jnp.ones((2, 2))}, {"w": jnp.zeros((2, 2))}],
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    written = checkpoint.save(path, tree)
    assert written == path  # already suffixed: unchanged
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    back = checkpoint.restore(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_suffixless_path_roundtrip(tmp_path):
    """save() must report the file numpy actually wrote (<path>.npz) —
    callers printed the bare path before — and restore must accept both
    spellings, including list-indexed pytree paths (blocks[0], blocks[1])."""
    tree = {
        "blocks": [
            {"w": jax.random.normal(KEY, (3, 2))},
            {"w": jax.random.normal(jax.random.fold_in(KEY, 1), (3, 2))},
        ],
        "head": {"b": jnp.arange(4.0)},
    }
    bare = os.path.join(tmp_path, "soup")
    written = checkpoint.save(bare, tree)
    assert written == bare + ".npz"
    assert os.path.exists(written)
    assert not os.path.exists(bare)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    for p in (written, bare):  # suffixed and suffix-less spellings
        back = checkpoint.restore(p, like)
        for a, b in zip(
            jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
