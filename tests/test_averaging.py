"""Soups, ensembles, interpolation (paper §4 evaluation strategies)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import averaging as avg
from repro.core import population as pop


def _toy_linear_population(n=4):
    key = jax.random.key(0)
    ws = jax.random.normal(key, (n, 5, 3))
    return {"head": {"w": ws}}


def _apply(params, x):
    return x @ params["head"]["w"]


def test_uniform_soup_is_mean():
    p = _toy_linear_population()
    soup = avg.uniform_soup(p)
    np.testing.assert_allclose(
        np.asarray(soup["head"]["w"]), np.asarray(p["head"]["w"]).mean(0), rtol=1e-6
    )


def test_interpolate_weights():
    p = _toy_linear_population(3)
    w = jnp.asarray([1.0, 0.0, 0.0])
    m = avg.interpolate(p, w)
    np.testing.assert_allclose(
        np.asarray(m["head"]["w"]), np.asarray(p["head"]["w"])[0], rtol=1e-6
    )


def test_ensemble_beats_or_matches_members_on_average_prob():
    key = jax.random.key(1)
    x = jax.random.normal(key, (64, 5))
    p = _toy_linear_population(4)
    labels = jnp.argmax(_apply(pop.member(p, 0), x), axis=-1)
    accs = avg.member_accuracies(_apply, p, x, labels)
    ens = avg.ensemble_accuracy(_apply, p, x, labels)
    assert float(ens) >= float(jnp.min(accs)) - 1e-6


def test_greedy_soup_at_least_best_member():
    key = jax.random.key(2)
    x = jax.random.normal(key, (128, 5))
    p = _toy_linear_population(5)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (128,), 0, 3)
    best = float(jnp.max(avg.member_accuracies(_apply, p, x, labels)))
    gs = avg.greedy_soup(_apply, p, x, labels)
    acc = float(avg.model_accuracy(_apply, gs, x, labels))
    assert acc >= best - 1e-6
